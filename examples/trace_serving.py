"""Trace a serving run and open it in Perfetto: attach a ``Tracer`` +
``MetricsExporter`` to the disaggregated speculative engine, write
Chrome-trace JSON, and reconcile the trace against the engine's counters.

    PYTHONPATH=src python examples/trace_serving.py

Then load trace.json at https://ui.perfetto.dev (or chrome://tracing) —
one labeled lane per component: router decisions, prefill dispatch/harvest
(async spans over each request's in-flight window), decode-step phases
(dispatch/sync/commit), transfer extract/splice with the wire bytes,
the per-page freeze lifecycle (queued -> dispatched -> installed |
dropped | rolled_back as async spans), and speculative
propose/verify/accept/rollback.

CLI equivalent (any engine flags compose with the observability ones):
    PYTHONPATH=src python -m repro.launch.serve --reduced --engine disagg \
        --speculate 2 --kv-quant kmeans_ls@16 --migrate frozen \
        --trace-out trace.json --metrics-jsonl metrics.jsonl
"""
import json

import jax
import numpy as np

from repro import models
from repro.configs import get_reduced_config
from repro.obs import MetricsExporter, Tracer, count_events, prometheus_text
from repro.serving import DisaggEngine, derive_draft

cfg = get_reduced_config("qwen3_0_6b")
params = models.init_params(cfg, jax.random.PRNGKey(0))

B, prompt_len, gen = 4, 16, 12
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab, prompt_len).tolist() for _ in range(B)]

tracer = Tracer()                              # perf_counter clock
exporter = MetricsExporter("metrics.jsonl", interval_s=0.25)
eng = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                   migrate="frozen", kv_quant="kmeans_ls@16",
                   speculate=2, draft=derive_draft(params, cfg),
                   max_slots=B, block_size=8,
                   max_seq_len=prompt_len + gen + 4,
                   tracer=tracer, exporter=exporter)
eng.generate(prompts, max_new_tokens=gen)
exporter.close(eng.metrics)

tracer.write("trace.json")
d = json.load(open("trace.json"))
tracks = sorted(e["args"]["name"] for e in d["traceEvents"]
                if e["ph"] == "M" and e["name"] == "thread_name")
print(f"trace.json: {len(d['traceEvents'])} events on tracks {tracks}")
print("  -> load at https://ui.perfetto.dev")

# the trace is not just pictures — it reconciles exactly with the counters
c = eng.decode[0].counters
s = eng.metrics.summary()
assert count_events(tracer.events, name="decode_step", ph="X") \
    == c["decode_steps"]
assert count_events(tracer.events, name="flush", ph="X") \
    == c["freeze_dispatches"]
assert count_events(tracer.events, name="accept", ph="i") == s["spec_steps"]
print(f"reconciled: {c['decode_steps']} decode steps, "
      f"{c['freeze_dispatches']} freeze flushes, "
      f"{s['spec_steps']} verify slices against the trace")

# metrics.jsonl holds periodic snapshots (windowed p50/p99 per histogram);
# the same snapshot renders as Prometheus text exposition for scraping
rows = [json.loads(ln) for ln in open("metrics.jsonl")]
print(f"metrics.jsonl: {len(rows)} snapshots; final gen_tokens="
      f"{rows[-1]['gen_tokens']}")
print(prometheus_text(eng.metrics.snapshot()).splitlines()[0])
