"""Quickstart: quantize a vector with every method in the paper (+ ours).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import quantize, registry

rng = np.random.default_rng(0)
w = rng.normal(0, 1, 2000).round(2)          # duplicates -> 'm' unique values

print(f"{'spec':20s} {'n_values':>8s} {'l2_loss':>10s} {'bytes':>7s} {'time':>8s}")
for method in registry.methods():
    spec = (f"{method}:lam=0.05"
            if registry.get(method).param_kind == "lam" else f"{method}@16")
    qt, info = quantize(w, spec)
    print(f"{spec:20s} {info['n_values']:8d} {info['l2_loss']:10.4f} "
          f"{info['compressed_bytes']:7d} {info['time_s']*1e3:7.1f}ms")

qt, info = quantize(w, "kmeans_ls@16")
print(f"\ndense bytes: {w.size * 4}, compressed: {qt.nbytes()} "
      f"({w.size * 4 / qt.nbytes():.1f}x), codebook: {np.asarray(qt.codebook)[:5]}...")
