"""End-to-end driver: train a ~100M-param LM with the full stack - sharded
step, synthetic pipeline, checkpointing trainer with crash recovery.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to 40 steps so the example finishes in ~a minute on CPU)
"""
import argparse
import dataclasses
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import LayerSpec, ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.runtime.ftolerance import Trainer
from repro.train.step import make_train_step, train_state_specs
from repro.runtime.sharding import batch_shardings


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="lm", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
        group=(LayerSpec(),), qk_norm=True,
        param_dtype="float32", compute_dtype="float32", scan_chunk=32,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject crashes at these steps (recovery demo)")
    args = ap.parse_args()

    cfg = config_100m()
    mesh = make_host_mesh(2, 4)
    step_fn, opt = make_train_step(cfg, mesh, lr=3e-4)
    state_shape, state_shard = train_state_specs(cfg, mesh, opt)
    n_params = sum(int(jnp.size(x)) for x in jax.tree.leaves(state_shape["params"]))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  mesh={dict(mesh.shape)}")

    specs = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
             "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
    pipe = SyntheticLM(cfg, args.batch, args.seq, seed=0)
    bshard = batch_shardings(mesh, specs)
    jit_step = jax.jit(step_fn, in_shardings=(state_shard, bshard),
                       out_shardings=(state_shard, None), donate_argnums=(0,))

    with jax.set_mesh(mesh):
        def init_state():
            params = jax.device_put(models.init_params(cfg, jax.random.PRNGKey(0)),
                                    state_shard["params"])
            return {"params": params,
                    "opt": jax.device_put(opt.init(params), state_shard["opt"]),
                    "step": jnp.zeros((), jnp.int32)}

        trainer = Trainer(
            step_fn=jit_step, init_state_fn=init_state,
            next_batch_fn=lambda s: pipe.next_batch(s, mesh, specs),
            ckpt_dir=args.ckpt_dir, ckpt_every=20,
            fail_at=set(args.fail_at), async_ckpt=True)
        state = trainer.run(args.steps)

    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"steps={len(trainer.metrics_log)} restarts={trainer.restarts} "
          f"stragglers={len(trainer.monitor.flagged)}")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"(improved={losses[-1] < losses[0]})")


if __name__ == "__main__":
    main()
