"""Quantized serving: PTQ a small LM with the paper's solver, then decode
with batched requests comparing dense vs value-shared weights.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_reduced_config
from repro.quant.ptq import compression_ratio, dequantize_tree, quantize_tree
from repro.quant.serve import estimate_decode_bytes

cfg = get_reduced_config("qwen3_0_6b")
params = models.init_params(cfg, jax.random.PRNGKey(0))

# PTQ with the paper's Algorithm 3 (k-means + least squares), 16 values/tensor
qtree, report = quantize_tree(params, "kmeans_ls@16:weighted=true")
ratio = compression_ratio(report)
print(f"quantized {len(report)} tensors; compression {ratio:.1f}x")

params_q = dequantize_tree(qtree)

B, prompt_len, gen = 4, 16, 12
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len), 0, cfg.vocab)


def generate(p):
    cache = models.init_cache(cfg, B, prompt_len + gen)
    logits, cache = models.prefill(p, cfg, {"tokens": tokens}, cache)
    tok = jnp.argmax(logits[:, None] if logits.ndim == 2 else logits, -1)
    tok = tok[:, -1:].astype(jnp.int32) if tok.ndim == 2 else tok
    out = [tok]
    for i in range(gen - 1):
        logits, cache = models.decode_step(p, cfg, tok, cache, prompt_len + i)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


dense_out = generate(params)
quant_out = generate(params_q)
agree = float((dense_out == quant_out).mean())
print(f"decode agreement dense vs 16-value quantized: {agree*100:.0f}% "
      f"({gen} tokens x {B} requests)")

# roofline estimate of the decode speedup on TPU v5e (decode = HBM-bound)
n_params = sum(int(x.size) for x in jax.tree.leaves(params))
est = estimate_decode_bytes(n_params * 2, ratio, cache_bytes=0)
print(f"v5e decode-step estimate: dense {est['t_dense_s']*1e6:.1f}us -> "
      f"quantized {est['t_quant_s']*1e6:.1f}us ({est['speedup']:.2f}x weight-read speedup)")
