"""Quantized serving: PTQ a small LM with the paper's solver, then decode
with batched requests comparing dense vs value-shared weights.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_reduced_config
from repro.quant.ptq import compression_ratio, dequantize_tree, quantize_tree
from repro.quant.serve import estimate_decode_bytes

cfg = get_reduced_config("qwen3_0_6b")
params = models.init_params(cfg, jax.random.PRNGKey(0))

# PTQ with the paper's Algorithm 3 (k-means + least squares), 16 values/tensor
qtree, report = quantize_tree(params, "kmeans_ls@16:weighted=true")
ratio = compression_ratio(report)
print(f"quantized {len(report)} tensors; compression {ratio:.1f}x")

params_q = dequantize_tree(qtree)

B, prompt_len, gen = 4, 16, 12
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len), 0, cfg.vocab)


def generate(p):
    cache = models.init_cache(cfg, B, prompt_len + gen)
    logits, cache = models.prefill(p, cfg, {"tokens": tokens}, cache)
    tok = jnp.argmax(logits[:, None] if logits.ndim == 2 else logits, -1)
    tok = tok[:, -1:].astype(jnp.int32) if tok.ndim == 2 else tok
    out = [tok]
    for i in range(gen - 1):
        logits, cache = models.decode_step(p, cfg, tok, cache, prompt_len + i)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


dense_out = generate(params)
quant_out = generate(params_q)
agree = float((dense_out == quant_out).mean())
print(f"decode agreement dense vs 16-value quantized: {agree*100:.0f}% "
      f"({gen} tokens x {B} requests)")

# roofline estimate of the decode speedup on TPU v5e (decode = HBM-bound)
n_params = sum(int(x.size) for x in jax.tree.leaves(params))
est = estimate_decode_bytes(n_params * 2, ratio, cache_bytes=0)
print(f"v5e decode-step estimate: dense {est['t_dense_s']*1e6:.1f}us -> "
      f"quantized {est['t_quant_s']*1e6:.1f}us ({est['speedup']:.2f}x weight-read speedup)")

# --- disaggregated serving with frozen KV page migration -------------------
# The same solvers also compress the serving KV cache AND the prefill->
# decode handoff: a DisaggEngine runs prompts on prefill workers and
# migrates finished pages to decode workers as packed 4-bit codes +
# per-block codebooks (migrate="frozen", ~7x fewer bytes than fp rows).
# CLI equivalent (plus --prefill-workers/--decode-workers, the TTFT/TPOT
# ratio knob, --freeze-page-budget, and --temperature/--top-k sampling —
# see `python -m repro.launch.serve --help`):
#   PYTHONPATH=src python -m repro.launch.serve --reduced --engine disagg \
#       --kv-quant kmeans_ls@16 --migrate frozen --request-rate 4
from repro.serving import DisaggEngine

eng = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                   migrate="frozen", kv_quant="kmeans_ls@16",
                   max_slots=B, block_size=16,
                   max_seq_len=prompt_len + gen + 16)
eng.generate([np.asarray(tokens[i]).tolist() for i in range(B)],
             max_new_tokens=gen)
s = eng.metrics.summary()
c = eng.decode[0].counters
print(f"disagg serve: {s['completed']} requests, prefill->decode handoff "
      f"moved {c['migrate_bytes']/1e3:.1f} kB as codes+codebooks "
      f"(fp rows would be {c['migrate_fp_equiv_bytes']/1e3:.1f} kB, "
      f"{c['migrate_fp_equiv_bytes']/max(c['migrate_bytes'],1):.1f}x more), "
      f"{c['host_page_solves']} host page solves")
