"""The paper's end-to-end scenario (§4.1): train the 784-256-128-64-10 MLP,
quantize the last layer with each method, measure the accuracy cost, then
recover it with one round of QAT (straight-through) fine-tuning.

    PYTHONPATH=src python examples/train_quantized_mlp.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import train_paper_mlp
from repro.core import quantize
from repro.models.mlp import mlp_accuracy, mlp_loss
from repro.quant.qat import fake_quant

params, (xtr, ytr), (xte, yte), acc_tr, acc_te = train_paper_mlp()
print(f"baseline: train {acc_tr:.4f}  test {acc_te:.4f}")
w = np.asarray(params[-1]["w"])
xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)
xtr_j, ytr_j = jnp.asarray(xtr), jnp.asarray(ytr)

for l in (4, 8, 16):
    qt, info = quantize(w, f"kmeans_ls@{l}:weighted=true")
    p2 = [dict(layer) for layer in params]
    p2[-1]["w"] = qt.to_dense()
    acc_q = float(mlp_accuracy(p2, xte_j, yte_j))

    # QAT recovery: fine-tune THROUGH the quantizer for 100 steps
    cb = qt.codebook

    def qat_loss(p, x, y):
        pq = [dict(layer) for layer in p]
        pq[-1]["w"] = fake_quant(pq[-1]["w"], cb)
        return mlp_loss(pq, x, y)

    p3 = [dict(layer) for layer in params]

    @jax.jit
    def step(p, i):
        idx = (jnp.arange(256) + i * 256) % xtr_j.shape[0]
        g = jax.grad(qat_loss)(p, xtr_j[idx], ytr_j[idx])
        return jax.tree.map(lambda a, b: a - 3e-3 * b, p, g), None

    p3, _ = jax.lax.scan(step, p3, jnp.arange(100))
    p3[-1]["w"] = fake_quant(p3[-1]["w"], cb)
    acc_qat = float(mlp_accuracy(p3, xte_j, yte_j))
    print(f"l={l:3d}: PTQ test acc {acc_q:.4f}  ->  QAT-recovered {acc_qat:.4f}"
          f"  (n_values={info['n_values']}, l2={info['l2_loss']:.4f})")
