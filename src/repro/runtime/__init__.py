"""Runtime layer: sharding rules, activation hints, pipeline parallelism,
and fault tolerance."""
