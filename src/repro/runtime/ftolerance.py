"""Fault tolerance: resumable training loop, failure injection, straggler
monitor, elastic restart.

On real pods, a node failure kills the process; recovery = restart + restore
latest checkpoint + resume the data stream at the saved step (the pipeline
is deterministic in (seed, step), so no data is skipped or repeated). The
Trainer below implements exactly that loop and the tests inject failures
mid-run to prove end-state equivalence with an uninterrupted run.
"""
from __future__ import annotations

import collections
import time


class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than `threshold` x EMA.

    On-device work is identical across chips under SPMD, so per-host step
    time is the right signal; on a real cluster the flagged host is reported
    to the scheduler for preemptive replacement (here: recorded + surfaced).
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ema = None
        self.n = 0
        self.flagged: list[tuple[int, float, float]] = []

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = (self.n > self.warmup
                        and dt > self.threshold * self.ema)
        if is_straggler:
            self.flagged.append((step, dt, self.ema))
        else:   # don't let the outlier poison the EMA
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    """Checkpointed training loop with crash recovery.

    run() survives any number of SimulatedFailure (or real) crashes between
    checkpoints: each retry restores the latest checkpoint and replays the
    deterministic data stream from there.
    """

    def __init__(self, *, step_fn, init_state_fn, next_batch_fn, ckpt_dir,
                 ckpt_every: int = 10, keep_last: int = 3,
                 fail_at: set | None = None, async_ckpt: bool = False):
        self.step_fn = step_fn
        self.init_state_fn = init_state_fn
        self.next_batch_fn = next_batch_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep_last = keep_last
        self.fail_at = fail_at or set()
        self.async_ckpt = async_ckpt
        self.monitor = StragglerMonitor()
        self.metrics_log: list[dict] = []
        self.restarts = 0

    def _restore_or_init(self):
        from repro.checkpoint import ckpt

        state = self.init_state_fn()
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is not None:
            state, step = ckpt.restore(state, self.ckpt_dir)
            return state, step
        return state, 0

    def run(self, total_steps: int, *, max_restarts: int = 10):
        from repro.checkpoint import ckpt

        attempts = 0
        while True:
            try:
                state, start = self._restore_or_init()
                pending = None
                for step in range(start, total_steps):
                    if step in self.fail_at:
                        self.fail_at.discard(step)
                        raise SimulatedFailure(f"injected failure @ step {step}")
                    t0 = time.perf_counter()
                    batch = self.next_batch_fn(step)
                    state, metrics = self.step_fn(state, batch)
                    dt = time.perf_counter() - t0
                    self.monitor.record(step, dt)
                    self.metrics_log.append(
                        {"step": step, **{k: float(v) for k, v in metrics.items()}})
                    if (step + 1) % self.ckpt_every == 0:
                        if pending is not None:
                            pending.join()
                        pending = ckpt.save(state, self.ckpt_dir, step + 1,
                                            keep_last=self.keep_last,
                                            async_=self.async_ckpt)
                if pending is not None:
                    pending.join()
                ckpt.save(state, self.ckpt_dir, total_steps,
                          keep_last=self.keep_last)
                return state
            except SimulatedFailure:
                attempts += 1
                self.restarts += 1
                if attempts > max_restarts:
                    raise
