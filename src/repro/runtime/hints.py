"""Activation-sharding hints decoupled from model code.

Model code calls ``hint(x, "hidden")`` etc.; the distributed step builder
installs a mapping kind -> PartitionSpec for the active mesh. Outside a
context (unit tests, single-host smoke runs) hints are no-ops, so the same
model code serves 1-device tests and 512-chip dry-runs.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding

_CTX = contextvars.ContextVar("shard_hints", default=None)


@contextlib.contextmanager
def hint_context(mesh, specs: dict):
    tok = _CTX.set((mesh, specs))
    try:
        yield
    finally:
        _CTX.reset(tok)


def _axsize(mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        out = 1
        for a in ax:
            out *= mesh.shape[a]
        return out
    return mesh.shape[ax]


# kinds where a non-divisible dim may still shard with GSPMD padding
PAD_OK_KINDS = frozenset({"wkv"})


def model_axis_size() -> int:
    ctx = _CTX.get()
    if ctx is None:
        return 1
    mesh, _ = ctx
    return int(mesh.shape.get("model", 1))


def hint(x, kind: str):
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, specs = ctx
    spec = specs.get(kind)
    if spec is None:
        return x
    from jax.sharding import PartitionSpec as P

    if kind not in PAD_OK_KINDS:
        # drop sharding on axes the runtime shape doesn't divide (e.g. the
        # sequence-parallel 'model' axis on S=1 decode steps)
        fitted = []
        for dim, ax in zip(x.shape,
                           tuple(spec) + (None,) * (x.ndim - len(spec))):
            fitted.append(ax if ax is not None
                          and dim % _axsize(mesh, ax) == 0 else None)
        spec = P(*fitted)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh():
    ctx = _CTX.get()
    return None if ctx is None else ctx[0]
