"""Sharding rules: parameter/activation/cache PartitionSpecs per mesh.

Name-based rules (DESIGN.md §6): TP over 'model' (heads / ffn / experts /
vocab), FSDP 2-D sharding of weights and optimizer state over
('data','model') within a pod, batch over ('pod','data'); pods replicate
params (DP across pods - where quantized gradient all-reduce applies).

Rules degrade gracefully: any dim not divisible by its axis size falls back
to replication for that dim (GSPMD would pad; we'd rather keep the bytes
honest and flag it - see roofline notes for glm4 kv=2 / granite 40e / rwkv
40 heads).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# ------------------------------------------------------------------ helpers


def _axsize(mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return int(mesh.shape[ax])


def _fit(mesh, spec: P, shape) -> P:
    """Drop sharding on dims the shape doesn't divide evenly."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        out.append(ax if ax is not None and dim % _axsize(mesh, ax) == 0 else None)
    return P(*out)


# ------------------------------------------------------------- param rules

# matched against the LAST path component; first hit wins. (in_dim-sharded
# matrices put 'data' on dim 0 = FSDP; out-dim 'model' = TP megatron split)
_PARAM_RULES_2D = {
    # (d_in, out*) column-parallel
    "wq": P("data", "model"), "wk": P("data", "model"), "wv": P("data", "model"),
    "c_wq": P("data", "model"), "c_wk": P("data", "model"), "c_wv": P("data", "model"),
    "w_gate": P("data", "model"), "w_up": P("data", "model"),
    "w_in": P("data", "model"), "w_r": P("data", "model"), "w_k": P("data", "model"),
    "w_v": P("data", "model"), "w_g": P("data", "model"), "c_k": P("data", "model"),
    "c_r": P("data", "model"), "w_lora_a": P("data", None),
    # (in*, d_out) row-parallel
    "wo": P("model", "data"), "c_wo": P("model", "data"),
    "w_down": P("model", "data"), "w_out": P("model", "data"),
    "w_o": P("model", "data"), "c_v": P("model", "data"),
    "w_lora_b": P(None, "data"),
    # embeddings
    "embed": P("model", "data"), "lm_head": P("data", "model"),
    # mla
    "wdkv": P("data", None), "wkr": P("data", None), "wukv": P(None, "model"),
    # mamba
    "w_bcdt": P("model", None), "w_dt": P(None, "model"),
    "A_log": P("model", None), "conv_w": P(None, "model"),
    # router: replicated (tiny, f32)
    "router": P(None, None),
}
_PARAM_RULES_3D = {   # MoE expert-stacked weights: experts over 'model'
    "w_gate": P("model", "data", None), "w_up": P("model", "data", None),
    "w_down": P("model", None, "data"),
}
_PARAM_RULES_1D = {
    "dt_bias": P("model"), "D_skip": P("model"),
}


def param_spec(mesh, path, leaf) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    last = names[-1]
    stacked = "groups" in names           # scanned layer stack: leading G axis
    core_shape = leaf.shape[1:] if stacked else leaf.shape
    rank = len(core_shape)
    spec = None
    if rank == 3 and last in _PARAM_RULES_3D:
        spec = _PARAM_RULES_3D[last]
    elif rank == 2 and last in _PARAM_RULES_2D:
        spec = _PARAM_RULES_2D[last]
    elif rank == 1 and last in _PARAM_RULES_1D:
        spec = _PARAM_RULES_1D[last]
    if spec is None:
        spec = P(*([None] * rank))        # norms, biases, mix coeffs: replicate
    spec = _fit(mesh, spec, core_shape)
    if stacked:
        spec = P(None, *spec)
    return spec


def param_shardings(mesh, params_shape):
    """Pytree of NamedShardings matching a params (shape-)pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(mesh, path, leaf)),
        params_shape)


# ------------------------------------------------------- activations hints


def hint_specs(cfg, mesh) -> dict:
    bax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bax = bax if len(bax) > 1 else (bax[0] if bax else None)
    msize = mesh.shape.get("model", 1)
    kv_ok = (cfg.n_kv_heads % msize) == 0
    heads_ok = (cfg.n_heads % msize) == 0
    return {
        # sequence parallelism: the residual stream (and thus every
        # remat-boundary save) shards S over 'model' - 16x less activation
        # memory than replicating; GSPMD inserts the Megatron-SP
        # all-gather / reduce-scatter pairs around attention/ffn.
        # hint() drops the constraint when S doesn't divide (decode S=1).
        "hidden": P(bax, "model", None),
        # heads divide the model axis -> head-parallel attention (scores
        # shard on heads); otherwise context-parallel: q's SEQ dim shards
        # over 'model' and the grouped einsum keeps KV un-repeated. Either
        # way the (B,*,Sq,Skv) score tiles are 1/model-axis sized -
        # replication was the 16x memory/traffic failure mode.
        "qkv": (P(bax, None, "model", None) if heads_ok
                else P(bax, "model", None, None)),
        "kv": P(bax, None, "model" if kv_ok else None, None),
        "ffn": P(bax, None, "model"),
        "logits": P(bax, None, "model"),
        "moe_buf": P(bax, "model", None, None),
        "moe_h": P(bax, "model", None, None),
        # combine path: token(xK) dim over 'model' - aligns with the SP'd
        # sequence so the K-sum stays local and the expert->token movement
        # lowers to permutes instead of a (B,S*K,D) f32 all-reduce
        "moe_tok": P(bax, "model", None),
        "ssm_inner": P(bax, None, "model"),
        # rwkv wkv region: on a single pod, shard BATCH over (data x model)
        # (exact & collective-cheap: no weight matmuls inside); on multi-pod
        # the global batch doesn't divide pod*data*model, so pad-shard the
        # 40 heads over 'model' instead (hints.PAD_OK_KINDS).
        "wkv": (P(("data", "model"), None, None, None)
                if "pod" not in mesh.axis_names
                else P(bax, None, "model", None)),
    }


# ------------------------------------------------------------- cache specs


def cache_spec(mesh, cfg, path, leaf, *, batch_size: int) -> P:
    """KV/state cache sharding; when batch < data-axis size, shard the
    sequence dim of KV buffers instead (sequence-parallel decode)."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    last = names[-1]
    stacked = "groups" in names or "cross" in names
    core_shape = leaf.shape[1:] if stacked else leaf.shape
    dsize = mesh.shape.get("data", 1)
    msize = mesh.shape.get("model", 1)
    batch_ok = batch_size % dsize == 0

    def heads_spec(n_heads):
        return "model" if n_heads % msize == 0 else None

    if last in ("k", "v", "k_s", "v_s"):   # (B, L, Hkv, Dh|1)
        hs = heads_spec(cfg.n_kv_heads)
        if batch_ok:
            # heads divide the model axis -> head sharding (no softmax
            # all-reduce); otherwise shard the KV LENGTH over 'model'
            # (sequence-parallel decode; replicating a 32k cache across 16
            # model shards would cost 16x HBM - EXPERIMENTS.md §Dry-run)
            spec = (P("data", None, hs, None) if hs is not None
                    else P("data", "model", None, None))
        else:                        # batch too small: SP over everything
            spec = (P(None, "data", hs, None) if hs is not None
                    else P(None, ("data", "model"), None, None))
    elif last in ("ckv", "krope"):   # MLA latent (B, L, r)
        spec = (P("data", "model", None) if batch_ok
                else P(None, ("data", "model"), None))
    elif last == "h":                # mamba state (B, E, N)
        spec = P("data" if batch_ok else None, "model", None)
    elif last == "conv":             # (B, dc-1, E)
        spec = P("data" if batch_ok else None, None, "model")
    elif last == "s":                # rwkv state (B, H, hd, hd)
        hs = heads_spec(cfg.d_model // cfg.rwkv_head_dim)
        spec = P("data" if batch_ok else None, hs, None, None)
    elif last in ("shift_t", "shift_c"):
        spec = P("data" if batch_ok else None, None)
    else:
        spec = P(*([None] * len(core_shape)))
    spec = _fit(mesh, spec, core_shape)
    if stacked:
        spec = P(None, *spec)
    return spec


def cache_shardings(mesh, cfg, cache_shape, *, batch_size: int):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(mesh, cfg, path, leaf, batch_size=batch_size)),
        cache_shape)


def batch_shardings(mesh, batch_shape):
    """Token/label/embed inputs: batch dim over ('pod','data') when divisible."""
    bax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bax = bax if len(bax) > 1 else (bax[0] if bax else None)

    def spec(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        shape = leaf.shape
        if names and names[-1] == "positions" and len(shape) == 3:
            return NamedSharding(mesh, _fit(mesh, P(None, bax, None), shape))
        return NamedSharding(
            mesh, _fit(mesh, P(bax, *([None] * (len(shape) - 1))), shape))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)
