"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

The assigned dry-run mesh is DP x TP (x pod) per the task spec, so PP is a
framework capability demonstrated at small scale (tests run it on 4 host
devices) rather than part of the 40-cell table. Implementation: shard_map
over 'stage'; each stage holds its layer slice; microbatches stream through
with `ppermute` handoffs; the schedule is GPipe (fill-drain) with
B/microbatch bubbles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn, params_stacked, x_microbatches, *, mesh,
                     n_stages: int):
    """Run x through n_stages stage_fns with GPipe microbatching.

    params_stacked: pytree with leading dim n_stages (stage i's params).
    x_microbatches: (n_micro, mb, ...) activations entering stage 0.
    Returns (n_micro, mb, ...) outputs of the last stage.
    """
    n_micro = x_microbatches.shape[0]

    def per_stage(params, xs):
        stage = jax.lax.axis_index("stage")
        params = jax.tree.map(lambda p: p[0], params)   # local stage slice
        xs = xs[0]                                       # sharded dim

        steps = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs)

        def body(carry, t):
            buf, inflight = carry
            # receive from previous stage (stage 0 injects microbatch t)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = xs[mb_idx]
            recv = jax.lax.ppermute(
                inflight, "stage",
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            x_in = jnp.where(stage == 0, inject, recv)
            active = (t - stage >= 0) & (t - stage < n_micro)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, x_in)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_out = (stage == n_stages - 1) & (t - stage >= 0) & (t - stage < n_micro)
            buf = jnp.where(is_out,
                            jax.lax.dynamic_update_index_in_dim(
                                buf, y, out_idx, 0),
                            buf)
            return (buf, y), None

        (buf, _), _ = jax.lax.scan(body, (buf, jnp.zeros_like(xs[0])),
                                   jnp.arange(steps))
        return buf[None]

    fn = jax.shard_map(
        per_stage, mesh=mesh,
        in_specs=(P("stage"), P(None)),
        out_specs=P("stage"),
        axis_names=frozenset({"stage"}), check_vma=False)
    out = fn(params_stacked, x_microbatches[None])
    # every stage returns a buffer; the last stage's is the real one
    return out[-1]
