"""Training step builders (loss, grad accumulation, optimizer wiring)."""
