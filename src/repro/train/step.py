"""Step builders: train / prefill / decode, with shardings for pjit.

Everything here is AOT-friendly: ``input_specs`` produces ShapeDtypeStruct
stand-ins for all inputs of every assigned (arch x shape) cell, and the
builders return (fn, in_shardings, out_shardings) ready for
``jax.jit(...).lower().compile()`` - the multi-pod dry-run path.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.optim import for_config
from repro.runtime.hints import hint_context
from repro.runtime.sharding import (batch_shardings, cache_shardings,
                                    hint_specs, param_shardings)

# ---------------------------------------------------------------- shapes

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}
WHISPER_ENC_LEN = 1504   # whisper's 30s window (1500 frames, padded to 32*47)


def shape_skip_reason(cfg, shape_name: str) -> str | None:
    """Cells skipped BY DESIGN (recorded in EXPERIMENTS.md, not silent)."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 512k decode needs sub-quadratic "
                "attention (DESIGN.md §4)")
    return None


def input_specs(cfg, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    i32 = jnp.int32
    f = jnp.dtype(cfg.compute_dtype)
    specs: dict[str, Any] = {}
    if info["kind"] in ("train", "prefill"):
        if cfg.family == "encdec":
            specs["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        elif cfg.input_kind == "embeds":
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
            if cfg.mrope_sections is not None:
                specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if info["kind"] == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token against a seq-long cache
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    return specs


def cache_specs(cfg, shape_name: str):
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    enc_len = WHISPER_ENC_LEN if cfg.family == "encdec" else None
    return jax.eval_shape(
        functools.partial(models.init_cache, cfg, B, S, enc_len=enc_len))


# ---------------------------------------------------------------- loss


def lm_loss(params, cfg, batch, *, train=True, loss_chunk: int = 512):
    """Next-token CE + z-loss, computed in sequence chunks.

    Chunking the head projection + softmax (with remat on the chunk body)
    bounds the f32 logits live-set to (B, chunk, V) instead of (B, S, V) -
    at vocab 152k x seq 4k this is the difference between ~7.5 GB/device and
    ~0.1 GB/device (memory notes in EXPERIMENTS.md §Dry-run).
    """
    x = models.forward(params, cfg, batch, train=train, return_hidden=True)
    B, S, D = x.shape
    labels = batch["labels"]

    def chunk_loss(xc, lc):
        logits = models.lm_head(params, cfg, xc).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        ce_sum = jnp.sum(lse - picked)
        z_sum = jnp.sum(lse ** 2)
        return ce_sum, z_sum

    if S % loss_chunk == 0 and S > loss_chunk:
        nc = S // loss_chunk
        xs = (x.reshape(B, nc, loss_chunk, D).swapaxes(0, 1),
              labels.reshape(B, nc, loss_chunk).swapaxes(0, 1))

        def body(carry, xs_i):
            ce_sum, z_sum = jax.checkpoint(chunk_loss)(xs_i[0], xs_i[1])
            return (carry[0] + ce_sum, carry[1] + z_sum), None

        (ce_sum, z_sum), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.float32(0.0)), xs)
    else:
        ce_sum, z_sum = chunk_loss(x, labels)
    n = B * S
    loss = ce_sum / n
    zl = 1e-4 * z_sum / n
    return loss + zl, {"ce": loss, "zloss": zl}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------- builders


def make_train_step(cfg, mesh, *, lr: float = 3e-4, clip_norm: float = 1.0,
                    grad_compress=None):
    """Returns (train_step, state_shardings, batch_shardings_fn).

    grad_compress: optional fn(grads)->grads applied before the optimizer
    (cross-pod quantized all-reduce with error feedback lives there).
    """
    opt = for_config(cfg)
    hs = hint_specs(cfg, mesh)

    def train_step(state, batch):
        with hint_context(mesh, hs):
            (loss, metrics), grads = jax.value_and_grad(
                lm_loss, has_aux=True)(state["params"], cfg, batch)
            if grad_compress is not None:
                grads = grad_compress(grads)
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
            params, opt_state = opt.update(grads, state["opt"],
                                           state["params"], lr=lr)
            metrics = dict(metrics, loss=loss, grad_norm=gn)
            new_state = {"params": params, "opt": opt_state,
                         "step": state["step"] + 1}
        return new_state, metrics

    return train_step, opt


def make_prefill_step(cfg, mesh, shape_name: str):
    """Prefill allocates + fills the cache inside the step (counted by
    memory_analysis as outputs). Returns last-position logits + cache."""
    hs = hint_specs(cfg, mesh)
    info = SHAPES[shape_name]

    def prefill_step(params, batch):
        with hint_context(mesh, hs):
            enc_len = WHISPER_ENC_LEN if cfg.family == "encdec" else None
            cache = models.init_cache(cfg, info["batch"], info["seq"],
                                      enc_len=enc_len)
            logits, cache = models.prefill(params, cfg, batch, cache)
            return logits[:, -1], cache

    return prefill_step


def make_decode_step(cfg, mesh, shape_name: str):
    """One token in, one token out, cache updated in place (donated)."""
    hs = hint_specs(cfg, mesh)
    info = SHAPES[shape_name]
    cache_index = info["seq"] - 1

    def decode_step(params, tokens, cache):
        with hint_context(mesh, hs):
            logits, new_cache = models.decode_step(params, cfg, tokens, cache,
                                                   cache_index)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return next_tok[:, None], new_cache

    return decode_step


# ---------------------------------------------------------------- shardings


def _names(path):
    return tuple(getattr(k, "key", getattr(k, "name", str(k))) for k in path)


def opt_state_shardings(mesh, params_sharding_tree, opt_state_shape):
    """Optimizer state mirrors param shardings; adafactor vr/vc reduce the
    spec along the factored dim; scalars replicate."""
    flat = {}
    jax.tree_util.tree_map_with_path(
        lambda p, s: flat.__setitem__(_names(p), s), params_sharding_tree)

    def per_leaf(path, leaf):
        names = _names(path)
        if names[-1] == "count" or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        sub = names[1:]  # drop leading "m"/"v"
        if sub in flat:
            return flat[sub]
        if names[-1] in ("vr", "vc", "v") and sub[:-1] in flat:
            spec = flat[sub[:-1]].spec
            if names[-1] == "vr":      # reduced over last dim
                return NamedSharding(mesh, P(*spec[:-1]))
            if names[-1] == "vc":      # reduced over second-to-last dim
                return NamedSharding(mesh, P(*(tuple(spec[:-2]) + tuple(spec[-1:]))))
            return flat[sub[:-1]]
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return jax.tree_util.tree_map_with_path(per_leaf, opt_state_shape)


def train_state_specs(cfg, mesh, opt):
    """(state_shape, state_shardings) via eval_shape - no allocation."""
    params_shape = jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0)))
    p_shard = param_shardings(mesh, params_shape)
    opt_shape = jax.eval_shape(opt.init, params_shape)
    o_shard = opt_state_shardings(mesh, p_shard, opt_shape)
    state_shape = {"params": params_shape, "opt": opt_shape,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
    state_shard = {"params": p_shard, "opt": o_shard,
                   "step": NamedSharding(mesh, P())}
    return state_shape, state_shard
