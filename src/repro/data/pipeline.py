"""Deterministic synthetic data pipeline, sharded per host.

Produces seeded token/embedding batches as globally-sharded jax.Arrays via
``make_array_from_callback`` - each host materializes only its addressable
shards (the multi-host pattern; on one host it degenerates gracefully).
Deterministic in (seed, step): restarts resume mid-epoch without state.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.runtime.sharding import batch_shardings


@dataclasses.dataclass
class SyntheticLM:
    cfg: object
    batch: int
    seq: int
    seed: int = 0

    def _host_tokens(self, step: int, lo: int, hi: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step, lo))
        # markov-ish stream so the loss is learnable, not pure noise
        v = self.cfg.vocab
        base = rng.integers(0, v, size=(hi - lo, seq), dtype=np.int64)
        drift = np.arange(seq)[None, :] * 31
        return ((base + drift) % v).astype(np.int32)

    def batch_specs(self):
        from repro.train.step import input_specs  # avoid cycle at import

        return {k: v for k, v in input_specs(self.cfg, "train_4k").items()}

    def next_batch(self, step: int, mesh, specs: dict) -> dict:
        """specs: name -> ShapeDtypeStruct (any train shape)."""
        shards = batch_shardings(mesh, specs)
        out = {}
        for name, sds in specs.items():
            sharding = shards[name]

            def cb(index, name=name, sds=sds):
                # index: tuple of slices into the global shape
                if name in ("tokens", "labels"):
                    lo, hi = index[0].start or 0, index[0].stop or sds.shape[0]
                    s0 = index[1].start or 0
                    s1 = index[1].stop or sds.shape[1]
                    tok = self._host_tokens(step, lo, hi, sds.shape[1])
                    arr = tok[:, s0:s1]
                    return arr if name == "tokens" else np.roll(arr, -1, axis=1)
                shape = tuple(sl.stop - sl.start if isinstance(sl, slice)
                              else sl for sl in
                              (slice(*s.indices(dim)) for s, dim in
                               zip(index, sds.shape)))
                rng = np.random.default_rng((self.seed, step, hash(name) % 997))
                return rng.normal(0, 1, size=shape).astype(sds.dtype)

            out[name] = jax.make_array_from_callback(sds.shape, sharding, cb)
        return out
