"""Deterministic synthetic data pipeline (sharded host loading)."""
from .pipeline import SyntheticLM

__all__ = ["SyntheticLM"]
