"""Sharded, atomic, async checkpointing with reshard-on-load.

Layout: <dir>/step_<N>/ with one .npy per host-local shard chunk plus a
manifest (tree structure, global shapes, dtypes). Writes go to a tmp dir and
are renamed atomically; keep_last prunes old steps. ``restore`` accepts ANY
target mesh/sharding: it reassembles from the manifest and re-shards
(elastic restart across different pod counts - DESIGN.md fault tolerance).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "::"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(getattr(k, "key", getattr(k, "name", str(k)))
                        for k in path)
        flat[key] = leaf
    return flat


def save(state, directory: str, step: int, *, keep_last: int = 3,
         async_: bool = False):
    """Write a checkpoint. async_=True returns a thread (join to wait)."""
    # gather to host BEFORE the thread: jax.device_get in the main thread,
    # disk I/O (the slow part) off the critical path
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(state).items()}

    def _write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        for key, arr in flat.items():
            fname = f"{abs(hash(key)) % 10**12}_{len(manifest)}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest[key] = {"file": fname, "shape": list(arr.shape),
                             "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "tree": manifest, "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        _prune(directory, keep_last)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _prune(directory: str, keep_last: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(like_tree, directory: str, *, step: int | None = None,
            shardings=None):
    """Load into the structure of ``like_tree`` (shapes/dtypes validated).

    shardings: optional matching pytree of NamedShardings - the arrays are
    device_put with the CURRENT mesh, whatever its size (reshard-on-load).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["tree"]

    flat_like = _flatten(like_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    missing = set(flat_like) - set(manifest)
    extra = set(manifest) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint/tree mismatch: missing={sorted(missing)[:4]} "
                         f"extra={sorted(extra)[:4]}")
    out_flat = {}
    for key, like in flat_like.items():
        meta = manifest[key]
        arr = np.load(os.path.join(d, meta["file"]))
        want_shape = tuple(like.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: ckpt {arr.shape} != expected {want_shape}")
        arr = arr.astype(like.dtype)
        if key in flat_sh:
            out_flat[key] = jax.device_put(arr, flat_sh[key])
        else:
            out_flat[key] = jnp.asarray(arr)
    # unflatten by path
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    keys = list(_flatten(like_tree).keys())
    return jax.tree_util.tree_unflatten(
        treedef, [out_flat[k] for k in keys]), step
