"""Checkpointing: save/restore of sharded pytrees with reshard-on-load."""
