"""CLI driver: ``python -m repro.analysis [paths...]``.

Runs the registered lint passes over the given files/directories
(default: ``src/repro``), subtracts the committed baseline, and exits
non-zero on any new finding — the CI fast-lane gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .lint import (all_passes, iter_python_files, load_baseline, Module,
                   partition_baseline, run_passes, save_baseline)

_EPILOG = """\
pragma syntax (suppression must carry a reason):

    nxt = np.asarray(argmax)  # lint: sync(step-end token sync)

  # lint: <pass>(<reason>)[, <pass>(<reason>)...]

A pragma suppresses that pass's findings on its own line and the line
directly below it (so it can sit alone above a long statement).  Pragmas
with an empty reason (LINT001), an unknown pass name (LINT002), or that
suppress nothing (LINT003) are themselves findings.

baseline workflow:

  findings already accepted live in analysis-baseline.json (fingerprints,
  line-number free); only NEW findings fail the gate.  Regenerate with
  --write-baseline after review.  The baseline must stay empty for
  src/repro/serving and src/repro/kernels — hot-path findings get fixed
  or pragma'd with a reason, never baselined.
"""


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro's static-analysis suite: host-sync sanitizer, "
                    "retrace lint, async-span lifecycle checker, "
                    "counter-name checker (stdlib ast only).",
        epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to scan (default: src/repro)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default="analysis-baseline.json",
                    help="committed fingerprint file (missing = empty)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings into the baseline file")
    ap.add_argument("--passes", default=None,
                    help="comma-separated subset of passes to run")
    ap.add_argument("--list-passes", action="store_true")
    args = ap.parse_args(argv)

    registry = all_passes()
    if args.list_passes:
        for name, cls in sorted(registry.items()):
            print(f"{name:10s} {cls.description}")
        return 0

    if args.passes:
        unknown = [p for p in args.passes.split(",")
                   if p not in registry]
        if unknown:
            ap.error(f"unknown pass(es): {', '.join(unknown)} "
                     f"(known: {', '.join(sorted(registry))})")
        classes = [registry[p] for p in args.passes.split(",")]
    else:
        classes = list(registry.values())

    modules = [Module.load(p, rel)
               for p, rel in iter_python_files(args.paths)]
    findings = run_passes(modules, classes)

    baseline_path = Path(args.baseline)
    if args.write_baseline:
        save_baseline(baseline_path, findings)
        print(f"[analysis] wrote {len(findings)} fingerprint(s) to "
              f"{baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, old = partition_baseline(findings, baseline)

    if args.format == "json":
        print(json.dumps({
            "scanned_files": len(modules),
            "passes": [c.name for c in classes],
            "new": [f.to_json() for f in new],
            "baselined": [f.to_json() for f in old],
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        tail = (f"[analysis] {len(modules)} files, "
                f"{len(new)} new finding(s), {len(old)} baselined")
        print(tail, file=sys.stderr if new else sys.stdout)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
