"""Static analysis of lowered HLO: bytes/FLOPs accounting and roofline."""
