"""Static analysis: HLO bytes/FLOPs accounting + roofline (``hlo``,
``roofline``) and the stdlib-ast lint suite gating CI
(``python -m repro.analysis`` — see ``lint`` for the framework and
``hostsync``/``retrace``/``spans``/``counters`` for the passes)."""

from .lint import (Finding, LintPass, Module, all_passes, load_baseline,
                   partition_baseline, run_passes, run_paths, save_baseline)

__all__ = [
    "Finding", "LintPass", "Module", "all_passes", "load_baseline",
    "partition_baseline", "run_passes", "run_paths", "save_baseline",
]
