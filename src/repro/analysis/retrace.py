"""Retrace lint (pass ``retrace``): jit surfaces must be shared and
hashable.

PR 2 established the shared-jit convention (one module-level jitted
callable per step shape, reused across workers) and PR 3 made specs
hashable precisely so they can key jit entries (``static_argnames``).
Violations recompile per instance or retrace per call — the classic
silent 100x serving slowdown.  Checks, over every scanned module:

  RET001  jax.jit created inside a function or class body (instance- or
          call-scoped jit: each construction compiles its own cache)
  RET002  static_argnames entry that is not a parameter of the jitted
          function (jax raises only when the arg is actually passed)
  RET003  static parameter annotated with an unhashable type
          (list/dict/set/ndarray/Array cannot key a jit cache)
  RET004  jax.jit(lambda ...): unnameable, unshareable jit entry

Intentional exceptions (one-shot launchers whose shardings depend on a
runtime mesh) carry ``# lint: retrace(reason)``.
"""
from __future__ import annotations

import ast
from typing import Iterable

from .lint import Finding, LintPass, Module, dotted_name, register

_UNHASHABLE = {"list", "dict", "set", "bytearray", "ndarray", "Array",
               "DeviceArray"}


def _jit_refs(mod: Module) -> list[ast.AST]:
    """Every Name/Attribute node referring to jax.jit (``jax.jit`` always;
    bare ``jit`` only when imported from jax)."""
    bare_jit = any(
        isinstance(n, ast.ImportFrom) and n.module == "jax"
        and any(a.name == "jit" for a in n.names)
        for n in ast.walk(mod.tree))
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Attribute) and dotted_name(node) == "jax.jit":
            out.append(node)
        elif bare_jit and isinstance(node, ast.Name) and node.id == "jit" \
                and isinstance(node.ctx, ast.Load):
            out.append(node)
    return out


def _scope(node: ast.AST) -> ast.AST | None:
    """Nearest function/class body enclosing ``node`` — decorator position
    does NOT count as inside the decorated def (decorator linenos precede
    the def's lineno)."""
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            if node.lineno >= cur.lineno:       # not one of its decorators
                return cur
        cur = getattr(cur, "parent", None)
    return None


def _options_call(node: ast.AST) -> ast.Call | None:
    """The Call carrying jit options for this jit reference:
    ``jax.jit(f, static_argnames=...)`` (node is func) or
    ``functools.partial(jax.jit, static_argnames=...)`` (node is arg)."""
    parent = getattr(node, "parent", None)
    if not isinstance(parent, ast.Call):
        return None
    if parent.func is node:
        return parent
    pf = dotted_name(parent.func)
    if node in parent.args and pf in ("functools.partial", "partial"):
        return parent
    return None


def _decorated_def(node: ast.AST) -> ast.FunctionDef | None:
    """The function whose decorator list this jit reference sits in."""
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.lineno < cur.lineno:
            return cur
        cur = getattr(cur, "parent", None)
    return None


def _static_names(call: ast.Call) -> list[tuple[str, ast.AST]]:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            if kw.arg == "static_argnums":
                return []                       # positional: not checkable
            v = kw.value
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            return [(e.value, e) for e in elts
                    if isinstance(e, ast.Constant) and isinstance(e.value,
                                                                  str)]
    return []


def _params(fn: ast.FunctionDef) -> dict[str, ast.arg]:
    a = fn.args
    out = {}
    for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
        out[arg.arg] = arg
    return out


def _unhashable_annotation(arg: ast.arg) -> str | None:
    ann = arg.annotation
    if ann is None:
        return None
    if isinstance(ann, ast.Subscript):          # list[int], dict[str, int]
        ann = ann.value
    name = dotted_name(ann)
    if name and name.split(".")[-1] in _UNHASHABLE:
        return name
    return None


@register
class RetracePass(LintPass):
    name = "retrace"
    description = ("jit at module scope only, static_argnames entries must "
                   "be hashable-typed parameters of the jitted callable")

    def check_module(self, mod: Module) -> Iterable[Finding]:
        module_defs = {
            n.name: n for n in ast.iter_child_nodes(mod.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}

        for ref in _jit_refs(mod):
            scope = _scope(ref)
            if scope is not None:
                kind = ("class" if isinstance(scope, ast.ClassDef)
                        else "function")
                yield Finding(
                    mod.relpath, ref.lineno, "RET001", self.name,
                    f"jax.jit created inside {kind} {scope.name}: jitted "
                    f"callables must live at module scope so every caller "
                    f"shares one compile cache")

            call = _options_call(ref)
            target: ast.FunctionDef | None = _decorated_def(ref)
            if call is not None and call.func is ref and call.args:
                first = call.args[0]
                if isinstance(first, ast.Lambda):
                    yield Finding(
                        mod.relpath, first.lineno, "RET004", self.name,
                        "jax.jit(lambda ...): unnameable jit entry — "
                        "define and jit a module-level function")
                    target = None
                elif isinstance(first, ast.Name):
                    target = module_defs.get(first.id, target)

            if call is None or target is None:
                continue
            params = _params(target)
            for sname, snode in _static_names(call):
                if sname not in params:
                    yield Finding(
                        mod.relpath, snode.lineno, "RET002", self.name,
                        f"static_argnames entry {sname!r} is not a "
                        f"parameter of {target.name}() "
                        f"(has: {', '.join(params) or 'none'})")
                else:
                    bad = _unhashable_annotation(params[sname])
                    if bad:
                        yield Finding(
                            mod.relpath, params[sname].lineno, "RET003",
                            self.name,
                            f"static parameter {sname!r} of {target.name}()"
                            f" is annotated {bad}, which is unhashable and "
                            f"cannot key a jit cache")
