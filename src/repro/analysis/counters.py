"""Counter-name checker (pass ``counter``): stringly-typed metric and
summary keys must resolve to a registration site.

The streaming registry (``obs.stats.Registry``) is get-or-create: a typo'd
``histogram("itl_z")`` silently creates an empty metric and every read off
it is zero.  Summary dicts have the same failure mode — ``s.get("typo",
0)`` reads 0 forever.  This pass cross-references every literal-keyed read
against the registration surfaces that actually feed data:

  registrations
    * str keys of dict literals / ``d[k] =`` stores / ``.update(...)``
      kwargs inside summary-producing functions (``summary``,
      ``_summary``, ``snapshot``)
    * str keys of dict literals assigned to a ``.counters`` attribute
      (the per-worker counter dicts merged into engine summaries)
    * ``.counter("x")`` / ``.gauge("x")`` / ``.histogram("x")`` lookups
      immediately written through (``.inc()``/``.set()``/``.observe()``)
      — the ingestion side of the get-or-create registry
    * ``.admission("reason")`` calls (reasons surface as summary keys)

  usages (each must resolve)
    * literal subscript reads / ``.get("k")`` on summary-typed locals
      (assigned from ``.summary()``/``.snapshot()``/``.run()`` or params
      named ``s``/``summary``/``snap``/``snapshot``/``counters``)
    * literal subscript reads and ``+=`` updates on ``.counters`` dicts
    * registry ``.counter/.gauge/.histogram`` name lookups NOT written
      through (e.g. overload.py reading the itl_s histogram's window)

  CTR001  literal key read with no registration site

Dynamically-computed keys are out of scope (skipped, not guessed).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable

from .lint import (Finding, LintPass, Module, dotted_name,
                   enclosing_function, register)

#: modules whose stringly-typed metric/summary keys are audited
_AUDIT_MARKERS = ("/serving/",)
_AUDIT_SUFFIXES = ("obs/export.py", "launch/serve.py")

_SUMMARY_FN_NAMES = ("summary", "_summary", "snapshot")
_SUMMARY_PRODUCERS = ("summary", "_summary", "snapshot", "run")
_SUMMARY_PARAM_NAMES = ("s", "summary", "snap", "snapshot", "counters")
_REGISTRY_CALLS = ("counter", "gauge", "histogram")
#: a registry lookup immediately chained into one of these is ingestion
_WRITE_METHODS = ("inc", "set", "observe", "add")


def is_audited(relpath: str) -> bool:
    return any(m in relpath for m in _AUDIT_MARKERS) \
        or relpath.endswith(_AUDIT_SUFFIXES)


@dataclasses.dataclass
class _Use:
    key: str
    relpath: str
    line: int
    what: str


def _str_const(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _is_counters_attr(node: ast.AST) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == "counters"


class _Scope:
    """Summary-typed local names of one function (flow-insensitive)."""

    def __init__(self, fn: ast.AST | None, tree: ast.AST):
        self.names: set[str] = set()
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = fn.args
            for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
                if arg.arg in _SUMMARY_PARAM_NAMES:
                    self.names.add(arg.arg)
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call) \
                    and isinstance(node.value.func, ast.Attribute) \
                    and node.value.func.attr in _SUMMARY_PRODUCERS:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.names.add(tgt.id)


@register
class CounterNamePass(LintPass):
    name = "counter"
    description = ("stringly-typed counter/gauge/histogram/summary keys "
                   "must resolve to a registration site (a typo'd name "
                   "silently reads zero)")

    def __init__(self) -> None:
        self._registered: set[str] = set()
        self._used: list[_Use] = []

    # ------------------------------------------------------------ collect

    def check_module(self, mod: Module) -> Iterable[Finding]:
        if not is_audited(mod.relpath):
            return ()
        scopes: dict[int, _Scope] = {}
        funcs = [n for n in ast.walk(mod.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for fn in funcs:
            scopes[id(fn)] = _Scope(fn, fn)
        module_scope = _Scope(None, mod.tree)

        def scope_of(node: ast.AST) -> _Scope:
            fn = enclosing_function(node)
            return scopes[id(fn)] if fn is not None else module_scope

        def in_summary_fn(node: ast.AST) -> bool:
            fn = enclosing_function(node)
            return fn is not None and fn.name in _SUMMARY_FN_NAMES

        def is_summary_dict(value: ast.AST, node: ast.AST) -> bool:
            if _is_counters_attr(value):
                return True
            return isinstance(value, ast.Name) \
                and value.id in scope_of(node).names

        for node in ast.walk(mod.tree):
            # ---- registrations
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Dict) \
                    and any(_is_counters_attr(t) for t in node.targets):
                for k in node.value.keys:
                    key = _str_const(k) if k is not None else None
                    if key:
                        self._registered.add(key)
            if isinstance(node, ast.Dict) and in_summary_fn(node):
                for k in node.keys:
                    key = _str_const(k) if k is not None else None
                    if key:
                        self._registered.add(key)
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                attr = node.func.attr
                arg0 = _str_const(node.args[0]) if node.args else None
                if attr in _REGISTRY_CALLS and arg0:
                    parent = getattr(node, "parent", None)
                    written = (isinstance(parent, ast.Attribute)
                               and parent.attr in _WRITE_METHODS)
                    if written:
                        self._registered.add(arg0)
                    else:
                        self._used.append(_Use(
                            arg0, mod.relpath, node.lineno,
                            f"registry .{attr}() lookup"))
                elif attr == "admission" and arg0:
                    self._registered.add(arg0)
                elif attr in ("update", "setdefault") \
                        and (in_summary_fn(node)
                             or is_summary_dict(node.func.value, node)):
                    for kw in node.keywords:
                        if kw.arg:
                            self._registered.add(kw.arg)
                    if attr == "update" and node.args \
                            and isinstance(node.args[0], ast.Dict):
                        for k in node.args[0].keys:
                            key = _str_const(k) if k is not None else None
                            if key:
                                self._registered.add(key)
                    if attr == "setdefault" and arg0:
                        self._registered.add(arg0)
                elif attr == "get" and node.args \
                        and is_summary_dict(node.func.value, node):
                    key = _str_const(node.args[0])
                    if key:
                        self._used.append(_Use(
                            key, mod.relpath, node.lineno, ".get() read"))
            # ---- subscripts on summary/counters dicts (any literal-key
            # store inside a summary-producing function registers, even on
            # a dict built locally from a literal)
            if isinstance(node, ast.Subscript):
                summaryish = is_summary_dict(node.value, node)
                key = _str_const(node.slice)
                if key is None:
                    continue
                parent = getattr(node, "parent", None)
                if isinstance(node.ctx, ast.Store) \
                        and not isinstance(parent, ast.AugAssign):
                    if summaryish or in_summary_fn(node):
                        self._registered.add(key)
                elif summaryish:
                    self._used.append(_Use(
                        key, mod.relpath, node.lineno, "subscript read"))
        return ()

    # ------------------------------------------------------------ resolve

    def finish(self) -> Iterable[Finding]:
        for use in self._used:
            if use.key not in self._registered:
                yield Finding(
                    use.relpath, use.line, "CTR001", self.name,
                    f"metric/summary key {use.key!r} ({use.what}) has no "
                    f"registration site — a typo'd name silently reads "
                    f"zero")
