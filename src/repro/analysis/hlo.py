"""HLO-text analyzer: per-device FLOPs, HBM-traffic proxy, collective bytes.

Why not just compiled.cost_analysis()? Verified on this jax/xla build:
HloCostAnalysis counts while-loop (lax.scan) bodies ONCE, so an 80-layer
model scanned over 10 groups under-counts by 10x. We therefore parse the
optimized HLO: extract every computation, find while-loop trip counts from
their condition's compare-against-constant, propagate multipliers through
the call graph (while bodies, fusions, calls), and sum:

  - dot FLOPs: 2 * prod(out_shape) * prod(lhs contracting dims)
  - collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute
  - HBM traffic proxy: operand+result bytes of top-level fusion/dot/
    collective/copy ops (fusion internals stay in registers/VMEM)

All shapes in the optimized module are post-SPMD-partition = per-device.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([a-z\-]+)\(")
# header: `%name (params...) -> type {` - params nest parens, so match only
# the leading name token at column 0
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    line: str


def parse_hlo(text: str):
    """-> {comp_name: [Op]}, plus per-comp metadata."""
    comps: dict[str, list[Op]] = {}
    cur = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):   # computation header
            m = _COMP_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            name, type_str, kind = m.groups()
            comps[cur].append(Op(name, kind, type_str, line.strip()))
    return comps


def _trip_count(while_line: str, cond_ops: list[Op]) -> int:
    """Trip count: XLA records it in backend_config known_trip_count; fall
    back to the condition's compare-against-constant."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', while_line)
    if m:
        return max(int(m.group(1)), 1)
    const = None
    for op in cond_ops:
        if op.kind == "constant":
            cm = re.search(r"constant\((-?\d+)\)", op.line)
            if cm:
                const = int(cm.group(1))
    return max(const, 1) if const is not None else 1


def _dot_flops(op: Op, symtab: dict[str, str]) -> int:
    """2 * prod(out) * prod(contracting dims of lhs)."""
    out_dt, out_dims = _shape_elems(op.type_str)
    m = re.search(r"\(([^)]*)\)", op.line[op.line.index(op.kind):])
    if not m:
        return 0
    args = [a.strip().lstrip("%") for a in m.group(1).split(",")]
    lhs_type = symtab.get(args[0]) if args else None
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    flops = 2
    for d in out_dims:
        flops *= d
    if lhs_type and cm and cm.group(1):
        _, lhs_dims = _shape_elems(lhs_type)
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                flops *= lhs_dims[i]
    return flops


def _operand_shapes(op: Op, symtab: dict[str, str]) -> list[str]:
    m = re.search(r"\(([^)]*)\)", op.line[op.line.index(op.kind):])
    if not m:
        return []
    out = []
    for a in m.group(1).split(","):
        a = a.strip().lstrip("%")
        if a in symtab:
            out.append(symtab[a])
    return out


def _operand_bytes(op: Op, symtab: dict[str, str]) -> int:
    return sum(_shape_bytes(t) for t in _operand_shapes(op, symtab))


def analyze(text: str) -> dict:
    """Whole-module analysis with while-loop multipliers.

    Returns dict(flops, collective_bytes, hbm_bytes, per_collective,
    while_trips).
    """
    comps = parse_hlo(text)
    symtab_per_comp = {c: {op.name: op.type_str for op in ops}
                       for c, ops in comps.items()}

    # map: computation -> multiplier (entry = 1), resolved via worklist
    mult: dict[str, float] = defaultdict(float)
    entry = None
    for c in comps:
        if c.endswith("main") or entry is None and "main" in c:
            entry = c
    if entry is None:
        entry = next(iter(comps))
    mult[entry] = 1.0

    # discover call edges: while(body=%b, condition=%c), fusion calls=%f,
    # call to=%t / calls=%t, conditional branches
    edge_re = re.compile(r"(?:body|condition|calls|to_apply|branch_computations)"
                         r"=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    trips: dict[str, int] = {}
    for c, ops in comps.items():
        for op in ops:
            if op.kind == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                if bm and cm:
                    t = _trip_count(op.line, comps.get(cm.group(1), []))
                    trips[bm.group(1)] = t
                    edges[c].append((bm.group(1), float(t)))
                    edges[c].append((cm.group(1), float(t)))
            else:
                for m in edge_re.finditer(op.line):
                    for t in [x.strip().lstrip("%") for x in m.group(1).split(",")]:
                        if t in comps:
                            edges[c].append((t, 1.0))

    # propagate multipliers (call graph is a DAG)
    changed = True
    iters = 0
    while changed and iters < 100:
        changed = False
        iters += 1
        for src, outs in list(edges.items()):
            for dst, k in outs:
                nm = mult[src] * k
                if nm > mult[dst] + 1e-9:
                    mult[dst] = nm
                    changed = True

    flops = 0.0
    coll_bytes = 0.0
    hbm = 0.0
    per_coll = defaultdict(float)
    for c, ops in comps.items():
        m = mult.get(c, 0.0)
        if m == 0.0:
            continue
        st = symtab_per_comp[c]
        for op in ops:
            if op.kind == "dot":
                flops += m * _dot_flops(op, st)
            elif op.kind in ("convolution",):
                # rare here; approximate: 2 * out elems * (bytes heuristic)
                flops += m * 2 * _shape_bytes(op.type_str)
            if any(op.kind.startswith(k) for k in COLLECTIVES):
                b = _operand_bytes(op, st)
                coll_bytes += m * b
                per_coll[op.kind] += m * b
            if op.kind == "dynamic-slice":
                # reads only the slice (result-sized), writes the result
                hbm += m * 2 * _shape_bytes(op.type_str)
            elif op.kind in ("dynamic-update-slice", "scatter", "gather"):
                # touches the update region, not the whole buffer (in-place)
                upd = _operand_shapes(op, st)
                region = min((_shape_bytes(u) for u in upd[1:]),
                             default=_shape_bytes(op.type_str))
                hbm += m * 2 * region
            elif op.kind == "fusion" and "dynamic-update-slice" in op.name:
                # in-place update fusion: result aliases the big operand;
                # traffic ~ the small operands (update + indices), twice
                sizes = [_shape_bytes(t) for t in _operand_shapes(op, st)]
                hbm += m * 2 * (sum(sizes) - (max(sizes) if sizes else 0))
            elif op.kind in ("fusion", "dot", "copy", "convolution",
                             "custom-call") or \
                    any(op.kind.startswith(k) for k in COLLECTIVES):
                hbm += m * (_operand_bytes(op, st) + _shape_bytes(op.type_str))
    return {
        "flops": flops,
        "collective_bytes": coll_bytes,
        "hbm_bytes": hbm,
        "per_collective": dict(per_coll),
        "while_trips": trips,
        "n_computations": len(comps),
    }
