"""Framework for repro's stdlib-``ast`` lint passes.

The serving stack rests on conventions that used to be enforced only at
runtime or by reviewer memory: no host syncs inside the decode hot loop,
module-level jit keyed on hashable specs, every async freeze/offload span
reaching exactly one terminal state, and stringly-typed counter names
resolving to a registration site.  The passes in this package turn those
conventions into machine-checked findings; this module provides the shared
machinery:

  Module      parsed source file (AST + parent links + pragma map)
  Finding     one diagnostic, with a line-independent fingerprint
  LintPass    base class; ``register`` adds subclasses to the registry
  run_passes  drive the selected passes over a file set, apply pragma
              suppression, and emit pragma-hygiene findings
  Baseline    committed fingerprint set; only findings NOT in it gate CI

Pragma syntax (suppression must carry a reason)::

    nxt = np.asarray(argmax)  # lint: sync(intentional step-end sync)

A pragma on line L suppresses that pass's findings anchored at L or L+1
(so it can sit on its own line above a long statement).  Multiple
pragmas separate with commas: ``# lint: sync(reason), retrace(reason)``.
Pragmas with an empty reason, an unknown pass name, or that suppress
nothing are themselves findings (LINT001/LINT002/LINT003).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Iterable, Iterator

# --------------------------------------------------------------- findings


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic. The fingerprint deliberately excludes the line
    number so committed baselines don't churn when unrelated edits move
    code; ``message`` must therefore be stable (name things, not lines)."""

    path: str          # posix path as scanned (repo-relative in CI)
    line: int
    code: str          # e.g. "SYNC001"
    pass_name: str     # registry name of the emitting pass
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.path}:{self.code}:{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} [{self.pass_name}] " \
               f"{self.message}"

    def to_json(self) -> dict:
        return {"path": self.path, "line": self.line, "code": self.code,
                "pass": self.pass_name, "message": self.message}


# --------------------------------------------------------------- pragmas

_PRAGMA_RE = re.compile(r"#\s*lint:\s*(?P<body>.+)$")
_PRAGMA_ITEM_RE = re.compile(r"(?P<name>[A-Za-z_][A-Za-z0-9_]*)"
                             r"\((?P<reason>[^()]*)\)")


@dataclasses.dataclass
class Pragma:
    line: int
    pass_name: str
    reason: str
    used: bool = False


def parse_pragmas(source: str) -> list[Pragma]:
    """Pragmas from real COMMENT tokens only — pragma examples quoted in
    docstrings don't count (tokenize, not line-regex)."""
    out: list[Pragma] = []
    toks = tokenize.generate_tokens(io.StringIO(source).readline)
    for tok in toks:
        if tok.type != tokenize.COMMENT:
            continue
        m = _PRAGMA_RE.search(tok.string)
        if not m:
            continue
        for item in _PRAGMA_ITEM_RE.finditer(m.group("body")):
            out.append(Pragma(tok.start[0], item.group("name"),
                              item.group("reason").strip()))
    return out


# --------------------------------------------------------------- modules


class Module:
    """One parsed source file handed to every pass.

    ``relpath`` is the path as given on the command line (posix-ified) —
    fingerprints embed it, so scans must address files consistently
    (CI and the self-check test both scan ``src/repro`` from the repo
    root).  Every AST node gets a ``parent`` link before passes run.
    """

    def __init__(self, path: Path, relpath: str, source: str):
        self.path = path
        self.relpath = relpath
        self.source = source
        self.tree = ast.parse(source, filename=relpath)
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child.parent = node  # type: ignore[attr-defined]
        self.pragmas = parse_pragmas(source)
        self._by_line: dict[tuple[int, str], Pragma] = {
            (p.line, p.pass_name): p for p in self.pragmas}

    @classmethod
    def load(cls, path: Path, relpath: str | None = None) -> "Module":
        rel = relpath if relpath is not None else path.as_posix()
        return cls(path, rel, path.read_text())

    def suppressing_pragma(self, pass_name: str, line: int) -> Pragma | None:
        """The pragma (if any) covering a finding of ``pass_name`` at
        ``line``: same line, or the line directly above."""
        for ln in (line, line - 1):
            p = self._by_line.get((ln, pass_name))
            if p is not None:
                return p
        return None


def enclosing_function(node: ast.AST) -> ast.AST | None:
    """Nearest FunctionDef/AsyncFunctionDef ancestor, if any."""
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = getattr(cur, "parent", None)
    return None


def dotted_name(node: ast.AST) -> str | None:
    """'jax.jit' for Attribute chains / Names; None for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ----------------------------------------------------------- pass registry


class LintPass:
    """Base class. ``check_module`` runs once per file and may emit
    findings immediately; passes needing whole-program context collect in
    ``check_module`` and emit from ``finish`` (called once, after every
    module)."""

    name = ""
    description = ""

    def check_module(self, mod: Module) -> Iterable[Finding]:
        return ()

    def finish(self) -> Iterable[Finding]:
        return ()


PASSES: dict[str, type[LintPass]] = {}


def register(cls: type[LintPass]) -> type[LintPass]:
    assert cls.name and cls.name not in PASSES, cls
    PASSES[cls.name] = cls
    return cls


def all_passes() -> dict[str, type[LintPass]]:
    # import side effect registers the bundled passes exactly once
    from . import counters, hostsync, retrace, spans  # noqa: F401
    return PASSES


# ----------------------------------------------------------------- runner


def iter_python_files(paths: Iterable[str]) -> Iterator[tuple[Path, str]]:
    """(path, relpath) for every .py under ``paths``, deterministic order.

    ``relpath`` keeps the spelling given on the command line so baseline
    fingerprints are stable across machines (CI passes ``src/repro``)."""
    for raw in paths:
        p = Path(raw)
        if p.is_file():
            yield p, p.as_posix()
        else:
            for f in sorted(p.rglob("*.py")):
                yield f, f.as_posix()


def run_passes(modules: list[Module],
               passes: Iterable[type[LintPass]] | None = None,
               ) -> list[Finding]:
    """Run passes over the modules; returns pragma-filtered findings plus
    pragma-hygiene findings, sorted by (path, line, code)."""
    classes = list(passes) if passes is not None \
        else list(all_passes().values())
    raw: list[Finding] = []
    for cls in classes:
        inst = cls()
        for mod in modules:
            raw.extend(inst.check_module(mod))
        raw.extend(inst.finish())

    by_rel = {m.relpath: m for m in modules}
    kept: list[Finding] = []
    for f in raw:
        mod = by_rel.get(f.path)
        pragma = mod.suppressing_pragma(f.pass_name, f.line) if mod else None
        if pragma is None:
            kept.append(f)
        else:
            pragma.used = True

    known = {cls.name for cls in classes}
    for mod in modules:
        for p in mod.pragmas:
            if p.pass_name not in known:
                kept.append(Finding(
                    mod.relpath, p.line, "LINT002", "pragma",
                    f"pragma names unknown pass {p.pass_name!r} "
                    f"(known: {', '.join(sorted(known))})"))
            elif not p.reason:
                kept.append(Finding(
                    mod.relpath, p.line, "LINT001", "pragma",
                    f"pragma {p.pass_name!r} must carry a reason: "
                    f"# lint: {p.pass_name}(why this is safe)"))
            elif not p.used:
                kept.append(Finding(
                    mod.relpath, p.line, "LINT003", "pragma",
                    f"unused pragma {p.pass_name!r} at line {p.line} "
                    f"suppresses nothing — delete it"))
    return sorted(kept, key=lambda f: (f.path, f.line, f.code, f.message))


def run_paths(paths: Iterable[str],
              passes: Iterable[type[LintPass]] | None = None,
              ) -> list[Finding]:
    modules = [Module.load(p, rel) for p, rel in iter_python_files(paths)]
    return run_passes(modules, passes)


# --------------------------------------------------------------- baseline


def load_baseline(path: Path | None) -> set[str]:
    """Committed fingerprint set; a missing file is an empty baseline."""
    if path is None or not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("fingerprints", []))


def save_baseline(path: Path, findings: Iterable[Finding]) -> None:
    fps = sorted({f.fingerprint for f in findings})
    path.write_text(json.dumps(
        {"version": 1,
         "comment": "accepted repro.analysis findings; regenerate with "
                    "`python -m repro.analysis <paths> --write-baseline`. "
                    "Must stay empty for src/repro/serving and "
                    "src/repro/kernels.",
         "fingerprints": fps}, indent=2) + "\n")


def partition_baseline(findings: Iterable[Finding], baseline: set[str],
                       ) -> tuple[list[Finding], list[Finding]]:
    """(new, baselined) — only ``new`` findings gate."""
    new: list[Finding] = []
    old: list[Finding] = []
    for f in findings:
        (old if f.fingerprint in baseline else new).append(f)
    return new, old
