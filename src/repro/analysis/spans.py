"""Async-span lifecycle checker (pass ``span``): every async trace span
must be able to reach exactly the declared terminal states.

The freeze/offload lifecycles are real state machines — a page freeze ends
``installed``, ``dropped``, ``rolled_back`` or ``offloaded``; an offload
ends ``restored`` — and the runtime reconciler (``_trace_reconcile``)
verifies counts only on traced runs.  This pass is the static complement:
it collects every ``async_begin``/``async_end`` call site and checks the
call graph *can* produce exactly the declared terminal-state set.

  SPAN001  terminal states at async_end sites differ from the declared
           machine (a missing state means a lifecycle that can never
           close that way; an undeclared state is a typo the runtime
           reconciler would count into nothing)
  SPAN002  async_end for a declared machine without a literal ``state=``
           (undeclared span names — plain spans like "prefill" — are
           exempt)
  SPAN003  async_begin with no async_end call site anywhere
  SPAN004  async_end with no async_begin call site anywhere

Only string-literal span names participate; dynamically-named spans are
invisible to static checking and intentionally out of scope.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, Mapping

from .lint import Finding, LintPass, Module, register

#: declared lifecycles: span name -> exact set of terminal states its
#: async_end sites must cover (workers.py's freeze/offload machines:
#: page_freeze queued→dispatched→installed|dropped|rolled_back|offloaded,
#: page_offload →restored)
MACHINES: dict[str, frozenset[str]] = {
    "page_freeze": frozenset(
        {"installed", "dropped", "rolled_back", "offloaded"}),
    "page_offload": frozenset({"restored"}),
}


@dataclasses.dataclass
class _Site:
    relpath: str
    line: int
    state: str | None          # literal state= value, if any
    has_state: bool            # a state= kwarg exists (literal or not)
    state_literal: bool


def _span_name(call: ast.Call) -> str | None:
    """async_begin(track, name, ...) / async_end(track, name, ...)."""
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant) \
            and isinstance(call.args[1].value, str):
        return call.args[1].value
    return None


@register
class SpanLifecyclePass(LintPass):
    name = "span"
    description = ("async_begin/async_end sites must realize exactly the "
                   "declared page_freeze/page_offload terminal states")

    def __init__(self, machines: Mapping[str, frozenset[str]] | None = None):
        self.machines = dict(MACHINES if machines is None else machines)
        self._begins: dict[str, list[_Site]] = {}
        self._ends: dict[str, list[_Site]] = {}

    def check_module(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("async_begin", "async_end")):
                continue
            name = _span_name(node)
            if name is None:
                continue
            state, has_state, literal = None, False, False
            for kw in node.keywords:
                if kw.arg == "state":
                    has_state = True
                    if isinstance(kw.value, ast.Constant) \
                            and isinstance(kw.value.value, str):
                        state, literal = kw.value.value, True
            site = _Site(mod.relpath, node.lineno, state, has_state, literal)
            bucket = (self._begins if node.func.attr == "async_begin"
                      else self._ends)
            bucket.setdefault(name, []).append(site)
        return ()

    def finish(self) -> Iterable[Finding]:
        for name, sites in sorted(self._begins.items()):
            if name not in self._ends:
                s = sites[0]
                yield Finding(
                    s.relpath, s.line, "SPAN003", self.name,
                    f"async_begin({name!r}) has no async_end call site "
                    f"anywhere — the span can never close")
        for name, sites in sorted(self._ends.items()):
            if name not in self._begins:
                s = sites[0]
                yield Finding(
                    s.relpath, s.line, "SPAN004", self.name,
                    f"async_end({name!r}) has no async_begin call site "
                    f"anywhere")

        for name, declared in sorted(self.machines.items()):
            begins = self._begins.get(name, [])
            ends = self._ends.get(name, [])
            if not begins and not ends:
                continue
            realized: set[str] = set()
            for s in ends:
                if not s.has_state or (s.has_state and not s.state_literal):
                    yield Finding(
                        s.relpath, s.line, "SPAN002", self.name,
                        f"async_end({name!r}) must carry a literal state= "
                        f"naming one of the declared terminal states "
                        f"({', '.join(sorted(declared))})")
                    continue
                realized.add(s.state)  # type: ignore[arg-type]
                if s.state not in declared:
                    yield Finding(
                        s.relpath, s.line, "SPAN001", self.name,
                        f"async_end({name!r}) closes with undeclared state "
                        f"{s.state!r}; declared terminal states: "
                        f"{', '.join(sorted(declared))}")
            missing = declared - realized
            if missing and begins:
                s = begins[0]
                yield Finding(
                    s.relpath, s.line, "SPAN001", self.name,
                    f"span {name!r} never reaches declared terminal "
                    f"state(s) {', '.join(sorted(missing))}: no async_end "
                    f"site closes with them")
