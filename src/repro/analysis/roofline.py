"""Three-term roofline model for TPU v5e (target hardware; CPU is runtime).

  compute    = FLOPs / (peak bf16 FLOP/s)        per chip
  memory     = HBM bytes / HBM bandwidth         per chip
  collective = collective bytes / ICI link bw    per chip

All inputs are PER-DEVICE (post-SPMD HLO). The dominant term is the
bottleneck; the roofline fraction reported in EXPERIMENTS.md §Perf is
compute / max(all terms) for train/prefill and the dominant-term utilization
story for decode (memory-bound by construction).
"""
from __future__ import annotations

import dataclasses

import numpy as np

PEAK_FLOPS_BF16 = 197e12      # per v5e chip
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~ per-chip effective)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops_per_device: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """No-overlap pessimum is the sum; perfect overlap is the max. We
        report the max (roofline = best achievable)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step the MXUs are busy at the bound = how close
        the cell is to compute-roofline if perfectly overlapped."""
        t = self.step_time_lower_bound
        return 0.0 if t == 0 else self.t_compute / t

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: catches remat/redundant compute."""
        return (self.model_flops_per_device / self.flops) if self.flops else 0.0

    def as_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "roofline_fraction": self.roofline_fraction,
            "useful_flops_ratio": self.useful_flops_ratio,
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "model_flops_per_device": self.model_flops_per_device,
        }


# --------------------------------------------------- analytic model FLOPs


def model_params_active(cfg) -> tuple[int, int]:
    """(total params, active params per token) - MoE-aware, analytic."""
    D, V = cfg.d_model, cfg.vocab
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    total = emb
    active = emb

    def attn_p():
        qo = D * cfg.n_heads * cfg.head_dim * 2
        kv = D * cfg.n_kv_heads * cfg.head_dim * 2
        return qo + kv

    def mla_p():
        return (D * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                + D * cfg.kv_lora_rank + D * cfg.qk_rope_dim
                + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                + cfg.n_heads * cfg.v_head_dim * D)

    def mamba_p():
        E, N, R = cfg.d_inner, cfg.ssm_d_state, cfg.dt_rank
        return D * 2 * E + E * (2 * N + R) + R * E + E * N + E * D

    def rwkv_p():
        return 6 * D * D + D * cfg.d_ff * 2 + D * 64 * 2

    def specs():
        out = list(cfg.head_layers)
        out += list(cfg.group) * cfg.n_groups
        if cfg.family == "encdec":
            out += [dataclasses.replace(s, cross_attn=False)
                    for s in [cfg.group[0]] * cfg.n_enc_layers]
        return out

    for spec in specs():
        if spec.mixer == "attn":
            p = attn_p() * (2 if spec.cross_attn else 1)
        elif spec.mixer == "mla":
            p = mla_p()
        elif spec.mixer == "mamba":
            p = mamba_p()
        elif spec.mixer == "rwkv6":
            p = rwkv_p()
        total += p
        active += p
        if spec.ffn == "dense":
            f = 3 * D * cfg.d_ff
            total += f
            active += f
        elif spec.ffn == "moe":
            per_e = 3 * D * cfg.expert_ff
            total += per_e * cfg.n_experts
            active += per_e * cfg.top_k
            if cfg.n_shared_experts:
                sh = 3 * D * cfg.expert_ff * cfg.n_shared_experts
                total += sh
                active += sh
        elif spec.ffn == "cmix":
            pass  # counted in rwkv_p
    return int(total), int(active)


def model_flops(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """6*N_active*D tokens for training; 2*N_active per token for inference."""
    _, active = model_params_active(cfg)
    tokens = batch * (seq if shape_kind in ("train", "prefill") else 1)
    per_token = (6 if shape_kind == "train" else 2) * active
    return float(per_token) * tokens
