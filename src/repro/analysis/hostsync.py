"""Host-sync sanitizer (pass ``sync``): no device→host synchronization on
the decode/step hot path.

A single stray ``np.asarray``/``.item()``/``float()`` on a device value
inside the decode loop serializes the host against the device pipeline and
silently halves throughput — the exact failure mode PR 2's async freeze
path was built to avoid.  This pass audits the hot-path modules
(``serving/workers.py``, ``serving/speculative.py``,
``serving/kv_cache.py`` — including the ``PrefixIndex`` rolling-hash
publish/lookup that runs on every prefill dispatch and freeze install —
and ``kernels/paged_attention.py``), computes the set of functions
reachable from any ``step()`` entry point by name-based call graph, and
flags host-sync constructs inside them:

  SYNC001  jax.block_until_ready(...)            (always a sync)
  SYNC002  np.asarray / np.array on a device value
  SYNC003  .item() call                          (device scalar pull)
  SYNC004  .to_host() call                       (payload staging)
  SYNC005  float()/int() directly on a device value

"Device value" is a local taint: results of ``jnp.*``/``jax.*`` calls and
of callees named ``*_fn`` (the jitted-step convention), propagated through
subscripts/attributes/arithmetic/unpacking.  Host-only numpy code in the
same functions stays clean — ``np.asarray(sorted(ids))`` is not a sync.

Intentional syncs carry a pragma with the reason, e.g.::

    nxt = np.asarray(argmax)  # lint: sync(step-end token sync: the host
                              # scheduler needs the sampled ids)
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable

from .lint import Finding, LintPass, Module, dotted_name, register

#: path suffixes of the modules whose step-reachable functions are audited
HOT_SUFFIXES = (
    "serving/workers.py",
    "serving/speculative.py",
    "serving/kv_cache.py",
    "kernels/paged_attention.py",
    "kernels/quant_matmul.py",
)

#: function names treated as hot-path entry points
ROOT_NAMES = ("step",)

_NP_PREFIXES = ("np.", "numpy.")
_DEVICE_PREFIXES = ("jnp.", "jax.")


def is_hot_module(relpath: str) -> bool:
    return relpath.endswith(HOT_SUFFIXES)


@dataclasses.dataclass
class _Func:
    module: Module
    node: ast.FunctionDef | ast.AsyncFunctionDef
    qualname: str
    calls: set[str] = dataclasses.field(default_factory=set)


def _callee_name(call: ast.Call) -> str | None:
    """Bare name a call resolves through: ``f(...)`` -> f,
    ``self.f(...)``/``obj.f(...)`` -> f (name-based linking)."""
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _collect_functions(mod: Module) -> tuple[list[_Func], dict[str, str]]:
    """All function defs with qualnames + class name -> __init__ bare-name
    mapping (so ``Cls(...)`` links to its constructor)."""
    funcs: list[_Func] = []
    ctor_of: dict[str, str] = {}

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}{child.name}"
                f = _Func(mod, child, qn)
                for sub in ast.walk(child):
                    if isinstance(sub, ast.Call):
                        n = _callee_name(sub)
                        if n:
                            f.calls.add(n)
                funcs.append(f)
                visit(child, qn + ".")
            elif isinstance(child, ast.ClassDef):
                ctor_of[child.name] = "__init__"
                visit(child, f"{child.name}.")
            else:
                visit(child, prefix)

    visit(mod.tree, "")
    return funcs, ctor_of


def _device_call(node: ast.AST) -> bool:
    """Call whose result lives on device: jnp.*/jax.* or a ``*_fn``."""
    if not isinstance(node, ast.Call):
        return False
    dn = dotted_name(node.func)
    if dn and dn.startswith(_DEVICE_PREFIXES):
        return True
    callee = _callee_name(node)
    return bool(callee and callee.endswith("_fn"))


def _contains_device_call(node: ast.AST) -> bool:
    return any(_device_call(n) for n in ast.walk(node))


class _Taint:
    """Flow-insensitive local taint: two passes over the function body in
    source order reach a fixpoint for the loop-carried case."""

    def __init__(self, func: ast.AST):
        self.tainted: set[str] = set()
        for _ in range(2):
            before = len(self.tainted)
            self._scan(func)
            if len(self.tainted) == before:
                break

    def _scan(self, root: ast.AST) -> None:
        for node in ast.walk(root):
            if isinstance(node, ast.Assign) and self.expr(node.value):
                for tgt in node.targets:
                    self._taint_target(tgt)
            elif isinstance(node, ast.AugAssign) and (
                    self.expr(node.value) or self.expr(node.target)):
                self._taint_target(node.target)
            elif isinstance(node, ast.AnnAssign) and node.value is not None \
                    and self.expr(node.value):
                self._taint_target(node.target)

    def _taint_target(self, tgt: ast.AST) -> None:
        if isinstance(tgt, ast.Name):
            self.tainted.add(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for elt in tgt.elts:
                self._taint_target(elt)

    def expr(self, node: ast.AST) -> bool:
        """Does ``node`` evaluate to a (possibly) device value?"""
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Call):
            if _device_call(node):
                return True
            # method on a tainted value: x.astype(...), x.at[i].set(...)
            if isinstance(node.func, ast.Attribute):
                return self.expr(node.func.value)
            return False
        if isinstance(node, (ast.Attribute, ast.Subscript, ast.Starred)):
            return self.expr(node.value)
        if isinstance(node, ast.BinOp):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.Compare):
            return self.expr(node.left) or any(
                self.expr(c) for c in node.comparators)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.expr(node.body) or self.expr(node.orelse)
        return False


def _snippet(node: ast.AST) -> str:
    try:
        return ast.unparse(node)[:60]
    except Exception:  # pragma: no cover - unparse is total on parsed code
        return "<expr>"


@register
class HostSyncPass(LintPass):
    name = "sync"
    description = ("no host synchronization (np.asarray/.item()/float()/"
                   "block_until_ready/.to_host()) on device values in "
                   "functions reachable from step()")

    def __init__(self) -> None:
        self._funcs: list[_Func] = []
        self._ctors: dict[str, str] = {}

    def check_module(self, mod: Module) -> Iterable[Finding]:
        if is_hot_module(mod.relpath):
            funcs, ctors = _collect_functions(mod)
            self._funcs.extend(funcs)
            self._ctors.update(ctors)
        return ()

    # -- call-graph reachability over the audited modules ----------------

    def _reachable(self) -> list[_Func]:
        by_name: dict[str, list[_Func]] = {}
        for f in self._funcs:
            by_name.setdefault(f.node.name, []).append(f)
        work = [f for f in self._funcs if f.node.name in ROOT_NAMES]
        seen = {id(f.node): f for f in work}
        while work:
            cur = work.pop()
            for callee in cur.calls:
                if callee in self._ctors:
                    callee = "__init__"
                for nxt in by_name.get(callee, ()):
                    if id(nxt.node) not in seen:
                        seen[id(nxt.node)] = nxt
                        work.append(nxt)
        return list(seen.values())

    def finish(self) -> Iterable[Finding]:
        out: list[Finding] = []
        for f in self._reachable():
            out.extend(self._audit(f))
        return out

    # -- per-function site detection -------------------------------------

    def _audit(self, f: _Func) -> Iterable[Finding]:
        taint = _Taint(f.node)
        nested = {id(n) for sub in ast.iter_child_nodes(f.node)
                  for n in ast.walk(sub)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and n is not f.node}
        skip: set[int] = set()
        for n in ast.walk(f.node):
            if id(n) in nested:
                skip.update(id(s) for s in ast.walk(n))

        def finding(node: ast.AST, code: str, what: str) -> Finding:
            return Finding(
                f.module.relpath, node.lineno, code, self.name,
                f"{what} in hot function {f.qualname} "
                f"[{_snippet(node)}]")

        for node in ast.walk(f.node):
            if id(node) in skip or not isinstance(node, ast.Call):
                continue
            dn = dotted_name(node.func)
            if dn == "jax.block_until_ready" or (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "block_until_ready"):
                yield finding(node, "SYNC001", "block_until_ready")
                continue
            if dn and dn.startswith(_NP_PREFIXES) \
                    and dn.split(".", 1)[1] in ("asarray", "array"):
                if node.args and (taint.expr(node.args[0])
                                  or _contains_device_call(node.args[0])):
                    yield finding(node, "SYNC002",
                                  "np.asarray on device value")
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                yield finding(node, "SYNC003", ".item() device scalar pull")
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "to_host":
                yield finding(node, "SYNC004", ".to_host() payload staging")
                continue
            if isinstance(node.func, ast.Name) \
                    and node.func.id in ("float", "int") \
                    and len(node.args) == 1 \
                    and (taint.expr(node.args[0])
                         or _contains_device_call(node.args[0])):
                yield finding(node, "SYNC005",
                              f"{node.func.id}() on device value")
