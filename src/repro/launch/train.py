"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b \
        --reduced --steps 50 --data-par 2 --model-par 4

Real-cluster usage: one process per host with jax.distributed.initialize()
(env-driven), full configs, make_production_mesh(); here the same code runs
on forced host devices. Resumes from --ckpt-dir automatically; survives
crashes via repro.runtime.ftolerance.Trainer.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data-par", type=int, default=2)
    ap.add_argument("--model-par", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--host-devices", type=int, default=8,
                    help="forced host device count (simulation only)")
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax
    import jax.numpy as jnp

    from repro import models
    from repro.configs import get_config, get_reduced_config
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.ftolerance import Trainer
    from repro.runtime.sharding import batch_shardings
    from repro.train.step import make_train_step, train_state_specs

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    mesh = make_host_mesh(args.data_par, args.model_par)
    step_fn, opt = make_train_step(cfg, mesh, lr=args.lr)
    state_shape, state_shard = train_state_specs(cfg, mesh, opt)
    n_params = sum(int(jnp.size(x))
                   for x in jax.tree.leaves(state_shape["params"]))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"mesh={dict(mesh.shape)}, steps={args.steps}")

    specs = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
             "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
    pipe = SyntheticLM(cfg, args.batch, args.seq)
    bshard = batch_shardings(mesh, specs)
    # lint: retrace(one-shot launcher jit; shardings close over the mesh)
    jit_step = jax.jit(step_fn, in_shardings=(state_shard, bshard),
                       out_shardings=(state_shard, None), donate_argnums=(0,))

    with jax.set_mesh(mesh):
        def init_state():
            params = jax.device_put(
                models.init_params(cfg, jax.random.PRNGKey(0)),
                state_shard["params"])
            return {"params": params,
                    "opt": jax.device_put(opt.init(params), state_shard["opt"]),
                    "step": jnp.zeros((), jnp.int32)}

        trainer = Trainer(step_fn=jit_step, init_state_fn=init_state,
                          next_batch_fn=lambda s: pipe.next_batch(s, mesh, specs),
                          ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                          async_ckpt=True)
        trainer.run(args.steps)
    log = trainer.metrics_log
    print(f"[train] done: loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}; "
          f"restarts={trainer.restarts} stragglers={len(trainer.monitor.flagged)}")


if __name__ == "__main__":
    main()
