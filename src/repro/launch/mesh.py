"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state - the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax
import numpy as np


def compat_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: pass axis_types=Auto only where
    jax.sharding.AxisType exists (older releases are implicitly auto)."""
    kw = {}
    at = getattr(jax.sharding, "AxisType", None)
    if at is not None:
        kw["axis_types"] = (at.Auto,) * len(axes)
    if devices is not None:
        kw["devices"] = devices
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e); multi_pod stacks 2 pods -> 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; the dry-run "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    return compat_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices for tests (e.g. 2x4 with device_count=8)."""
    return compat_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over: ('pod','data') on multi-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
