"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state - the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e); multi_pod stacks 2 pods -> 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; the dry-run "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto, devices=devices[:n])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices for tests (e.g. 2x4 with device_count=8)."""
    auto = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((data, model), ("data", "model"), axis_types=auto)


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over: ('pod','data') on multi-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
