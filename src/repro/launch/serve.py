"""Serving launcher.

Static engine (one-shot fixed batch, the original path):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --reduced \
        --quantize kmeans_ls@16 --gen 16

Continuous-batching engine under Poisson arrivals, optionally with
codebook-quantized KV pages (the paper's solvers applied to the cache):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --reduced \
        --engine continuous --request-rate 4 --kv-quant kmeans_ls@16

Disaggregated prefill/decode serving — N prefill workers feed M decode
workers through a global router; finished prompts migrate as fp pages or
as packed codes + codebooks (``--migrate frozen``, ~7x fewer handoff
bytes):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --reduced \
        --engine disagg --prefill-workers 1 --decode-workers 1 \
        --kv-quant kmeans_ls@16 --migrate frozen --request-rate 4

``--quantize`` / ``--kv-quant`` take a QuantSpec string ("kmeans_ls@16",
"iter_l1@16", "l1_ls:lam=0.02"); the registry's device-batched methods
(kmeans_ls, kmeans, iter_l1) freeze KV pages without host solves. Legacy
bare method names still combine with --num-values / --kv-num-values.

Speculative decoding — a reduced draft model proposes k tokens per step,
the target verifies all k+1 positions in one batched window pass against
the paged cache, accept/rollback adjusts seq_lens in place:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --reduced \
        --engine continuous --speculate 3 --draft-config auto \
        --kv-quant kmeans_ls@16 --request-rate 4

With --kv-quant (or --speculate) the run also replays a deterministic
subset against the fp, non-speculative paged cache (same engine
composition) and reports the logit deviation. Documented tolerance
(reduced configs, f32, per-page codebooks): max |dlogit| <= 2.5 and <= 8%
of the logit range at 16 values; greedy tokens typically agree exactly,
with 0 host page solves for device-capable specs. Speculative decoding is
greedy-token-identical by construction (every emitted token is a target
argmax), so the same check covers its verify-window numerics.
"""
import argparse
import os
import time

_EPILOG = """\
disaggregated serving (--engine disagg):
  --prefill-workers N / --decode-workers M   worker ratio = the TTFT/TPOT
        tradeoff knob: more prefill workers drain the prompt queue faster
        (TTFT), more decode workers hold more concurrent sequences (TPOT);
        decode iterations never wait on a prefill either way.
  --migrate fp|frozen   how finished prefill pages cross the handoff:
        "fp" ships full-width rows (baseline); "frozen" routes full pages
        through the batched device freeze (needs a device-capable
        --kv-quant spec) so they cross as packed 4-bit codes + per-block
        codebooks (~7x fewer bytes) and land directly servable by the
        fused kernel. The run reports measured handoff bytes both ways.
  --freeze-page-budget K   max pages quantized per decode step (colocated
        and disagg): the backpressure valve that keeps a prefill burst of
        full pages from backing up the device queue; deferred pages serve
        exact fp until their turn and are counted in the summary.
  --temperature T / --top-k K   engine-level sampling for the trace
        (temperature 0 = greedy, the default and the verification path;
        per-request seeds derive from --seed, so runs replay exactly).
  --staging-depth D     cap on prefills in flight past the waiting queue
        (assigned to a prefill worker or staged): a decode-capacity stall
        backpressures the prefill workers instead of growing the staged
        queue unboundedly. Default: unbounded.

speculative decoding (--speculate k, both engines):
  --speculate k         draft k tokens per step, verify all k+1 positions
        in one batched target pass; accepted tokens advance seq_lens in
        place, rejected suffixes roll back (never freezing a page past
        the accepted watermark). Greedy-only.
  --draft-config X      the draft model:
        auto      layer-truncate the target to its first half (shared
                  embed/head weights — a real reduced config at ~half the
                  decode FLOPs, ~90% greedy agreement on reduced configs)
        self      the target itself (acceptance ~100%: the upper bound)
        <arch>    an arch name (same --reduced flag; vocab must match)

observability (continuous + disagg engines):
  --trace-out PATH      write a Chrome trace-event / Perfetto-loadable
        JSON trace of the whole run: one track per component — router
        decisions, prefill dispatch/harvest, decode-step phases
        (dispatch/sync/commit), transfer extract/splice with payload
        bytes, the per-page freeze lifecycle (queued -> dispatched ->
        installed | dropped | rolled_back) as async spans, and
        speculative propose/verify/accept/rollback. Load it at
        https://ui.perfetto.dev (Open trace file) or chrome://tracing.
        The run prints a reconciliation of trace spans against the
        engine's freeze/step counters.
  --metrics-jsonl PATH  append one JSON metrics snapshot per
        --metrics-interval seconds (streaming counters/gauges/histogram
        percentiles, windowed over each interval; plus modeled HBM
        bytes/token roofline gauges). A Prometheus text rendering of the
        final snapshot lands next to it at PATH + ".prom".
  --metrics-interval S  snapshot cadence in seconds (default 1.0).

overload survival (continuous + disagg engines):
  --offload-pages       demote preemption victims' frozen KV pages to a
        host-memory tier as packed codes + codebooks (~7x smaller than
        fp rows; bit-exact on restore). Victims resume greedy-token
        identical — restore splices the exact pages back.
  --preempt             when a latency-tier request is blocked on pages,
        evict the coldest (LRU by last-attended step) best_effort
        sequence at a step boundary; a cost model picks restore (host
        tier) vs recompute (re-prefill prompt + emitted tokens) and the
        scheduler re-admits preempted work ahead of the FCFS queue.
  --admission slo|fcfs  "slo" sheds or defers best_effort arrivals when
        the windowed itl_p99 (--itl-slo) is breached or occupancy is
        critical, protecting the latency tier; deferred requests retry
        under hysteresis. "fcfs" (default) admits in arrival order.
  --itl-slo S           inter-token p99 target in seconds for
        --admission slo (unset: occupancy-only shedding).
  --priority latency|best_effort   tier for the generated trace;
        --best-effort-frac F marks a seed-derived fraction best_effort
        instead (the tier SLO admission sheds first, and the only tier
        --preempt will victimize).
  The run epilog reports admission outcomes by reason
  (rejected_queue_full / rejected_pool_full / shed_slo / deferred) and
  the preempt/offload/restore counters with measured host-tier
  compression; --trace-out reconciles page_offload spans (terminal
  state "restored") against those counters.

prefix sharing (--prefix-cache, continuous engine):
  Sequences whose prompts share a page-aligned prefix splice the SAME
  resident KV pages instead of re-prefilling them: a rolling token-hash
  index keys every immutable full page (installed-frozen reconstructions
  under --kv-quant, exact-fp prompt pages otherwise) by its whole prefix
  chain, and each match bumps the page's refcount in the allocator — a
  page returns to the free list only when its last reference drops.
  The write-hot tail page is never shared: lookups stop one page short
  of the prompt end, so each sequence materializes its divergence
  privately (copy-on-write; cow_copies counts matches truncated at that
  boundary). Admission charges worst-case-minus-shareable pages, which
  is what turns sharing into extra concurrent sequences per pool.
  Composes with speculative decoding (rollback stays past the shared
  prompt prefix), preemption/offload (a victim drops refs on shared
  pages instead of demoting them; payloads carry only exclusively-owned
  pages), and chunked prefill (chunks start after the shared run).
  --shared-prefix-len N makes the generated trace share its first N
  prompt tokens across requests (the shared-prefix burst scenario).
  The summary reports prefix_hits / prefix_shared_pages / cow_copies,
  and --trace-out reconciles prefix_match spans against prefix_hits.

chunked prefill (--prefill-chunk N, continuous engine):
  Admission reserves the slot and worst-case pages up front, then the
  prompt enters the cache N tokens per engine iteration, interleaved with
  decode steps for the live batch — a long prompt costs each iteration
  one chunk instead of a whole prefill, which bounds itl_max under a
  long-prompt burst. Each chunk scores against every earlier page through
  the same attention path decode uses; with --attn-impl fused, earlier
  frozen pages cross HBM as packed 4-bit codes + codebooks through the
  double-buffered kernel DMA (the modeled prefill-bytes win on shared
  frozen context — see the prefill_hbm_bytes_per_token gauge and the
  prefill rows in BENCH_paged_attention.json). The chunk sequence is
  logit-identical to single-shot prefill — bitwise on the gather path,
  which the run replays and asserts — and freeze bids are identical
  (queued at attach, after the whole prompt is in cache).

quantized weight serving (--quantize, all engines):
  PTQ'd QuantizedTensor leaves serve undequantized through qmatmul: flat
  leaves hit the fused dequant matmul kernel, and stacked leaves (the
  lax.scan layer-group form) hit the stacked-group kernel with each
  group's codebook VMEM-resident — scanned attention/FFN groups serve
  from uint8 codes with zero per-call dequant. Every traced dense
  materialization bumps the summary's qmatmul_dequant_fallback counter;
  a PTQ run asserts it stays 0.

migration note (pre-spec flags -> QuantSpec strings):
  --quantize kmeans_ls --num-values 16   ->  --quantize kmeans_ls@16:weighted=true
                               (legacy PTQ always optimized the weighted
                                full-vector loss; spell it in the spec)
  --kv-quant kmeans_ls --kv-num-values 8 ->  --kv-quant kmeans_ls@8
  --kv-quant tv                          ->  --kv-quant tv_iter@16
  (lam methods)                          ->  --quantize l1_ls:lam=0.02
Options fold into the spec: kmeans_ls@16:weighted=true,seed=3,clip=-1.0..1.0
The old flag pairs keep working; QuantSpec strings are the canonical form
used by BENCH_*.json artifacts and the registry-validated serving engine.
"""


def _ptq_spec(args) -> str:
    """--quantize value -> spec string (legacy bare names combine with
    --num-values; PTQ historically optimizes the weighted objective)."""
    q = args.quantize
    if "@" in q or ":" in q:
        return q
    return f"{q}@{args.num_values}:weighted=true"


def _run_static(args):
    import jax
    import jax.numpy as jnp

    from repro import models
    from repro.configs import get_config, get_reduced_config
    from repro.launch.static_steps import static_decode_step, static_prefill
    from repro.quant.ptq import (compression_ratio, dequantize_tree,
                                 quantize_tree)

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    if args.quantize:
        spec = _ptq_spec(args)
        qtree, report = quantize_tree(params, spec)
        print(f"[serve] PTQ {spec}: "
              f"{len(report)} tensors, {compression_ratio(report):.1f}x")
        params = dequantize_tree(qtree)

    B, P, G = args.batch, args.prompt_len, args.gen
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    enc = (jax.random.normal(jax.random.PRNGKey(2), (B, P, cfg.d_model))
           if cfg.family == "encdec" else None)

    t0 = time.perf_counter()
    tok, cache = static_prefill(params, cfg, tokens, enc, G)
    out = [tok]
    for i in range(G - 1):
        tok, cache = static_decode_step(params, cfg, tok, cache,
                                        jnp.int32(P + i))
        out.append(tok)
    gen = jnp.concatenate(out, axis=1).block_until_ready()
    dt = time.perf_counter() - t0
    print(f"[serve] {B} requests x {G} tokens in {dt:.2f}s "
          f"({B*G/dt:.1f} tok/s incl. compile); sample: {gen[0][:10].tolist()}")


def _make_draft(params, cfg, args):
    """Resolve --draft-config into a (draft_params, draft_cfg) pair."""
    import jax

    from repro import models
    from repro.configs import get_config, get_reduced_config
    from repro.serving import derive_draft

    name = args.draft_config
    if name in (None, "auto"):
        return derive_draft(params, cfg)
    if name == "self":
        return params, cfg
    dcfg = (get_reduced_config if args.reduced else get_config)(name)
    if dcfg.vocab != cfg.vocab:
        raise SystemExit(f"[serve] draft {name} vocab {dcfg.vocab} != "
                         f"target vocab {cfg.vocab}")
    return models.init_params(dcfg, jax.random.PRNGKey(7)), dcfg


def _make_engine(params, cfg, args, *, kv_quant, record_logits=False,
                 freeze_async=True, speculate=None, draft=None,
                 tracer=None, exporter=None, overload=False,
                 prefix_cache=False):
    """Build the engine composition ``args`` asks for (colocated vs
    disaggregated) — verification replays run through the same one
    (with tracer/exporter AND the overload/prefix-sharing machinery left
    off: replays are correctness probes on an uncontended pool)."""
    from repro.serving import ContinuousBatchingEngine, DisaggEngine

    speculate = args.speculate if speculate is None else speculate
    kw = dict(max_slots=args.max_slots, block_size=args.block_size,
              max_seq_len=args.max_seq_len, kv_quant=kv_quant,
              kv_num_values=args.kv_num_values, attn_impl=args.attn_impl,
              record_logits=record_logits, freeze_async=freeze_async,
              freeze_page_budget=args.freeze_page_budget,
              speculate=speculate, draft=draft if speculate else None,
              tracer=tracer, exporter=exporter)
    if overload:
        kw.update(offload_pages=args.offload_pages, preempt=args.preempt,
                  admission=args.admission, itl_slo_s=args.itl_slo)
    if args.engine == "disagg":
        # fp pages are the only thing that can migrate without a spec
        migrate = args.migrate if kv_quant is not None else "fp"
        return DisaggEngine(params, cfg,
                            prefill_workers=args.prefill_workers,
                            decode_workers=args.decode_workers,
                            migrate=migrate,
                            staging_depth=args.staging_depth, **kw)
    return ContinuousBatchingEngine(params, cfg,
                                    prefill_chunk=args.prefill_chunk,
                                    prefix_cache=prefix_cache, **kw)


def _verify_serving(params, cfg, args, draft=None):
    """Replay a deterministic batch through the fp, non-speculative engine
    vs the engine as configured (quantized KV and/or speculative) and
    report the logit deviation the quantized cache, the frozen page
    migration (disagg), and the verify-window numerics introduce.
    Speculative decoding must be greedy token-identical here: every
    emitted token is a target argmax for its exact accepted context."""
    import numpy as np

    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab, args.prompt_len).tolist()
               for _ in range(min(3, args.max_slots))]
    outs, engines = [], []
    for baseline in (True, False):
        eng = _make_engine(params, cfg, args,
                           kv_quant=None if baseline else args.kv_quant,
                           record_logits=True,
                           speculate=0 if baseline else args.speculate,
                           draft=draft,
                           freeze_async=False)  # deterministic install step
        outs.append(eng.generate(prompts, max_new_tokens=args.gen))
        engines.append(eng)
    fp, q = engines
    dmax = scale = dsum = dcount = 0.0
    agree, total = 0, 0
    for i in range(len(prompts)):
        a, b = fp.request_logits[i], q.request_logits[i]
        d = np.abs(a - b)
        dmax = max(dmax, float(d.max()))
        dsum += float(d.sum())
        dcount += d.size
        scale = max(scale, float(np.abs(a).max()))
        agree += sum(int(x == y) for x, y in zip(outs[0][i], outs[1][i]))
        total += len(outs[0][i])
    dmean = dsum / max(dcount, 1)
    rel = dmax / max(scale, 1e-9)
    host = (sum(w.counters["host_page_solves"] for w in q.decode)
            if args.engine == "disagg"
            else q.counters["host_page_solves"])
    tol_abs, tol_rel = 2.5, 0.08
    ok = dmax <= tol_abs and rel <= tol_rel
    if args.speculate:
        # token identity is the speculative acceptance bar, not a tolerance
        ok = ok and agree == total
    mig = f", migrate={q.migrate}" if args.engine == "disagg" else ""
    spec = f", speculate={args.speculate}" if args.speculate else ""
    print(f"[serve] serving check ({q.kv_spec or 'fp'}{mig}{spec}): "
          f"max|dlogit|={dmax:.3f} mean={dmean:.4f} rel={rel:.3%} "
          f"(tolerance: abs<={tol_abs}, rel<={tol_rel:.0%}) "
          f"greedy-token agreement {agree}/{total}, {host} host page solves "
          f"-> {'OK' if ok else 'EXCEEDED'}")
    if args.speculate:
        s = q.metrics.summary()
        steps = (sum(w.counters["seq_decode_steps"] for w in q.decode)
                 if args.engine == "disagg"
                 else q.counters["seq_decode_steps"])
        tps = (s.get("gen_tokens", 0) - s.get("completed", 0)) / max(steps, 1)
        print(f"[serve] speculative check: acceptance "
              f"{s.get('spec_acceptance_rate', 0.0):.1%} over "
              f"{s.get('spec_proposed', 0)} drafts, "
              f"{s.get('spec_rollbacks', 0)} rollbacks, "
              f"tokens/step {tps:.2f}")
    return ok


def _verify_chunked(params, cfg, args):
    """Replay a deterministic batch chunked (--prefill-chunk) vs
    single-shot through the gather read path and require BITWISE identity:
    same tokens, same recorded logits. A chunk sequence walks the same
    pages in the same order as one whole-prompt call, so equality is
    exact — any drift is a scheduler or masking bug, not numerics."""
    import numpy as np

    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab, args.prompt_len).tolist()
               for _ in range(min(3, args.max_slots))]
    chunk, impl = args.prefill_chunk, args.attn_impl
    args.attn_impl = "gather"   # one read path for both -> bitwise bar
    outs, engines = [], []
    try:
        for args.prefill_chunk in (None, chunk):
            eng = _make_engine(params, cfg, args, kv_quant=args.kv_quant,
                               record_logits=True, speculate=0,
                               freeze_async=False)
            outs.append(eng.generate(prompts, max_new_tokens=args.gen))
            engines.append(eng)
    finally:
        args.prefill_chunk, args.attn_impl = chunk, impl
    single, chunked = engines
    ok = outs[0] == outs[1]
    for i in range(len(prompts)):
        ok = ok and bool(np.array_equal(single.request_logits[i],
                                        chunked.request_logits[i]))
    n = chunked.prefill.counters["prefill_chunks"]
    print(f"[serve] chunked-prefill check (chunk={chunk}, "
          f"kv={args.kv_quant or 'fp'}, gather replay): {n} chunks, "
          f"tokens+logits vs single-shot "
          f"{'bitwise identical -> OK' if ok else 'MISMATCH -> FAILED'}")
    return ok


def _trace_reconcile(tracer, s, speculate: int) -> bool:
    """Cross-check trace spans against the engine's counters: the trace is
    only trustworthy if its event counts ARE the counters."""
    from repro.obs import count_events

    ev = tracer.events
    n_step = count_events(ev, name="decode_step", ph="X")
    n_flush = count_events(ev, name="flush", ph="X")
    nb = count_events(ev, name="page_freeze", ph="b")
    ne = count_events(ev, name="page_freeze", ph="e")
    states: dict = {}
    for e in ev:
        if e.get("ph") == "e" and e.get("name") == "page_freeze":
            st = e.get("args", {}).get("state", "?")
            states[st] = states.get(st, 0) + 1
    n_pc = count_events(ev, name="prefill_chunk", ph="X")
    ok = (n_step == s.get("decode_steps", 0)
          and n_flush == s.get("freeze_dispatches", 0) and nb == ne
          and n_pc == s.get("prefill_chunks", 0))
    if speculate:
        n_acc = count_events(ev, name="accept", ph="i")
        n_rb = count_events(ev, name="rollback", ph="i")
        ok = ok and (n_acc == s.get("spec_steps", 0)
                     and n_rb == s.get("spec_rollbacks", 0))
    # overload: every offloaded page's async span must close "restored",
    # and the preempt/restore instants must match the counters exactly
    ob = count_events(ev, name="page_offload", ph="b")
    oe = count_events(ev, name="page_offload", ph="e")
    o_restored = sum(1 for e in ev if e.get("name") == "page_offload"
                     and e.get("ph") == "e"
                     and e.get("args", {}).get("state") == "restored")
    ok = ok and (ob == oe == o_restored == s.get("offloaded_pages", 0)
                 == s.get("restored_pages", 0))
    ok = ok and (count_events(ev, name="preempt", ph="i")
                 == s.get("preemptions", 0))
    ok = ok and (count_events(ev, name="restore", ph="i")
                 == s.get("restored_seqs", 0))
    # prefix sharing: every counted hit carries exactly one prefix_match
    # span (prefill dispatch or restore re-attach), and vice versa
    n_pm = count_events(ev, name="prefix_match", ph="X")
    ok = ok and n_pm == s.get("prefix_hits", 0)
    state_txt = (", ".join(f"{k}={v}" for k, v in sorted(states.items()))
                 or "none")
    off_txt = f", page-offload spans {ob} -> {oe} restored" if ob else ""
    if n_pm or s.get("prefix_hits"):
        off_txt += (f", prefix_match spans {n_pm} "
                    f"(counter {s.get('prefix_hits', 0)})")
    if n_pc or s.get("prefill_chunks"):
        off_txt += (f", prefill_chunk spans {n_pc} "
                    f"(counter {s.get('prefill_chunks', 0)})")
    print(f"[serve] trace: {len(ev)} events | decode_step spans {n_step} "
          f"(counter {s.get('decode_steps', 0)}), freeze flushes {n_flush} "
          f"(counter {s.get('freeze_dispatches', 0)}), page-freeze spans "
          f"{nb} opened -> {ne} terminal ({state_txt}){off_txt} "
          f"-> {'reconciled' if ok else 'MISMATCH'}")
    return ok


def _run_continuous(args):
    import jax

    from repro.configs import get_config, get_reduced_config
    from repro import models
    from repro.serving.scheduler import poisson_trace

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    if args.quantize:
        from repro.quant.ptq import compression_ratio, quantize_tree

        # QuantizedTensor leaves are served as-is: attention/ffn projections
        # route through qmatmul's fused dequant path, never densifying.
        spec = _ptq_spec(args)
        params, report = quantize_tree(
            params, spec,
            skip_patterns=("ln", "norm", "router", "A_log", "mix", "dt_bias",
                           "D_skip", "w0", "embed", "lm_head"))
        print(f"[serve] PTQ {spec}: "
              f"{len(report)} tensors, {compression_ratio(report):.1f}x, "
              "serving undequantized via qmatmul")

    draft = _make_draft(params, cfg, args) if args.speculate else None
    if args.speculate and args.temperature > 0:
        raise SystemExit("[serve] --speculate serves the greedy path; "
                         "drop --temperature")
    tracer = exporter = None
    if args.trace_out or args.metrics_jsonl:
        from repro.obs import MetricsExporter, Tracer

        if args.trace_out:
            tracer = Tracer()
        if args.metrics_jsonl:
            exporter = MetricsExporter(args.metrics_jsonl,
                                       interval_s=args.metrics_interval)
    eng = _make_engine(params, cfg, args, kv_quant=args.kv_quant,
                       draft=draft, tracer=tracer, exporter=exporter,
                       overload=True, prefix_cache=args.prefix_cache)
    be_frac = (1.0 if args.priority == "best_effort"
               else args.best_effort_frac)
    trace = poisson_trace(args.num_requests, args.request_rate,
                          vocab=cfg.vocab, prompt_len=args.prompt_len,
                          max_new_tokens=args.gen, seed=args.seed,
                          temperature=args.temperature, top_k=args.top_k,
                          best_effort_frac=be_frac,
                          shared_prefix_len=args.shared_prefix_len)
    tag = (f"disagg {args.prefill_workers}P/{args.decode_workers}D "
           f"migrate={eng.migrate}" if args.engine == "disagg"
           else "continuous batching")
    spec_tag = (f", speculate={args.speculate} "
                f"(draft={draft[1].name})" if args.speculate else "")
    print(f"[serve] {tag}: {args.num_requests} requests, "
          f"Poisson rate {args.request_rate}/s, prompt {args.prompt_len}, "
          f"gen {args.gen}, {args.max_slots} slots x "
          f"{args.max_seq_len} tokens, block {args.block_size}, "
          f"kv={eng.kv_spec or 'fp'}{spec_tag}, sampling="
          f"{'greedy' if args.temperature <= 0 else f'T={args.temperature},top_k={args.top_k}'}")
    s = eng.run(trace)
    if exporter is not None:
        exporter.close(eng.metrics)
        from repro.obs import prometheus_text

        prom_path = args.metrics_jsonl + ".prom"
        with open(prom_path, "w") as f:
            f.write(prometheus_text(eng.metrics.snapshot()))
        print(f"[serve] metrics: {len(exporter.lines)} snapshots -> "
              f"{args.metrics_jsonl} (+ {prom_path})")
    if tracer is not None:
        tracer.write(args.trace_out)
        print(f"[serve] trace -> {args.trace_out} (load at "
              f"https://ui.perfetto.dev or chrome://tracing)")
        if not _trace_reconcile(tracer, s, args.speculate):
            raise SystemExit("[serve] trace/counter reconciliation failed")
    if not s["completed"]:
        print(f"[serve] no requests completed ({s['rejected']} rejected — "
              f"prompt+gen must fit --max-seq-len {args.max_seq_len})")
        return
    print(f"[serve] completed {s['completed']}/{args.num_requests} "
          f"(rejected {s['rejected']}) in {s['makespan_s']:.2f}s: "
          f"{s['throughput_tok_s']:.1f} gen tok/s")
    print(f"[serve] TTFT mean {s['ttft_mean_s']*1e3:.0f}ms "
          f"(= queue wait {s['queue_wait_mean_s']*1e3:.0f}ms + prefill "
          f"compute {s['prefill_compute_mean_s']*1e3:.0f}ms) "
          f"p50 {s['ttft_p50_s']*1e3:.0f}ms p99 {s['ttft_p99_s']*1e3:.0f}ms | "
          f"TPOT p50 {s['tpot_p50_s']*1e3:.1f}ms p99 {s['tpot_p99_s']*1e3:.1f}ms")
    occ = s.get("cache_occupancy_mean", 0.0)
    print(f"[serve] cache occupancy mean {occ:.1%} "
          f"max {s.get('cache_occupancy_max', 0.0):.1%}")
    print(f"[serve] attn_impl={s['attn_impl']} | freeze: "
          f"{s['freeze_dispatches']} dispatches -> {s['freeze_installs']} "
          f"installs, {s['host_page_solves']} host page solves, "
          f"{s['freeze_overlap_steps']} decode steps ran between dispatch "
          f"and install, {s['freeze_deferred_pages']} pages deferred by the "
          f"per-step budget ({args.freeze_page_budget}) | gather window <= "
          f"{s['max_gather_blocks']} blocks")
    if args.prefill_chunk:
        print(f"[serve] chunked prefill: {s.get('prefill_chunks', 0)} chunks "
              f"of <= {args.prefill_chunk} tokens interleaved with decode "
              f"steps (one chunk per engine iteration)")
    if args.quantize:
        fb = s.get("qmatmul_dequant_fallback", 0)
        print(f"[serve] quantized weights: qmatmul_dequant_fallback={fb} "
              f"(every PTQ'd projection must serve from codes)")
        if fb:
            raise SystemExit("[serve] PTQ run traced a dense dequant "
                             "fallback in qmatmul")
    adm = {k: s[k] for k in ("rejected_queue_full", "rejected_pool_full",
                             "shed_slo", "deferred") if s.get(k)}
    if adm or args.admission == "slo":
        txt = ", ".join(f"{k}={v}" for k, v in adm.items()) or "none"
        print(f"[serve] admission ({args.admission}"
              + (f", itl_slo={args.itl_slo}s" if args.itl_slo else "")
              + f"): {txt}")
    if args.prefix_cache:
        print(f"[serve] prefix cache: {s.get('prefix_hits', 0)} hits, "
              f"{s.get('prefix_shared_pages', 0)} pages spliced shared, "
              f"{s.get('cow_copies', 0)} copy-on-write tail materializations")
    if s.get("preemptions"):
        comp = s.get("offload_compression", 0.0)
        print(f"[serve] overload: {s['preemptions']} preemptions "
              f"({s.get('preempt_offloads', 0)} offloaded to host, "
              f"{s.get('preempt_recomputes', 0)} recomputed); "
              f"{s.get('offloaded_pages', 0)} pages -> host tier at "
              f"{s.get('offload_bytes', 0)/1e6:.3f} MB"
              + (f" ({comp:.1f}x smaller than fp)" if comp else "")
              + f", {s.get('restored_seqs', 0)} sequences "
              f"({s.get('restored_pages', 0)} pages) restored bit-exact")
    if args.engine == "disagg":
        mb = s.get("migrate_bytes", 0)
        print(f"[serve] migration: {s['prefills_done']} prefills -> "
              f"{s['migrated_seqs']} handoffs, {s['migrated_pages']} pages, "
              f"{mb/1e6:.3f} MB crossed ({s['migrate_compression']:.1f}x "
              f"fewer than fp rows at {s.get('migrate_fp_equiv_bytes', 0)/1e6:.3f} MB)")
    if args.speculate:
        print(f"[serve] speculative: acceptance "
              f"{s.get('spec_acceptance_rate', 0.0):.1%} "
              f"({s.get('spec_accepted', 0)}/{s.get('spec_proposed', 0)} "
              f"drafts), {s.get('spec_rollbacks', 0)} rollbacks, "
              f"tokens/step {s.get('tokens_per_step', 1.0):.2f}")
    if args.kv_quant:
        print(f"[serve] cache bytes: frozen-page compression "
              f"{s['page_compression']:.1f}x per page; measured mean "
              f"{s.get('cache_compression_mean', 1.0):.1f}x, at last "
              f"occupied step {s.get('cache_compression_final', 1.0):.1f}x "
              f"(partial pages stay fp)")
    if args.kv_quant or args.speculate:
        if not _verify_serving(params, cfg, args, draft=draft):
            raise SystemExit(1)     # tolerance breach must fail the run
    if args.prefill_chunk:
        if not _verify_chunked(params, cfg, args):
            raise SystemExit(1)     # bitwise breach must fail the run


def main():
    ap = argparse.ArgumentParser(
        epilog=_EPILOG, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", choices=("static", "continuous", "disagg"),
                    default="static")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--gen", type=int, default=None)
    ap.add_argument("--quantize", default=None,
                    help="PTQ QuantSpec for weights (e.g. kmeans_ls@16, "
                         "l1_ls:lam=0.02; bare method names combine with "
                         "--num-values)")
    ap.add_argument("--num-values", type=int, default=16,
                    help="legacy count budget for a bare --quantize method")
    # continuous engine
    ap.add_argument("--request-rate", type=float, default=4.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--num-requests", type=int, default=12)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--max-seq-len", type=int, default=256)
    ap.add_argument("--kv-quant", default=None,
                    help="page codebook QuantSpec (kmeans_ls@16, iter_l1@16, "
                         "tv_iter@16, dtc@16; bare method names combine "
                         "with --kv-num-values)")
    ap.add_argument("--kv-num-values", type=int, default=None,
                    help="legacy count budget for a bare --kv-quant method "
                         "(default 16; conflicts with a spec-form "
                         "--kv-quant)")
    ap.add_argument("--attn-impl", choices=("auto", "fused", "gather"),
                    default="auto",
                    help="decode read path: fused Pallas paged-attention "
                         "kernel vs dense gather (auto: fused on TPU)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="continuous engine: share page-aligned common "
                         "prompt prefixes across sequences via refcounted "
                         "copy-on-write pages (see epilog)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="share the first N prompt tokens across every "
                         "request in the generated trace (the shared-prefix "
                         "burst scenario --prefix-cache exploits)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous engine: admit prompts in N-token "
                         "chunks, one per engine iteration, interleaved "
                         "with decode steps (bounds itl_max under long-"
                         "prompt bursts; bit-identical to single-shot "
                         "prefill — see epilog)")
    # disaggregated engine
    ap.add_argument("--prefill-workers", type=int, default=1,
                    help="disagg: prefill worker count (the N of the N:M "
                         "TTFT/TPOT ratio knob)")
    ap.add_argument("--decode-workers", type=int, default=1,
                    help="disagg: decode worker count")
    ap.add_argument("--migrate", choices=("fp", "frozen"), default="fp",
                    help="disagg page handoff: fp rows vs packed codes + "
                         "codebooks via the device freeze path (needs a "
                         "device-capable --kv-quant)")
    ap.add_argument("--freeze-page-budget", type=int, default=4,
                    help="max KV pages quantized per decode step (prefill-"
                         "burst backpressure valve; deferred pages counted "
                         "in the summary)")
    ap.add_argument("--staging-depth", type=int, default=None,
                    help="disagg: cap on prefills in flight past the "
                         "waiting queue; a decode stall backpressures the "
                         "prefill workers (default: unbounded)")
    # speculative decoding
    ap.add_argument("--speculate", type=int, default=0,
                    help="draft k tokens per step and verify all k+1 "
                         "positions in one batched target pass (0 = off; "
                         "greedy only)")
    ap.add_argument("--draft-config", default="auto",
                    help="draft model for --speculate: 'auto' (layer-"
                         "truncated target, shared weights), 'self' (the "
                         "target itself), or an arch name with a matching "
                         "vocab")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="engine-level sampling temperature for the trace "
                         "(0 = greedy, the default and verification path)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation when sampling (0 = full vocab)")
    # overload survival
    ap.add_argument("--offload-pages", action="store_true",
                    help="demote preemption victims' frozen KV pages to a "
                         "host tier as packed codes+codebooks; restore is "
                         "bit-exact (see epilog)")
    ap.add_argument("--preempt", action="store_true",
                    help="evict the coldest best_effort sequence when a "
                         "latency-tier request is blocked on pages "
                         "(restore-vs-recompute cost model; preempted work "
                         "re-admits ahead of FCFS)")
    ap.add_argument("--admission", choices=("fcfs", "slo"), default="fcfs",
                    help="slo: shed/defer best_effort arrivals off windowed "
                         "itl_p99 (--itl-slo) + occupancy, protecting the "
                         "latency tier")
    ap.add_argument("--itl-slo", type=float, default=None,
                    help="inter-token p99 target in seconds for "
                         "--admission slo (unset: occupancy-only)")
    ap.add_argument("--priority", choices=("latency", "best_effort"),
                    default="latency",
                    help="tier for every request in the generated trace")
    ap.add_argument("--best-effort-frac", type=float, default=0.0,
                    help="mark this (seed-derived) fraction of the trace "
                         "best_effort — the tier SLO admission sheds and "
                         "--preempt victimizes")
    # observability
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable Chrome trace-event "
                         "JSON of the run (one track per component; see "
                         "epilog)")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append periodic JSON metrics snapshots here "
                         "(streaming percentiles windowed per interval; "
                         "final Prometheus text at PATH + '.prom')")
    ap.add_argument("--metrics-interval", type=float, default=1.0,
                    help="seconds between --metrics-jsonl snapshots")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if (args.trace_out or args.metrics_jsonl) \
            and args.engine not in ("continuous", "disagg"):
        ap.error("--trace-out/--metrics-jsonl instrument the continuous "
                 "and disagg engines")
    serving = args.engine in ("continuous", "disagg")
    if (args.offload_pages or args.preempt or args.admission == "slo") \
            and not serving:
        ap.error("--offload-pages/--preempt/--admission slo instrument the "
                 "continuous and disagg engines")
    if serving and args.request_rate <= 0:
        ap.error("--request-rate must be > 0 (requests per second)")
    if args.engine == "disagg" and args.migrate == "frozen" \
            and not args.kv_quant:
        ap.error("--migrate frozen needs --kv-quant (pages cross as "
                 "codes+codebooks)")
    if args.prefill_chunk is not None:
        if args.engine != "continuous":
            ap.error("--prefill-chunk interleaves the continuous engine's "
                     "decode loop (disagg already overlaps via workers)")
        if args.prefill_chunk < 1:
            ap.error("--prefill-chunk must be >= 1 token")
    if args.prefix_cache and args.engine != "continuous":
        ap.error("--prefix-cache shares pages within one colocated pool "
                 "(the continuous engine); disagg pools migrate pages out")
    if args.shared_prefix_len and not serving:
        ap.error("--shared-prefix-len shapes the continuous/disagg trace")
    if args.prompt_len is None:
        args.prompt_len = 64 if serving else 16
    if args.gen is None:
        args.gen = 32 if serving else 16
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    if serving:
        _run_continuous(args)
    else:
        _run_static(args)


if __name__ == "__main__":
    main()
