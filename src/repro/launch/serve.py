"""Serving launcher: batched prefill + decode with optional PTQ weights.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --reduced \
        --quantize kmeans_ls --num-values 16 --gen 16
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quantize", default=None,
                    help="PTQ method (e.g. kmeans_ls, l1_ls, tv)")
    ap.add_argument("--num-values", type=int, default=16)
    args = ap.parse_args()
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

    import jax
    import jax.numpy as jnp

    from repro import models
    from repro.configs import get_config, get_reduced_config
    from repro.quant.ptq import (compression_ratio, dequantize_tree,
                                 quantize_tree)

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    if args.quantize:
        qtree, report = quantize_tree(params, method=args.quantize,
                                      num_values=args.num_values,
                                      weighted=True)
        print(f"[serve] PTQ {args.quantize}@{args.num_values}: "
              f"{len(report)} tensors, {compression_ratio(report):.1f}x")
        params = dequantize_tree(qtree)

    B, P, G = args.batch, args.prompt_len, args.gen
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    enc = (jax.random.normal(jax.random.PRNGKey(2), (B, P, cfg.d_model))
           if cfg.family == "encdec" else None)

    @jax.jit
    def prefill(p, toks):
        cache = models.init_cache(cfg, B, P + G, enc_len=P)
        batch = {"tokens": toks}
        if enc is not None:
            batch["enc_embeds"] = enc
        logits, cache = models.prefill(p, cfg, batch, cache)
        return jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32), cache

    @jax.jit
    def step(p, tok, cache, idx):
        logits, cache = models.decode_step(p, cfg, tok, cache, idx)
        return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache

    t0 = time.perf_counter()
    tok, cache = prefill(params, tokens)
    out = [tok]
    for i in range(G - 1):
        tok, cache = step(params, tok, cache, jnp.int32(P + i))
        out.append(tok)
    gen = jnp.concatenate(out, axis=1).block_until_ready()
    dt = time.perf_counter() - t0
    print(f"[serve] {B} requests x {G} tokens in {dt:.2f}s "
          f"({B*G/dt:.1f} tok/s incl. compile); sample: {gen[0][:10].tolist()}")


if __name__ == "__main__":
    main()
