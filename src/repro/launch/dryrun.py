"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first lines - jax locks the device count on first init:
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import gzip
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze
from repro.analysis.roofline import (Roofline, model_flops,
                                     model_params_active)
from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.runtime.sharding import (batch_shardings, cache_shardings,
                                    param_shardings)
from repro.train.step import (SHAPES, cache_specs, input_specs,
                              make_decode_step, make_prefill_step,
                              make_train_step, shape_skip_reason,
                              train_state_specs)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def lower_cell(arch: str, shape: str, *, multi_pod: bool, overrides=None):
    """Build + lower the step for one cell. Returns (lowered, cfg, mesh)."""
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = SHAPES[shape]["kind"]
    bspecs = input_specs(cfg, shape)
    bshard = batch_shardings(mesh, bspecs)
    if kind == "train":
        step, opt = make_train_step(cfg, mesh)
        state_shape, state_shard = train_state_specs(cfg, mesh, opt)
        # lint: retrace(one-shot AOT lowering; shardings need the mesh)
        jit = jax.jit(step, in_shardings=(state_shard, bshard),
                      out_shardings=(state_shard, None), donate_argnums=(0,))
        lowered = jit.lower(state_shape, bspecs)
    elif kind == "prefill":
        step = make_prefill_step(cfg, mesh, shape)
        pshape = jax.eval_shape(
            lambda: __import__("repro.models", fromlist=["x"]).init_params(
                cfg, jax.random.PRNGKey(0)))
        pshard = param_shardings(mesh, pshape)
        cshape = cache_specs(cfg, shape)
        cshard = cache_shardings(mesh, cfg, cshape,
                                 batch_size=SHAPES[shape]["batch"])
        # lint: retrace(one-shot AOT lowering; shardings need the mesh)
        jit = jax.jit(step, in_shardings=(pshard, bshard),
                      out_shardings=(None, cshard))
        lowered = jit.lower(pshape, bspecs)
    else:  # decode
        step = make_decode_step(cfg, mesh, shape)
        from repro import models
        pshape = jax.eval_shape(
            lambda: models.init_params(cfg, jax.random.PRNGKey(0)))
        pshard = param_shardings(mesh, pshape)
        cshape = cache_specs(cfg, shape)
        cshard = cache_shardings(mesh, cfg, cshape,
                                 batch_size=SHAPES[shape]["batch"])
        # lint: retrace(one-shot AOT lowering; shardings need the mesh)
        jit = jax.jit(step, in_shardings=(pshard, bshard["tokens"], cshard),
                      out_shardings=(bshard["tokens"], cshard),
                      donate_argnums=(2,))
        lowered = jit.lower(pshape, bspecs["tokens"], cshape)
    return lowered, cfg, mesh


def run_cell(arch: str, shape: str, *, multi_pod: bool, save_hlo: bool = False,
             out_dir: str = RESULTS_DIR) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {"arch": arch, "shape": shape, "mesh": mesh_name}
    cfg = get_config(arch)
    skip = shape_skip_reason(cfg, shape)
    if skip:
        cell.update(status="skipped", reason=skip)
        return cell
    t0 = time.time()
    lowered, cfg, mesh = lower_cell(arch, shape, multi_pod=multi_pod)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax<=0.4.x: one dict per program
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    stats = analyze(txt)
    n_dev = mesh.size
    kind = SHAPES[shape]["kind"]
    mf = model_flops(cfg, kind, SHAPES[shape]["batch"], SHAPES[shape]["seq"])
    rl = Roofline(flops=stats["flops"], hbm_bytes=stats["hbm_bytes"],
                  collective_bytes=stats["collective_bytes"],
                  model_flops_per_device=mf / n_dev)
    total, active = model_params_active(cfg)
    cell.update(
        status="ok",
        t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
        n_devices=n_dev,
        params_total=total, params_active=active,
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_estimate=(mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
        ),
        cost_analysis=dict(flops=ca.get("flops", 0.0),
                           bytes_accessed=ca.get("bytes accessed", 0.0)),
        hlo=dict(flops=stats["flops"], hbm_bytes=stats["hbm_bytes"],
                 collective_bytes=stats["collective_bytes"],
                 per_collective=stats["per_collective"],
                 while_trips=stats["while_trips"]),
        roofline=rl.as_dict(),
    )
    if save_hlo:
        os.makedirs(out_dir, exist_ok=True)
        with gzip.open(os.path.join(
                out_dir, f"{arch}.{shape}.{mesh_name}.hlo.gz"), "wt") as f:
            f.write(txt)
    return cell


def _write(cell: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{cell['arch']}.{cell['shape']}.{cell['mesh']}.json")
    with open(path, "w") as f:
        json.dump(cell, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (or --all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every cell (each in a subprocess) incl. both meshes")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch in ARCHS:
            for shape in SHAPES:
                for mp in (False, True):
                    mesh_name = "pod2x16x16" if mp else "pod16x16"
                    path = os.path.join(
                        args.out, f"{arch}.{shape}.{mesh_name}.json")
                    if args.skip_existing and os.path.exists(path):
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--out", args.out]
                    if mp:
                        cmd.append("--multi-pod")
                    print(f"[dryrun] {arch} x {shape} x {mesh_name}",
                          flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh_name))
                        _write({"arch": arch, "shape": shape,
                                "mesh": mesh_name, "status": "error",
                                "reason": f"subprocess rc={r.returncode}"},
                               args.out)
        print(f"[dryrun] done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    try:
        cell = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                        save_hlo=args.save_hlo, out_dir=args.out)
    except Exception as e:
        cell = {"arch": args.arch, "shape": args.shape,
                "mesh": "pod2x16x16" if args.multi_pod else "pod16x16",
                "status": "error", "reason": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:]}
        path = _write(cell, args.out)
        print(f"[dryrun] ERROR -> {path}\n{cell['reason']}")
        sys.exit(1)
    path = _write(cell, args.out)
    if cell["status"] == "ok":
        rl = cell["roofline"]
        print(f"[dryrun] OK {path}\n"
              f"  devices={cell['n_devices']} compile={cell['t_compile_s']}s "
              f"peak_mem/dev={cell['memory']['peak_estimate']/2**30:.2f}GiB\n"
              f"  t_comp={rl['t_compute_s']:.4f}s t_mem={rl['t_memory_s']:.4f}s "
              f"t_coll={rl['t_collective_s']:.4f}s dominant={rl['dominant']} "
              f"frac={rl['roofline_fraction']:.2f}")
    else:
        print(f"[dryrun] {cell['status']}: {cell.get('reason')}")


if __name__ == "__main__":
    main()
