"""Module-level jitted steps for the static (one-shot fixed-batch) serve
path.

serve.py defers every jax import until after main() has set XLA_FLAGS, so
its jits cannot live at its module scope — they live here instead
(imported lazily by ``_run_static``), keeping the shared-jit convention:
one compile cache per step shape, keyed on the hashable cfg, shared by
every caller instead of re-created per invocation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("cfg", "gen_len"))
def static_prefill(params, cfg, tokens, enc, gen_len: int):
    """Prefill ``tokens`` (B, P) and sample the first greedy token; the
    cache is sized for ``gen_len`` further decode steps."""
    from repro import models

    B, P = tokens.shape
    cache = models.init_cache(cfg, B, P + gen_len, enc_len=P)
    batch = {"tokens": tokens}
    if enc is not None:
        batch["enc_embeds"] = enc
    logits, cache = models.prefill(params, cfg, batch, cache)
    return jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32), cache


@functools.partial(jax.jit, static_argnames=("cfg",))
def static_decode_step(params, cfg, tok, cache, idx):
    """One greedy decode step at ring-cache position ``idx``."""
    from repro import models

    logits, cache = models.decode_step(params, cfg, tok, cache, idx)
    return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache
