"""Entry points: train, serve (static + continuous batching), dryrun."""
