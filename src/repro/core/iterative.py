"""Algorithm 2: iterative l1 quantization with a lambda ramp.

Starts from a small lambda_1^0 and increases it linearly (Delta-lambda =
lambda_1^0), warm-starting alpha from the previous iteration, until
||alpha||_0 <= l; each iteration then applies the Algorithm-1 LS refit.
Faithful to the paper: may terminate with fewer than l values (§3.5).

`tv_iterative` is the beyond-paper variant: bisection on lambda against the
exact O(m) TV solver - no ramp hyper-parameters and a globally optimal
solution at each lambda (DESIGN.md §5.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .cd import cd_solve
from .problem import LSQProblem
from .refit import effective_num_values, refit_support, support_of


def iterative_l1(problem: LSQProblem, l: int, *, lam0: float | None = None,
                 max_iters: int = 60, max_sweeps: int = 200,
                 ) -> tuple[jax.Array, jax.Array, int, int]:
    """Returns (w_star, alpha_star, nnz, iters)."""
    if lam0 is None:
        # relative to the scale of the objective so the ramp is data-independent
        w = np.asarray(problem.w_hat).astype(np.float64)
        n = np.asarray(problem.counts).astype(np.float64)
        lam0 = float(0.005 * np.sum(n * w * w) / max(len(w), 1))
    alpha = jnp.ones((problem.m,), jnp.float32)
    nnz = problem.m
    it = 0
    lam_t = 0.0
    for it in range(1, max_iters + 1):
        lam_t = lam0 * it  # lam^t = lam0 + (t-1) * dlam, dlam = lam0
        alpha, _ = cd_solve(problem, jnp.float32(lam_t), alpha0=alpha,
                            max_sweeps=max_sweeps)
        nnz = effective_num_values(support_of(alpha))
        if nnz <= l:
            break
    # geometric acceleration: the paper's linear ramp may stall above l for
    # small lam0; doubling always terminates (lam -> inf drives alpha -> 0)
    while nnz > l:
        it += 1
        lam_t *= 2.0
        alpha, _ = cd_solve(problem, jnp.float32(lam_t), alpha0=alpha,
                            max_sweeps=max_sweeps)
        nnz = effective_num_values(support_of(alpha))
    w_star, alpha_star = refit_support(problem, support_of(alpha))
    return w_star, alpha_star, nnz, it


def tv_iterative(problem: LSQProblem, l: int, *, bisect_steps: int = 40,
                 ) -> tuple[jax.Array, jax.Array, int, int]:
    """Beyond-paper: exact-count targeting via bisection on lambda with the
    exact TV solver. Returns (w_star, alpha_star, nnz, iters)."""
    from .tv_exact import tv_solve_problem

    w = np.asarray(problem.w_hat).astype(np.float64)
    n = np.asarray(problem.counts).astype(np.float64)
    lo, hi = 0.0, float(np.sum(n * w * w)) + 1e-6
    best = None
    for it in range(bisect_steps):
        mid = 0.5 * (lo + hi)
        u = tv_solve_problem(problem, mid)
        sup = np.abs(np.diff(u, prepend=0.0)) > 1e-10
        nnz = effective_num_values(sup)
        if nnz <= l:
            best, hi = (u, nnz), mid
        else:
            lo = mid
        if best is not None and best[1] == l:
            break
    if best is None:
        u = tv_solve_problem(problem, hi)
        best = (u, effective_num_values(np.abs(np.diff(u, prepend=0.0)) > 1e-10))
    u, nnz = best
    support = jnp.asarray(np.abs(np.diff(u, prepend=0.0)) > 1e-10)
    w_star, alpha_star = refit_support(problem, support)
    return w_star, alpha_star, nnz, it + 1
