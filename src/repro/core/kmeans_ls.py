"""Algorithm 3: clustering-based least-square quantization (paper eq. 17-20).

k-means on the unique values fixes the one-hot membership matrix E; the
representative values are then the exact LS minimisers. With the paper's
cumulative matrix V-hat* parameterisation the closed-form solution (eq. 20)
equals per-cluster (count-weighted, if weighted) means over unique values -
we implement it via refit_support (clusters are intervals in 1-D, so the
cluster boundaries form a support mask) and keep a dense eq.-20 oracle in
tests to prove equivalence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kmeans import kmeans_1d
from .problem import LSQProblem
from .refit import refit_support


def kmeans_ls_quantize(problem: LSQProblem, l: int, *, seed: int = 0,
                       restarts: int = 10, max_iter: int = 300,
                       ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (w_star, alpha_star, assignment, iters)."""
    vals, counts = problem.w_hat, problem.counts
    _, idx, _, iters = kmeans_1d(vals, counts, l, seed=seed, restarts=restarts,
                                 max_iter=max_iter)
    # clusters are intervals on sorted vals: support = first index of each cluster
    prev = jnp.concatenate([jnp.full((1,), -1, idx.dtype), idx[:-1]])
    support = idx != prev
    w_star, alpha_star = refit_support(problem, support)
    return w_star, alpha_star, idx, iters


def kmeans_ls_dense_reference(problem: LSQProblem,
                              assignment: np.ndarray) -> np.ndarray:
    """Oracle: materialize E and V-hat* exactly as eq. 18-20 and solve."""
    w = np.asarray(problem.w_hat).astype(np.float64)
    n = np.asarray(problem.counts).astype(np.float64)
    idx = np.asarray(assignment)
    l = int(idx.max()) + 1
    m = w.shape[0]
    E = np.zeros((m, l))
    E[np.arange(m), idx] = 1.0
    v = float(np.mean(w))  # paper: fill non-zeros with v = mean(w_hat)
    Vstar = np.tril(np.ones((l, l))) * v
    X = E @ Vstar
    sw = np.sqrt(n)
    coef, *_ = np.linalg.lstsq(X * sw[:, None], w * sw, rcond=None)
    return X @ coef
