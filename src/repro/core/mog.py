"""Mixture-of-Gaussians quantization baseline (paper §2, [15][16]).

1-D GMM fit by EM on (unique values, multiplicities); quantized value of each
point is the mean of its most-likely component (hard assignment after EM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kmeans import kmeans_1d


@functools.partial(jax.jit, static_argnames=("k", "n_iter"))
def mog_quantize_unique(vals: jax.Array, counts: jax.Array, k: int, *,
                        seed: int = 0, n_iter: int = 100,
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (recon (m,), assignment (m,), means (k,))."""
    centers, _, _, _ = kmeans_1d(vals, counts, k, seed=seed, restarts=4)
    n_tot = jnp.sum(counts)
    var0 = jnp.maximum(jnp.sum(counts * (vals - jnp.sum(counts * vals) / n_tot) ** 2) / n_tot, 1e-12)
    state0 = (centers, jnp.full((k,), var0 / k), jnp.full((k,), 1.0 / k))

    def em(state: tuple[jax.Array, jax.Array, jax.Array], _: None,
           ) -> tuple[tuple[jax.Array, jax.Array, jax.Array], None]:
        mu, var, pi = state
        # E-step (log domain), counts as fractional repetitions
        logp = (
            jnp.log(jnp.maximum(pi, 1e-20))[None, :]
            - 0.5 * jnp.log(2 * jnp.pi * var)[None, :]
            - 0.5 * (vals[:, None] - mu[None, :]) ** 2 / var[None, :]
        )
        logr = logp - jax.scipy.special.logsumexp(logp, axis=1, keepdims=True)
        r = jnp.exp(logr) * counts[:, None]
        nk = jnp.maximum(jnp.sum(r, axis=0), 1e-12)
        mu = jnp.sum(r * vals[:, None], axis=0) / nk
        var = jnp.maximum(jnp.sum(r * (vals[:, None] - mu[None, :]) ** 2, axis=0) / nk, 1e-12)
        pi = nk / jnp.sum(nk)
        return (mu, var, pi), None

    (mu, var, pi), _ = lax.scan(em, state0, None, length=n_iter)
    logp = (
        jnp.log(jnp.maximum(pi, 1e-20))[None, :]
        - 0.5 * jnp.log(2 * jnp.pi * var)[None, :]
        - 0.5 * (vals[:, None] - mu[None, :]) ** 2 / var[None, :]
    )
    idx = jnp.argmax(logp, axis=1)
    return mu[idx], idx, mu
