"""QuantSpec: one hashable description of a quantizer configuration.

Every quantization surface in the repo (host PTQ via ``core.quantize`` /
``quant.ptq.quantize_tree``, batched device row solves for KV-page
freezing, the serving engine's ``kv_quant``, benchmark artifacts and CLI
flags) is parameterised by the same frozen dataclass:

    QuantSpec("kmeans_ls", num_values=16)
    QuantSpec("l1_ls", lam=0.02, weighted=True)

Specs round-trip through a compact string form, used by CLI flags and
test parametrisation::

    kmeans_ls@16                    count method @ budget
    l1_ls:lam=0.02                  lam method : penalty
    l1l2:lam=0.05,lam2=0.01         extra solver parameters
    kmeans_ls@16:weighted=true,seed=3,clip=-1.0..1.0

``QuantSpec.parse(str(spec)) == spec`` holds for every valid spec, and
``to_json``/``from_json`` round-trip through the dict form stored in
``BENCH_*.json`` rows so perf trajectories attribute to an exact solver
configuration.

Validation happens at construction time against ``core.registry``: unknown
methods, a count budget on a lam-parameterised method (or vice versa), and
``lam2`` on anything but ``l1l2`` all raise immediately — consumers (the
serving engine, jitted freeze functions keyed on the spec) never see a
half-legal configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from . import registry

_DEFAULTS = dict(num_values=None, lam=None, lam2=None, weighted=False,
                 clip=None, seed=0)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Frozen, hashable quantizer configuration (safe as a jit static arg).

    method      registry name (see ``core.registry.methods()``).
    num_values  codebook budget — required for count-parameterised methods,
                rejected for lam-parameterised ones.
    lam         l1 penalty — required for lam methods, rejected for count
                methods.
    lam2        negative-l2 strength, ``l1l2`` only (None = auto-stable).
    weighted    optimize the true full-vector loss (multiplicity-weighted);
                False is the paper's unique-values objective.
    clip        optional (lo, hi) hard-sigmoid on the codebook (eq. 21).
    seed        clustering init seed (kmeans/mog/dtc families).
    """

    method: str
    num_values: int | None = None
    lam: float | None = None
    lam2: float | None = None
    weighted: bool = False
    clip: tuple[float, float] | None = None
    seed: int = 0

    def __post_init__(self) -> None:
        solver = registry.get(self.method)    # raises on unknown method
        _set = object.__setattr__
        if self.num_values is not None:
            _set(self, "num_values", int(self.num_values))
        if self.lam is not None:
            _set(self, "lam", float(self.lam))
        if self.lam2 is not None:
            _set(self, "lam2", float(self.lam2))
        _set(self, "weighted", bool(self.weighted))
        _set(self, "seed", int(self.seed))
        if self.clip is not None:
            lo, hi = self.clip
            _set(self, "clip", (float(lo), float(hi)))
        if solver.param_kind == "lam":
            if self.lam is None:
                raise ValueError(
                    f"method {self.method!r} is lam-parameterised: "
                    f"QuantSpec requires lam= (e.g. '{self.method}:lam=0.02')")
            if self.num_values is not None:
                raise ValueError(
                    f"num_values= is not valid for lam-parameterised method "
                    f"{self.method!r}; count-parameterised methods: "
                    f"{', '.join(registry.count_methods())}")
        else:
            if self.num_values is None:
                raise ValueError(
                    f"method {self.method!r} is count-parameterised: "
                    f"QuantSpec requires num_values= "
                    f"(e.g. '{self.method}@16')")
            if self.num_values < 1:
                raise ValueError(f"num_values must be >= 1, got "
                                 f"{self.num_values}")
            if self.lam is not None or self.lam2 is not None:
                raise ValueError(
                    f"lam=/lam2= are not valid for count-parameterised "
                    f"method {self.method!r}; lam-parameterised methods: "
                    f"{', '.join(registry.lam_methods())}")
        if self.lam2 is not None and not solver.accepts_lam2:
            raise ValueError(f"lam2= is only valid for methods that accept "
                             f"it (l1l2), not {self.method!r}")
        if self.lam is not None and self.lam < 0:
            raise ValueError(f"lam must be >= 0, got {self.lam}")

    # ----------------------------------------------------------- registry
    @property
    def solver(self) -> registry.Solver:
        return registry.get(self.method)

    @property
    def param_kind(self) -> str:
        return self.solver.param_kind

    @property
    def device_capable(self) -> bool:
        """A batched on-device row solver exists for this method."""
        return self.solver.device_batch is not None

    def replace(self, **kw: Any) -> "QuantSpec":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------ compact string
    def __str__(self) -> str:
        head = self.method
        if self.num_values is not None:
            head += f"@{self.num_values}"
        opts: list[str] = []
        if self.lam is not None:
            opts.append(f"lam={_fmt_float(self.lam)}")
        if self.lam2 is not None:
            opts.append(f"lam2={_fmt_float(self.lam2)}")
        if self.weighted:
            opts.append("weighted=true")
        if self.clip is not None:
            opts.append(f"clip={_fmt_float(self.clip[0])}.."
                        f"{_fmt_float(self.clip[1])}")
        if self.seed != 0:
            opts.append(f"seed={self.seed}")
        return head + (":" + ",".join(opts) if opts else "")

    @classmethod
    def parse(cls, s: "str | QuantSpec") -> "QuantSpec":
        """Parse the compact string form (idempotent on QuantSpec input)."""
        if isinstance(s, QuantSpec):
            return s
        if not isinstance(s, str):
            raise TypeError(f"QuantSpec.parse wants a string or QuantSpec, "
                            f"got {type(s).__name__}")
        head, _, opts = s.strip().partition(":")
        method, _, budget = head.partition("@")
        kw: dict[str, Any] = {}
        if budget:
            try:
                kw["num_values"] = int(budget)
            except ValueError:
                raise ValueError(f"bad count budget {budget!r} in spec "
                                 f"{s!r} (want method@INT)") from None
        if opts:
            for item in opts.split(","):
                k, sep, v = item.partition("=")
                k = k.strip()
                if not sep or not k:
                    raise ValueError(f"bad option {item!r} in spec {s!r} "
                                     f"(want key=value)")
                if k in ("lam", "lam2"):
                    kw[k] = float(v)
                elif k == "num_values":
                    kw[k] = int(v)
                elif k == "weighted":
                    kw[k] = _parse_bool(v, s)
                elif k == "seed":
                    kw[k] = int(v)
                elif k == "clip":
                    lo, sep2, hi = v.partition("..")
                    if not sep2:
                        raise ValueError(f"bad clip {v!r} in spec {s!r} "
                                         f"(want clip=LO..HI)")
                    kw[k] = (float(lo), float(hi))
                else:
                    raise ValueError(f"unknown spec option {k!r} in {s!r}; "
                                     f"one of lam, lam2, num_values, "
                                     f"weighted, clip, seed")
        if not method:
            raise ValueError(f"empty method in spec {s!r}")
        return cls(method, **kw)

    # -------------------------------------------------------------- JSON
    def to_json(self) -> dict:
        """Dict form for BENCH_*.json rows (clip as a 2-list)."""
        d: dict[str, Any] = {"method": self.method}
        for k, default in _DEFAULTS.items():
            v = getattr(self, k)
            if v != default:
                d[k] = list(v) if k == "clip" else v
        d["str"] = str(self)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "QuantSpec":
        kw = {k: v for k, v in d.items() if k in _DEFAULTS}
        if kw.get("clip") is not None:
            kw["clip"] = tuple(kw["clip"])
        return cls(d["method"], **kw)


def _fmt_float(v: float) -> str:
    return repr(float(v))


def _parse_bool(v: str, spec: str) -> bool:
    lv = v.strip().lower()
    if lv in ("1", "true", "yes", "on"):
        return True
    if lv in ("0", "false", "no", "off"):
        return False
    raise ValueError(f"bad boolean {v!r} in spec {spec!r}")


def as_spec(spec: "str | QuantSpec", **replace_kw: Any) -> QuantSpec:
    """Coerce a QuantSpec | compact string to QuantSpec (with optional
    field overrides), for APIs that accept either form."""
    out = QuantSpec.parse(spec)
    return out.replace(**replace_kw) if replace_kw else out
