"""Problem setup shared by every sparse-LSQ solver (paper §3.1-3.2).

Given a vector ``w`` we pre-process to sorted unique values ``w_hat`` with
multiplicities ``counts`` (paper: ``unique(w)``). The design matrix V is the
lower-triangular cumulative matrix with column scales d (d_1 = v_1,
d_j = v_j - v_{j-1}); it is NEVER materialized:

    (V @ alpha)_i  = cumsum(alpha * d)_i
    (V.T @ r)_k    = d_k * suffix_sum(r)_k
    ||V[:,k]||^2   = d_k^2 * suffix_count(k)      (closed form, paper eq. 12)

``weighted=False`` reproduces the paper exactly (least squares on unique values);
``weighted=True`` multiplies residuals by multiplicities, minimizing the true
full-vector loss (beyond-paper improvement, see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["w_hat", "d", "counts", "z", "n_suffix"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class LSQProblem:
    """Static-shape sparse-LSQ problem on sorted unique values."""

    w_hat: jnp.ndarray    # (m,) sorted unique values (f32)
    d: jnp.ndarray        # (m,) column scales: d_1 = v_1, d_j = v_j - v_{j-1}
    counts: jnp.ndarray   # (m,) multiplicities as f32 (all-ones if unweighted)
    z: jnp.ndarray        # (m,) column norms  d_k^2 * N_k
    n_suffix: jnp.ndarray # (m,) suffix count sums N_k = sum_{i>=k} counts_i

    @property
    def m(self) -> int:
        return int(self.w_hat.shape[0])


def unique_with_counts(w: Any) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sorted unique values, multiplicities and inverse indices (host-side)."""
    flat = np.asarray(w).reshape(-1).astype(np.float64)
    vals, inverse, counts = np.unique(flat, return_inverse=True, return_counts=True)
    return vals, counts.astype(np.float64), inverse


def make_problem(w_hat: np.ndarray, counts: np.ndarray | None = None, *, weighted: bool = False) -> LSQProblem:
    w_hat = np.asarray(w_hat, dtype=np.float64)
    m = w_hat.shape[0]
    if counts is None or not weighted:
        n = np.ones(m, dtype=np.float64)
    else:
        n = np.asarray(counts, dtype=np.float64)
    d = np.diff(w_hat, prepend=0.0)
    n_suffix = np.cumsum(n[::-1])[::-1]
    z = d * d * n_suffix
    # d_1 = v_1 can be 0 if 0.0 is the smallest unique value; guard z for that column
    # (a zero column contributes nothing; alpha stays at its init there).
    z = np.where(z <= 0.0, 1.0, z)
    f32 = lambda x: jnp.asarray(x, dtype=jnp.float32)
    return LSQProblem(w_hat=f32(w_hat), d=f32(d), counts=f32(n), z=f32(z), n_suffix=f32(n_suffix))


def reconstruct(alpha: jax.Array, d: jax.Array) -> jax.Array:
    """w* on unique values: V @ alpha = cumsum(alpha * d)   (paper eq. 11)."""
    return jnp.cumsum(alpha * d)


def objective(problem: LSQProblem, alpha: jax.Array, lam1: float,
              lam2: float = 0.0, *, penalize_first: bool = True) -> jax.Array:
    """0.5 * ||sqrt(n) (w_hat - V a)||^2 + lam1 ||a||_1 - lam2 ||a||_2^2."""
    r = problem.w_hat - reconstruct(alpha, problem.d)
    pen = jnp.abs(alpha)
    if not penalize_first:
        pen = pen.at[0].set(0.0)
    return (
        0.5 * jnp.sum(problem.counts * r * r)
        + lam1 * jnp.sum(pen)
        - lam2 * jnp.sum(alpha * alpha)
    )
