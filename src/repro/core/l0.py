"""l0-constrained sparse-LSQ quantization (paper eq. 16, 'L0Learn'-style).

Penalized-l0 cyclic CD with the same O(m)-per-sweep suffix-sum structure as
cd.py, but a hard-threshold operator: keeping coordinate k at its LS value
t = g/z_k improves the smooth part by g^2/(2 z_k); it is kept iff that beats
the penalty gamma. The constrained form ||alpha||_0 <= l is reached by
bisection on gamma, which faithfully reproduces the paper's observation that
l0 'could not reach arbitrary required numbers of values' (§3.3, §4): the map
gamma -> support size is a step function and some counts are unreachable.
A local-swap pass (L0Learn's combinatorial move, simplified) follows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .problem import LSQProblem, reconstruct


def l0_sweep(alpha: jax.Array, problem: LSQProblem,
             gamma: jax.Array) -> tuple[jax.Array, jax.Array]:
    w, d, n, z, N = problem.w_hat, problem.d, problem.counts, problem.z, problem.n_suffix

    def body(carry: tuple[jax.Array, jax.Array],
             xs: tuple[jax.Array, ...],
             ) -> tuple[tuple[jax.Array, jax.Array],
                        tuple[jax.Array, jax.Array]]:
        S, c = carry
        w_k, d_k, n_k, z_k, N_k, a_old = xs
        g = d_k * S + z_k * a_old
        t = g / z_k
        keep = (g * g) / (2.0 * z_k) > gamma
        a_new = jnp.where(keep, t, 0.0)
        delta = a_new - a_old
        S = S - delta * d_k * N_k
        c = c + a_new * d_k
        S = S - n_k * (w_k - c)
        return (S, c), (a_new, jnp.abs(delta))

    r0 = w - reconstruct(alpha, d)
    S0 = jnp.sum(n * r0)
    (_, _), (alpha_new, deltas) = lax.scan(body, (S0, jnp.float32(0.0)),
                                           (w, d, n, z, N, alpha))
    return alpha_new, jnp.max(deltas)


@functools.partial(jax.jit, static_argnames=("max_sweeps",))
def l0_solve(problem: LSQProblem, gamma: jax.Array, *,
             alpha0: jax.Array | None = None, max_sweeps: int = 100,
             tol: float = 1e-7) -> jax.Array:
    m = problem.m
    if alpha0 is None:
        alpha0 = jnp.ones((m,), jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(problem.w_hat)), 1e-12)

    def cond(s: tuple[jax.Array, jax.Array, jax.Array]) -> jax.Array:
        _, it, md = s
        return jnp.logical_and(it < max_sweeps, md > tol * scale)

    def step(s: tuple[jax.Array, jax.Array, jax.Array],
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
        a, it, _ = s
        a, md = l0_sweep(a, problem, gamma)
        return a, it + 1, md

    alpha, _, _ = lax.while_loop(cond, step, (alpha0, jnp.int32(0), jnp.float32(jnp.inf)))
    return alpha


def l0_quantize(problem: LSQProblem, l: int, *, bisect_steps: int = 30,
                max_sweeps: int = 100) -> tuple[jax.Array, int]:
    """Constrained form: largest support size <= l reachable by gamma bisection.

    Returns (alpha, nnz). May return nnz < l (paper: 'non-universal') or fail
    to a trivial solution for large l - callers should check nnz.
    """
    import numpy as np

    from .refit import effective_num_values, support_of

    w = np.asarray(problem.w_hat).astype(np.float64)
    # gamma upper bound: any single-coordinate gain is bounded by the total
    # loss at alpha=0 OR by its own z_k/2 from the alpha=1 start (whichever is
    # larger) - above this every coordinate is pruned on the first sweep.
    n = np.asarray(problem.counts).astype(np.float64)
    z = np.asarray(problem.z).astype(np.float64)
    hi = float(np.sum(n * w * w) + 0.5 * z.max() + 1.0)
    lo = 0.0
    best = None
    for _ in range(bisect_steps):
        mid = 0.5 * (lo + hi)
        alpha = l0_solve(problem, jnp.float32(mid), max_sweeps=max_sweeps)
        nnz = effective_num_values(support_of(alpha))
        if nnz <= l:
            best = (alpha, nnz)
            hi = mid
        else:
            lo = mid
    if best is None:  # even the largest gamma kept > l values
        alpha = l0_solve(problem, jnp.float32(hi), max_sweeps=max_sweeps)
        best = (alpha, effective_num_values(support_of(alpha)))
    return best
