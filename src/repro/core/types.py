"""Core data types for sparse-LSQ scalar quantization.

A quantized tensor is a value-shared tensor: ``codebook[indices].reshape(shape)``.
This is the storage format the whole framework consumes (PTQ checkpoints,
quantized serving, gradient compression).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Value-shared tensor: ``dense = codebook[indices].reshape(shape)``.

    codebook: (l,) float array of distinct values (sorted ascending).
    indices:  flat integer array (uint8 if l<=256, else int32) of length prod(shape).
    shape:    original shape (static aux data).
    dtype:    original dtype (static aux data).
    """

    codebook: jax.Array
    indices: jax.Array
    shape: tuple
    dtype: Any

    @property
    def stacked(self) -> bool:
        """Stacked form: leading group axis on codebook (G, L) and indices
        (G, prod(shape)); ``shape`` describes one slice. Built by
        ``stack_quantized`` so scanned layer groups can carry per-group
        codebooks through ``lax.scan`` (which slices both children)."""
        return self.indices.ndim == 2

    def to_dense(self) -> jax.Array:
        idx = self.indices.astype(jnp.int32)
        if self.stacked:
            dense = jnp.take_along_axis(self.codebook, idx, axis=1)
            return dense.reshape((idx.shape[0],) + tuple(self.shape)
                                 ).astype(self.dtype)
        return jnp.take(self.codebook, idx, axis=0).reshape(
            self.shape
        ).astype(self.dtype)

    @property
    def num_values(self) -> int:
        return int(self.codebook.shape[-1])

    def bits_per_value(self) -> int:
        l = max(self.num_values, 2)
        return int(np.ceil(np.log2(l)))

    def nbytes(self) -> int:
        """Compressed storage footprint (codebook fp32 + packed indices)."""
        n = int(np.prod(self.shape)) * (
            self.indices.shape[0] if self.stacked else 1)
        cb = int(np.prod(self.codebook.shape))
        return cb * 4 + (n * self.bits_per_value() + 7) // 8

    def tree_flatten(self) -> tuple[tuple[jax.Array, jax.Array],
                                    tuple[tuple, Any]]:
        return (self.codebook, self.indices), (self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux: tuple[tuple, Any],
                       children: tuple[jax.Array, jax.Array],
                       ) -> "QuantizedTensor":
        codebook, indices = children
        shape, dtype = aux
        return cls(codebook=codebook, indices=indices, shape=shape, dtype=dtype)


def from_dense(w: jax.Array, reconstructed_unique: np.ndarray, inverse_idx: np.ndarray) -> QuantizedTensor:
    """Build a QuantizedTensor from per-unique-value reconstruction.

    reconstructed_unique: (m,) quantized value assigned to each *unique* input value.
    inverse_idx: (n,) index into the unique array for each flat element of ``w``.
    """
    recon = np.asarray(reconstructed_unique)
    codebook, code_of_unique = np.unique(recon, return_inverse=True)
    indices = code_of_unique[np.asarray(inverse_idx)]
    idx_dtype = np.uint8 if codebook.shape[0] <= 256 else np.int32
    dtype = w.dtype
    if dtype == np.float64:  # jax runs f32 unless x64 is enabled
        dtype = np.dtype(np.float32)
    return QuantizedTensor(
        codebook=jnp.asarray(codebook, dtype=jnp.float32),
        indices=jnp.asarray(indices.astype(idx_dtype)),
        shape=tuple(w.shape),
        dtype=dtype,
    )


def stack_quantized(qts: list[QuantizedTensor]) -> QuantizedTensor:
    """Stack per-slice QuantizedTensors (same shape) into the stacked form:
    codebook (G, L) / indices (G, n). Codebooks shorter than the widest are
    right-padded with their last value (codes never reference the padding),
    so every slice shares one static width for lax.scan."""
    assert len({qt.shape for qt in qts}) == 1, "slices must share a shape"
    L = max(qt.num_values for qt in qts)
    cbs: list[np.ndarray] = []
    for qt in qts:
        cb = np.asarray(qt.codebook, np.float32)
        if cb.shape[0] < L:
            cb = np.concatenate([cb, np.full(L - cb.shape[0], cb[-1],
                                             np.float32)])
        cbs.append(cb)
    idx_dtype = np.uint8 if L <= 256 else np.int32
    idx = np.stack([np.asarray(qt.indices, idx_dtype) for qt in qts])
    return QuantizedTensor(
        codebook=jnp.asarray(np.stack(cbs)),
        indices=jnp.asarray(idx),
        shape=qts[0].shape,
        dtype=qts[0].dtype,
    )


def hard_sigmoid(x: jax.Array, a: float, b: float) -> jax.Array:
    """Eq. 21 of the paper: clamp quantized outputs into a legal range [a, b]."""
    return jnp.clip(x, a, b)
