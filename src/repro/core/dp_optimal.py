"""Optimal 1-D k-segment quantizer via divide-and-conquer DP (beyond-paper).

Ckmeans.1d.dp-style: D[k][j] = min_i D[k-1][i-1] + cost(i, j) with cost the
weighted within-segment squared error (O(1) via prefix sums). The argmin is
monotone in j, so each layer solves in O(m log m) by divide and conquer.
This is the true information-loss lower bound for ANY l-value scalar
quantizer - used in EXPERIMENTS.md to score every method (including k-means,
which is only locally optimal).
"""
from __future__ import annotations

import numpy as np


def optimal_kmeans_1d(vals: np.ndarray, counts: np.ndarray, k: int,
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray, float]:
    """Returns (recon (m,), assignment (m,), centers (k',), sse). k' <= k."""
    y = np.asarray(vals, np.float64)
    n = np.asarray(counts, np.float64)
    m = y.shape[0]
    k = min(k, m)
    # prefix sums for O(1) weighted segment cost over [i, j] inclusive
    cn = np.concatenate([[0.0], np.cumsum(n)])
    cy = np.concatenate([[0.0], np.cumsum(n * y)])
    cy2 = np.concatenate([[0.0], np.cumsum(n * y * y)])

    def cost(i: int, j: int) -> float:  # segment [i, j], 0-indexed inclusive
        sn = cn[j + 1] - cn[i]
        sy = cy[j + 1] - cy[i]
        sy2 = cy2[j + 1] - cy2[i]
        if sn <= 0:
            return 0.0
        return sy2 - sy * sy / sn

    INF = np.inf
    prev = np.array([cost(0, j) for j in range(m)])
    back = np.zeros((k, m), dtype=np.int64)

    for layer in range(1, k):
        cur = np.full(m, INF)

        def solve(jlo: int, jhi: int, ilo: int, ihi: int) -> None:
            if jlo > jhi:
                return
            jmid = (jlo + jhi) // 2
            best, arg = INF, ilo
            for i in range(ilo, min(ihi, jmid) + 1):
                c = (prev[i - 1] if i > 0 else (0.0 if layer <= 0 else INF)) + cost(i, jmid)
                # i must be >= layer so that layers 0..layer-1 each hold >= 1 point
                if i >= layer and c < best:
                    best, arg = c, i
            cur[jmid] = best
            back[layer, jmid] = arg
            solve(jlo, jmid - 1, ilo, arg)
            solve(jmid + 1, jhi, arg, ihi)

        solve(layer, m - 1, layer, m - 1)
        prev = cur

    # pick the best number of segments <= k ending at m-1 is just layer k-1;
    # fewer distinct values can never be better, so use k (or m) segments.
    sse = prev[m - 1] if k > 1 else cost(0, m - 1)
    # backtrack boundaries
    bounds: list[int] = []
    j = m - 1
    for layer in range(k - 1, 0, -1):
        i = int(back[layer, j])
        bounds.append(i)
        j = i - 1
    bounds = sorted(bounds)
    starts = np.array([0] + bounds, dtype=np.int64)
    assignment = np.zeros(m, dtype=np.int64)
    for s_idx, s in enumerate(starts):
        assignment[s:] = s_idx
    centers = np.empty(len(starts))
    ends = np.concatenate([starts[1:], [m]])
    for s_idx, (s, e) in enumerate(zip(starts, ends)):
        sn = cn[e] - cn[s]
        centers[s_idx] = (cy[e] - cy[s]) / max(sn, 1e-300)
    recon = centers[assignment]
    return recon, assignment, centers, float(sse)
