"""Public quantization API: one spec-driven surface over the solver registry.

The paper contributes a *family* of interchangeable solvers for scalar
quantization as sparse least-square optimization. A quantizer configuration
is a :class:`~repro.core.spec.QuantSpec` — frozen, hashable, and
round-trippable through compact strings — and ``quantize`` is a thin
driver that builds the sorted-unique problem and dispatches to the
method's registry entry::

    from repro.core import QuantSpec, quantize

    qt, info = quantize(w, QuantSpec("kmeans_ls", num_values=16))
    qt, info = quantize(w, "l1_ls:lam=0.02")       # compact string form
    w_approx = qt.to_dense()

Methods (see ``core.registry`` for the authoritative list + capabilities):

  paper        l1 (eq. 6), l1_ls (alg. 1), l1l2 (eq. 13), l0 (eq. 16),
               iter_l1 (alg. 2), kmeans_ls (alg. 3)
  baselines    kmeans, mog, dtc (paper §4)
  beyond-paper tv (exact O(m) global optimum of eq. 6), tv_iter
               (exact-count via lambda bisection on tv), dp (optimal 1-D
               quantizer, loss lower bound)

lam-parameterised methods (l1/l1_ls/l1l2/tv) take ``lam``;
count-parameterised methods take ``num_values`` — the spec rejects the
wrong kind at construction. ``weighted=True`` optimizes the true
full-vector loss; False is the paper's unique-values objective.
``clip=(a,b)`` applies the paper's hard-sigmoid (eq. 21) to the codebook.

Methods with a batched device backend (``registry.device_methods()``:
kmeans_ls, kmeans, iter_l1) additionally solve many rows per kernel
dispatch for the serving engine's KV-page freezing; ``quantize`` itself is
the host reference path.

The pre-spec kwargs signature ``quantize(w, method=..., num_values=...)``
still works as a deprecation shim (it warns and builds the equivalent
spec).
"""
from __future__ import annotations

import time
import warnings
from typing import Any

import numpy as np

from . import registry, types
from .problem import make_problem, unique_with_counts
from .spec import QuantSpec

# Backward-compatible capability tuples, now derived from the registry.
LAM_METHODS = registry.lam_methods()
COUNT_METHODS = registry.count_methods()
ALL_METHODS = registry.methods()

_UNSET = object()
_LEGACY_KEYS = ("num_values", "lam", "lam2", "weighted", "clip", "seed")


def resolve_spec(spec: QuantSpec | str | None = None, *, method: Any = _UNSET,
                 num_values: Any = _UNSET, lam: Any = _UNSET,
                 lam2: Any = _UNSET, weighted: Any = _UNSET,
                 clip: Any = _UNSET, seed: Any = _UNSET,
                 _warn_stacklevel: int = 3) -> QuantSpec:
    """Coerce (spec | spec-string | legacy kwargs) to a validated QuantSpec.

    Shared by every shimmed entry point (``quantize``, ``quantize_tree``,
    ``freeze_blocks``, the serving engine): a QuantSpec or a string
    containing '@'/':' is the new-style path; a bare method name plus
    loose kwargs is the legacy path and warns.
    """
    passed = {k: v for k, v in dict(
        num_values=num_values, lam=lam, lam2=lam2, weighted=weighted,
        clip=clip, seed=seed).items() if v is not _UNSET}
    if isinstance(spec, QuantSpec) or (
            isinstance(spec, str) and ("@" in spec or ":" in spec)):
        if method is not _UNSET or passed:
            bad = ", ".join((["method"] if method is not _UNSET else [])
                            + list(passed))
            raise TypeError(
                f"got both a QuantSpec ({spec!s}) and loose quantizer "
                f"kwargs ({bad}); fold them into the spec, e.g. "
                f"'kmeans_ls@16:weighted=true'")
        return QuantSpec.parse(spec)
    if isinstance(spec, str):
        name = spec
    elif spec is None and isinstance(method, str):
        name = method
    else:
        raise TypeError(
            "quantize API needs a QuantSpec, a spec string like "
            "'kmeans_ls@16' / 'l1_ls:lam=0.02', or (deprecated) a method "
            f"name plus kwargs; got spec={spec!r}, method={method!r}")
    out = QuantSpec(name, **passed)
    warnings.warn(
        f"loose quantizer kwargs (method={name!r}, "
        f"{', '.join(f'{k}={v!r}' for k, v in passed.items()) or 'no params'}"
        f") are deprecated; pass the spec {str(out)!r} (string or QuantSpec) "
        f"instead", DeprecationWarning, stacklevel=_warn_stacklevel)
    return out


def quantize(w: Any, spec: QuantSpec | str | None = None, *,
             method: Any = _UNSET, num_values: Any = _UNSET, lam: Any = _UNSET,
             lam2: Any = _UNSET, weighted: Any = _UNSET, clip: Any = _UNSET,
             seed: Any = _UNSET,
             **kw: Any) -> tuple[types.QuantizedTensor, dict]:
    """Quantize any array into a value-shared QuantizedTensor.

    ``spec`` is a QuantSpec or compact spec string; the loose
    method/num_values/lam/... kwargs are the deprecated pre-spec surface.
    Extra ``**kw`` (e.g. ``max_sweeps``, ``bisect_steps``) pass through to
    the method's host solver.
    """
    spec = resolve_spec(spec, method=method, num_values=num_values, lam=lam,
                        lam2=lam2, weighted=weighted, clip=clip, seed=seed)
    t0 = time.perf_counter()
    solver = registry.get(spec.method)
    w_np = np.asarray(w)
    vals, counts, inverse = unique_with_counts(w_np)
    problem = make_problem(vals, counts, weighted=spec.weighted)
    m = problem.m
    info: dict[str, Any] = {"m_unique": m, "method": spec.method,
                            "spec": spec.to_json()}
    budget = (None if spec.num_values is None
              else int(min(spec.num_values, m)))
    ctx = registry.HostSolveContext(problem=problem, vals=vals, counts=counts,
                                    num_values=budget, info=info)
    recon, alpha = solver.host_solve(ctx, spec, **kw)

    recon = np.asarray(recon).astype(np.float64)
    if spec.clip is not None:
        recon = np.clip(recon, spec.clip[0], spec.clip[1])  # eq. 21
    qt = types.from_dense(w_np, recon, inverse)
    full = np.asarray(qt.to_dense()).reshape(-1).astype(np.float64)
    flat = np.asarray(w_np).reshape(-1).astype(np.float64)
    info.update(
        n_values=qt.num_values,
        l2_loss=float(np.sum((flat - full) ** 2)),
        l2_loss_unique=float(np.sum((vals - recon) ** 2)),
        time_s=time.perf_counter() - t0,
        compressed_bytes=qt.nbytes(),
    )
    if alpha is not None:
        info["alpha"] = np.asarray(alpha)
    return qt, info
