"""Public quantization API (the paper's contribution as a composable module).

    qt, info = quantize(w, method="l1_ls", num_values=16)
    w_approx  = qt.to_dense()

Methods (paper):
  "l1"        eq. 6   - raw l1 CD (no refit)
  "l1_ls"     alg. 1  - l1 CD + LS refit on the support
  "l1l2"      eq. 13  - l1 + negative-l2 CD (+ refit)
  "l0"        eq. 16  - l0-constrained CD w/ gamma bisection
  "iter_l1"   alg. 2  - lambda-ramp to reach <= num_values
  "kmeans_ls" alg. 3  - k-means support + LS values
Baselines (paper §4): "kmeans", "mog", "dtc".
Beyond-paper: "tv" (exact O(m) global optimum of eq. 6),
  "tv_iter" (exact-count via lambda bisection on tv),
  "dp" (optimal 1-D quantizer, loss lower bound).

lam-parameterised methods (l1/l1_ls/l1l2/tv) take ``lam``; count-parameterised
methods take ``num_values``. ``weighted=True`` optimizes the true full-vector
loss; False is the paper's unique-values objective. ``clip=(a,b)`` applies the
paper's hard-sigmoid (eq. 21) to the codebook.
"""
from __future__ import annotations

import time
from typing import Any

import jax.numpy as jnp
import numpy as np

from . import types
from .cd import cd_solve, max_stable_lam2
from .dp_optimal import optimal_kmeans_1d
from .dtc import dtc_quantize_unique
from .iterative import iterative_l1, tv_iterative
from .kmeans import kmeans_quantize_unique
from .kmeans_ls import kmeans_ls_quantize
from .l0 import l0_quantize
from .mog import mog_quantize_unique
from .problem import make_problem, reconstruct, unique_with_counts
from .refit import refit_support, support_of
from .tv_exact import tv_solve_problem

LAM_METHODS = ("l1", "l1_ls", "l1l2", "tv")
COUNT_METHODS = ("l0", "iter_l1", "kmeans_ls", "kmeans", "mog", "dtc", "dp", "tv_iter")
ALL_METHODS = LAM_METHODS + COUNT_METHODS


def quantize(
    w,
    method: str = "l1_ls",
    *,
    num_values: int | None = None,
    lam: float | None = None,
    lam2: float | None = None,
    weighted: bool = False,
    clip: tuple[float, float] | None = None,
    seed: int = 0,
    **kw: Any,
) -> tuple[types.QuantizedTensor, dict]:
    """Quantize any array into a value-shared QuantizedTensor."""
    t0 = time.perf_counter()
    w_np = np.asarray(w)
    vals, counts, inverse = unique_with_counts(w_np)
    problem = make_problem(vals, counts, weighted=weighted)
    m = problem.m
    info: dict[str, Any] = {"m_unique": m, "method": method}

    if method in LAM_METHODS and lam is None:
        raise ValueError(f"method {method!r} requires lam=")
    if method in COUNT_METHODS and num_values is None:
        raise ValueError(f"method {method!r} requires num_values=")
    if num_values is not None:
        num_values = int(min(num_values, m))

    if method == "l1":
        alpha, sweeps = cd_solve(problem, jnp.float32(lam), **kw)
        recon = reconstruct(alpha, problem.d)
        info["sweeps"] = int(sweeps)
    elif method == "l1_ls":
        alpha, sweeps = cd_solve(problem, jnp.float32(lam), **kw)
        recon, alpha = refit_support(problem, support_of(alpha))
        info["sweeps"] = int(sweeps)
    elif method == "l1l2":
        if lam2 is None:
            lam2 = 0.25 * max_stable_lam2(problem)
        else:
            lam2 = min(lam2, 0.49 * max_stable_lam2(problem))  # keep convex (DESIGN §8)
        alpha, sweeps = cd_solve(problem, jnp.float32(lam), jnp.float32(lam2), **kw)
        recon, alpha = refit_support(problem, support_of(alpha))
        info["sweeps"] = int(sweeps)
        info["lam2"] = float(lam2)
    elif method == "tv":
        u = tv_solve_problem(problem, float(lam))
        support = jnp.asarray(np.abs(np.diff(u, prepend=0.0)) > 1e-10)
        recon, alpha = refit_support(problem, support)
    elif method == "l0":
        alpha, nnz = l0_quantize(problem, num_values, **kw)
        recon, alpha = refit_support(problem, support_of(alpha))
        info["nnz"] = nnz
    elif method == "iter_l1":
        recon, alpha, nnz, iters = iterative_l1(problem, num_values, **kw)
        info.update(nnz=nnz, iters=iters)
    elif method == "tv_iter":
        recon, alpha, nnz, iters = tv_iterative(problem, num_values, **kw)
        info.update(nnz=nnz, iters=iters)
    elif method == "kmeans_ls":
        recon, alpha, _, iters = kmeans_ls_quantize(problem, num_values, seed=seed, **kw)
        info["lloyd_iters"] = int(iters)
    elif method == "kmeans":
        recon, _, _, inertia, iters = kmeans_quantize_unique(
            problem.w_hat, problem.counts, num_values, seed=seed, **kw)
        alpha = None
        info.update(inertia=float(inertia), lloyd_iters=int(iters))
    elif method == "mog":
        recon, _, _ = mog_quantize_unique(problem.w_hat, problem.counts, num_values,
                                          seed=seed, **kw)
        alpha = None
    elif method == "dtc":
        recon, _, _ = dtc_quantize_unique(problem.w_hat, problem.counts, num_values,
                                          seed=seed, **kw)
        alpha = None
    elif method == "dp":
        recon, _, _, sse = optimal_kmeans_1d(vals, counts if weighted else np.ones_like(counts),
                                             num_values)
        alpha = None
        info["sse_unique"] = sse
    else:
        raise ValueError(f"unknown method {method!r}; one of {ALL_METHODS}")

    recon = np.asarray(recon).astype(np.float64)
    if clip is not None:
        recon = np.clip(recon, clip[0], clip[1])  # hard-sigmoid, eq. 21
    qt = types.from_dense(w_np, recon, inverse)
    full = np.asarray(qt.to_dense()).reshape(-1).astype(np.float64)
    flat = np.asarray(w_np).reshape(-1).astype(np.float64)
    info.update(
        n_values=qt.num_values,
        l2_loss=float(np.sum((flat - full) ** 2)),
        l2_loss_unique=float(np.sum((vals - recon) ** 2)),
        time_s=time.perf_counter() - t0,
        compressed_bytes=qt.nbytes(),
    )
    if alpha is not None:
        info["alpha"] = np.asarray(alpha)
    return qt, info
