"""1-D (weighted) k-means quantization baseline (paper's main comparison).

Lloyd's algorithm specialised to scalars: data is sorted unique values with
multiplicities, so assignment is a searchsorted against centroid midpoints
(clusters are intervals in 1-D) and the update is a segment mean - both O(m).
k-means++ initialisation, multi-restart (the paper uses sklearn's default of
10 restarts), empty clusters keep their previous centroid (the paper calls out
empty/out-of-range clusters as a k-means failure mode; ++ init avoids the
out-of-range case entirely).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def _assign(vals: jax.Array, centers: jax.Array) -> jax.Array:
    """Interval assignment: cluster id per value, given sorted centers."""
    mid = 0.5 * (centers[1:] + centers[:-1])
    return jnp.searchsorted(mid, vals)


def _lloyd(vals: jax.Array, counts: jax.Array, centers0: jax.Array,
           max_iter: int, tol: float,
           ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    k = centers0.shape[0]

    def cond(state: tuple[jax.Array, jax.Array, jax.Array]) -> jax.Array:
        centers, prev, it = state
        return jnp.logical_and(it < max_iter, jnp.max(jnp.abs(centers - prev)) > tol)

    def step(state: tuple[jax.Array, jax.Array, jax.Array],
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
        centers, _, it = state
        idx = _assign(vals, centers)
        num = jax.ops.segment_sum(counts * vals, idx, num_segments=k)
        den = jax.ops.segment_sum(counts, idx, num_segments=k)
        new = jnp.where(den > 0, num / jnp.maximum(den, 1e-20), centers)
        new = jnp.sort(new)  # keep interval invariant
        return new, centers, it + 1

    centers, _, iters = lax.while_loop(
        cond, step, (jnp.sort(centers0), centers0 + jnp.inf, jnp.int32(0))
    )
    idx = _assign(vals, centers)
    inertia = jnp.sum(counts * (vals - centers[idx]) ** 2)
    return centers, idx, inertia, iters


def _kmeanspp(vals: jax.Array, counts: jax.Array, k: int,
              key: jax.Array) -> jax.Array:
    """Weighted k-means++ seeding."""
    m = vals.shape[0]
    key, sub = jax.random.split(key)
    first = jax.random.categorical(sub, jnp.log(jnp.maximum(counts, 1e-20)))
    centers = jnp.full((k,), vals[first])
    d2 = (vals - vals[first]) ** 2

    def body(carry: tuple[jax.Array, jax.Array, jax.Array],
             key_i: jax.Array,
             ) -> tuple[tuple[jax.Array, jax.Array, jax.Array], None]:
        centers, d2, i = carry
        logits = jnp.log(jnp.maximum(counts * d2, 1e-30))
        nxt = jax.random.categorical(key_i, logits)
        centers = centers.at[i].set(vals[nxt])
        d2 = jnp.minimum(d2, (vals - vals[nxt]) ** 2)
        return (centers, d2, i + 1), None

    keys = jax.random.split(key, k - 1) if k > 1 else jnp.zeros((0, 2), jnp.uint32)
    (centers, _, _), _ = lax.scan(body, (centers, d2, jnp.int32(1)), keys)
    return centers


@functools.partial(jax.jit, static_argnames=("k", "restarts", "max_iter"))
def kmeans_1d(vals: jax.Array, counts: jax.Array, k: int, *, seed: int = 0,
              restarts: int = 10, max_iter: int = 300, tol: float = 1e-7,
              ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Weighted 1-D k-means. Returns (centers (k,), assignment (m,), inertia, iters).

    vals must be sorted ascending (unique values); counts are multiplicities
    (pass ones for the paper's unweighted setting on unique values).
    """
    keys = jax.random.split(jax.random.PRNGKey(seed), restarts)

    def one(key: jax.Array,
            ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        c0 = _kmeanspp(vals, counts, k, key)
        return _lloyd(vals, counts, c0, max_iter, tol)

    centers, idx, inertia, iters = jax.vmap(one)(keys)
    best = jnp.argmin(inertia)
    return centers[best], idx[best], inertia[best], jnp.sum(iters)


def kmeans_quantize_unique(
        vals: jax.Array, counts: jax.Array, k: int, *, seed: int = 0,
        restarts: int = 10, max_iter: int = 300,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Reconstruction on unique values using plain k-means centroids."""
    centers, idx, inertia, iters = kmeans_1d(vals, counts, k, seed=seed,
                                             restarts=restarts, max_iter=max_iter)
    return centers[idx], idx, centers, inertia, iters
