"""Solver registry: one entry per quantization method.

The paper's contribution is a *family* of interchangeable solvers for the
same sparse least-square objective. This registry is the single place a
method's capabilities are declared:

  param_kind          "lam" (penalty-parameterised: l1/l1_ls/l1l2/tv) or
                      "count" (budget-parameterised: kmeans_ls, l0, ...).
                      ``QuantSpec`` validates its parameters against this at
                      construction time.
  host_solve          the reference host path ``(ctx, spec, **kw) ->
                      (recon, alpha)`` on the sorted-unique problem
                      (``core.api.quantize`` is a thin driver over it).
  device_batch        dotted reference ("module:function") to a batched
                      on-device row solver ``(rows, spec) -> (codes, cb)``
                      used by KV-page freezing; resolved lazily so the core
                      package never imports kernel code at import time.
  tree_batched        the method can quantize a whole parameter tree in one
                      batched kernel launch (``quant.ptq.quantize_tree``'s
                      FISTA path).

Adding a solver is a single ``register(Solver(...))`` call; every consumer
(``quantize``, PTQ, the serving engine's freeze path, benchmarks, CLI flag
validation) discovers it from here.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import TYPE_CHECKING, Any, Callable

import jax.numpy as jnp
import numpy as np

from .cd import cd_solve, max_stable_lam2
from .dp_optimal import optimal_kmeans_1d
from .dtc import dtc_quantize_unique
from .iterative import iterative_l1, tv_iterative
from .kmeans import kmeans_quantize_unique
from .kmeans_ls import kmeans_ls_quantize
from .l0 import l0_quantize
from .mog import mog_quantize_unique
from .problem import LSQProblem, reconstruct
from .refit import refit_support, support_of
from .tv_exact import tv_solve_problem

if TYPE_CHECKING:
    from .spec import QuantSpec


@dataclasses.dataclass
class HostSolveContext:
    """What a host solver sees: the sorted-unique problem plus the raw
    unique values/counts (float64, for solvers that want full precision)
    and the count budget already clamped to ``m``. ``info`` is the
    quantize() report dict solvers append diagnostics to."""

    problem: LSQProblem
    vals: np.ndarray
    counts: np.ndarray
    num_values: int | None
    info: dict


@dataclasses.dataclass(frozen=True)
class Solver:
    """Registry entry declaring one method's parameterisation and backends."""

    name: str
    param_kind: str                       # "lam" | "count"
    host_solve: Callable[..., Any]
    device_batch: str | None = None       # "module:function", lazy-resolved
    accepts_lam2: bool = False
    tree_batched: bool = False
    description: str = ""

    def __post_init__(self) -> None:
        assert self.param_kind in ("lam", "count"), self.param_kind


_REGISTRY: dict[str, Solver] = {}


def register(solver: Solver) -> Solver:
    _REGISTRY[solver.name] = solver
    return solver


def get(name: str) -> Solver:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown quantization method {name!r}; registered methods: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def methods() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def lam_methods() -> tuple[str, ...]:
    return tuple(n for n, s in _REGISTRY.items() if s.param_kind == "lam")


def count_methods() -> tuple[str, ...]:
    return tuple(n for n, s in _REGISTRY.items() if s.param_kind == "count")


def device_methods() -> tuple[str, ...]:
    """Methods with a batched on-device row solver (KV freezing needs no
    per-page host numpy for these)."""
    return tuple(n for n, s in _REGISTRY.items() if s.device_batch)


_DEVICE_CACHE: dict[str, Callable] = {}


def device_batch_solve(name: str) -> Callable:
    """Resolve a method's device row solver ``(rows, spec) -> (codes, cb)``.

    The reference is a dotted "module:function" string so importing
    ``repro.core`` never pulls in kernel/accelerator code; the import
    happens on first use (the serving freeze path).
    """
    solver = get(name)
    if not solver.device_batch:
        raise ValueError(
            f"method {name!r} has no batched device solver; device-capable "
            f"methods: {', '.join(device_methods())}")
    fn = _DEVICE_CACHE.get(name)
    if fn is None:
        mod, _, attr = solver.device_batch.partition(":")
        fn = getattr(importlib.import_module(mod), attr)
        _DEVICE_CACHE[name] = fn
    return fn


# --------------------------------------------------------------- host solvers
# Each closes over the module that implements it; signature
# (ctx, spec, **kw) -> (recon, alpha_or_None). ``kw`` carries solver extras
# (max_sweeps, bisect_steps, ...) passed through quantize().


def _solve_l1(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    alpha, sweeps = cd_solve(ctx.problem, jnp.float32(spec.lam), **kw)
    ctx.info["sweeps"] = int(sweeps)
    return reconstruct(alpha, ctx.problem.d), alpha


def _solve_l1_ls(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    alpha, sweeps = cd_solve(ctx.problem, jnp.float32(spec.lam), **kw)
    ctx.info["sweeps"] = int(sweeps)
    return refit_support(ctx.problem, support_of(alpha))


def _solve_l1l2(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    lam2 = spec.lam2
    if lam2 is None:
        lam2 = 0.25 * max_stable_lam2(ctx.problem)
    else:
        lam2 = min(lam2, 0.49 * max_stable_lam2(ctx.problem))  # keep convex
    alpha, sweeps = cd_solve(ctx.problem, jnp.float32(spec.lam),
                             jnp.float32(lam2), **kw)
    ctx.info["sweeps"] = int(sweeps)
    ctx.info["lam2"] = float(lam2)
    return refit_support(ctx.problem, support_of(alpha))


def _solve_tv(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    u = tv_solve_problem(ctx.problem, float(spec.lam), **kw)
    support = jnp.asarray(np.abs(np.diff(u, prepend=0.0)) > 1e-10)
    return refit_support(ctx.problem, support)


def _solve_l0(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    alpha, nnz = l0_quantize(ctx.problem, ctx.num_values, **kw)
    ctx.info["nnz"] = nnz
    return refit_support(ctx.problem, support_of(alpha))


def _solve_iter_l1(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    recon, alpha, nnz, iters = iterative_l1(ctx.problem, ctx.num_values, **kw)
    ctx.info.update(nnz=nnz, iters=iters)
    return recon, alpha


def _solve_tv_iter(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    recon, alpha, nnz, iters = tv_iterative(ctx.problem, ctx.num_values, **kw)
    ctx.info.update(nnz=nnz, iters=iters)
    return recon, alpha


def _solve_kmeans_ls(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    recon, alpha, _, iters = kmeans_ls_quantize(ctx.problem, ctx.num_values,
                                                seed=spec.seed, **kw)
    ctx.info["lloyd_iters"] = int(iters)
    return recon, alpha


def _solve_kmeans(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    recon, _, _, inertia, iters = kmeans_quantize_unique(
        ctx.problem.w_hat, ctx.problem.counts, ctx.num_values,
        seed=spec.seed, **kw)
    ctx.info.update(inertia=float(inertia), lloyd_iters=int(iters))
    return recon, None


def _solve_mog(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    recon, _, _ = mog_quantize_unique(ctx.problem.w_hat, ctx.problem.counts,
                                      ctx.num_values, seed=spec.seed, **kw)
    return recon, None


def _solve_dtc(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    recon, _, _ = dtc_quantize_unique(ctx.problem.w_hat, ctx.problem.counts,
                                      ctx.num_values, seed=spec.seed, **kw)
    return recon, None


def _solve_dp(ctx: HostSolveContext, spec: "QuantSpec",
              **kw: Any) -> tuple[Any, Any]:
    recon, _, _, sse = optimal_kmeans_1d(
        ctx.vals,
        ctx.counts if spec.weighted else np.ones_like(ctx.counts),
        ctx.num_values, **kw)
    ctx.info["sse_unique"] = sse
    return recon, None


# --------------------------------------------------------------- registration

register(Solver("l1", "lam", _solve_l1,
                description="eq. 6 - raw l1 CD (no refit)"))
register(Solver("l1_ls", "lam", _solve_l1_ls, tree_batched=True,
                description="alg. 1 - l1 CD + LS refit on the support "
                            "(tree-batched via the FISTA Pallas kernel)"))
register(Solver("l1l2", "lam", _solve_l1l2, accepts_lam2=True,
                description="eq. 13 - l1 + negative-l2 CD (+ refit)"))
register(Solver("tv", "lam", _solve_tv,
                description="beyond-paper exact O(m) global optimum of eq. 6"))
register(Solver("l0", "count", _solve_l0,
                description="eq. 16 - l0-constrained CD w/ gamma bisection"))
register(Solver("iter_l1", "count", _solve_iter_l1,
                device_batch="repro.kernels.page_quant:quantize_pages_fista_spec",
                description="alg. 2 - lambda-ramp to <= num_values; device "
                            "backend: batched FISTA + per-row lam bisection"))
register(Solver("tv_iter", "count", _solve_tv_iter,
                description="exact-count via lambda bisection on tv"))
register(Solver("kmeans_ls", "count", _solve_kmeans_ls,
                device_batch="repro.kernels.page_quant:quantize_pages_kmeans_spec",
                description="alg. 3 - k-means support + LS values"))
register(Solver("kmeans", "count", _solve_kmeans,
                device_batch="repro.kernels.page_quant:quantize_pages_kmeans_raw_spec",
                description="baseline §4 - plain 1-D k-means"))
register(Solver("mog", "count", _solve_mog,
                description="baseline §4 - mixture-of-Gaussians EM"))
register(Solver("dtc", "count", _solve_dtc,
                description="baseline §4 - decision-tree clustering"))
register(Solver("dp", "count", _solve_dp,
                description="optimal 1-D quantizer (loss lower bound)"))
