"""Least-square refit on the l1 support (paper eq. 7-10, Algorithm 1 steps 3-5).

Because the selected columns of V span piecewise-constant vectors with
breakpoints at the support indices, the LS refit has a closed form: each
segment's value is the (count-weighted) mean of w_hat over that segment
(DESIGN.md §1.3). Rows before the first support index reconstruct to 0, as in
the paper's V* formulation. A dense lstsq oracle is kept for tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .problem import LSQProblem


@functools.partial(jax.jit, static_argnames=())
def refit_support(problem: LSQProblem, support: jnp.ndarray,
                  ) -> tuple[jax.Array, jax.Array]:
    """Optimal piecewise-constant reconstruction given a boolean support mask.

    Returns (w_star, alpha_star): reconstruction on unique values (m,) and the
    refit alpha vector (eq. 10; zeros off-support).
    """
    m = problem.m
    w, n = problem.w_hat, problem.counts
    seg_id = jnp.cumsum(support.astype(jnp.int32)) - 1  # -1 before first support
    valid = seg_id >= 0
    sid = jnp.where(valid, seg_id, 0)
    num = jax.ops.segment_sum(jnp.where(valid, n * w, 0.0), sid, num_segments=m)
    den = jax.ops.segment_sum(jnp.where(valid, n, 0.0), sid, num_segments=m)
    seg_mean = num / jnp.maximum(den, 1e-20)
    w_star = jnp.where(valid, seg_mean[sid], 0.0)
    # alpha* (eq. 10): jump sizes at support positions scaled by 1/d_k
    prev = jnp.concatenate([jnp.zeros((1,), w_star.dtype), w_star[:-1]])
    jump = w_star - prev
    d_safe = jnp.where(problem.d == 0, 1.0, problem.d)
    alpha_star = jnp.where(support, jump / d_safe, 0.0)
    return w_star, alpha_star


def refit_support_dense_reference(problem: LSQProblem,
                                  support: np.ndarray) -> np.ndarray:
    """Oracle: materialize V*, solve eq. 9 by lstsq. Tests only."""
    w = np.asarray(problem.w_hat).astype(np.float64)
    d = np.asarray(problem.d).astype(np.float64)
    n = np.asarray(problem.counts).astype(np.float64)
    m = w.shape[0]
    V = np.tril(np.ones((m, m))) * d[None, :]
    Vs = V[:, np.asarray(support, bool)]
    sw = np.sqrt(n)
    coef, *_ = np.linalg.lstsq(Vs * sw[:, None], w * sw, rcond=None)
    return Vs @ coef


def support_of(alpha: jax.Array, tol: float = 1e-10) -> jax.Array:
    return jnp.abs(alpha) > tol


def effective_num_values(support: np.ndarray | jax.Array) -> int:
    """Distinct values of the reconstruction for a support mask.

    If index 0 is off-support, rows before the first support index reconstruct
    to the extra value 0 (paper's V* leaves them uncovered) - count it.
    """
    s = np.asarray(support)
    return int(s.sum()) + (0 if (s.size and s[0]) else 1)
