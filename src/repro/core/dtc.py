"""Data-transformation clustering baseline (paper ref [9], Azimi et al. 2017).

Approximation note (DESIGN.md §7): [9] clusters after a density-equalising data
transformation. We implement the 1-D specialisation: a weighted quantile
(rank) transform maps values to [0,1] (equal-density space), k-means runs in
transformed space (which reduces to near-equal-frequency intervals), and
representatives are the count-weighted means of the original values per
cluster. This matches the paper's qualitative finding that the method is
competitive on NN weights but weaker on skewed synthetic data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kmeans import kmeans_1d


@functools.partial(jax.jit, static_argnames=("k",))
def dtc_quantize_unique(vals: jax.Array, counts: jax.Array, k: int, *,
                        seed: int = 0,
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (recon (m,), assignment (m,), centers (k,))."""
    m = vals.shape[0]
    # weighted quantile transform (midpoint rank)
    cum = jnp.cumsum(counts)
    total = cum[-1]
    u = (cum - 0.5 * counts) / jnp.maximum(total, 1e-20)
    # cluster in transformed space
    _, idx, _, _ = kmeans_1d(u, counts, k, seed=seed, restarts=4)
    num = jax.ops.segment_sum(counts * vals, idx, num_segments=k)
    den = jax.ops.segment_sum(counts, idx, num_segments=k)
    centers = jnp.where(den > 0, num / jnp.maximum(den, 1e-20), 0.0)
    return centers[idx], idx, centers
