"""Coordinate descent for the paper's l1 / l1+l2 objectives (eq. 6, 13-15).

Exact cyclic coordinate descent, but each full sweep is O(m) instead of the
O(m^2) the paper's complexity analysis assumes, by exploiting the cumulative
structure of V (DESIGN.md §3):

  sweeping k = 1..m, carry
    S = sum_{i>=k} n_i r_i        (weighted suffix residual sum)
    c = sum_{j<=k-1} a_j^new d_j  (running reconstruction prefix)
  then per coordinate, all in O(1):
    grad numerator   g   = d_k S + z_k a_k
    lasso            a_k <- S_{lam1}(g) / z_k                     (paper eq. 14)
    l1 + neg-l2      a_k <- S_{lam1}(g) / (z_k - 2 lam2)          (paper eq. 15)
    S <- S - delta d_k N_k ;  c <- c + a_k d_k ;  S <- S - n_k (w_k - c)

The iterates are identical to textbook cyclic CD (verified in tests against a
dense implementation). Linear global convergence per paper Prop. 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .problem import LSQProblem, reconstruct


def _soft(g: jax.Array, lam: jax.Array) -> jax.Array:
    return jnp.sign(g) * jnp.maximum(jnp.abs(g) - lam, 0.0)


def cd_sweep(alpha: jax.Array, problem: LSQProblem, lam1_vec: jax.Array,
             lam2: float) -> tuple[jax.Array, jax.Array]:
    """One full cyclic CD sweep. Returns (alpha_new, max |delta|)."""
    w, d, n, z, N = problem.w_hat, problem.d, problem.counts, problem.z, problem.n_suffix
    r0 = w - reconstruct(alpha, d)
    S0 = jnp.sum(n * r0)

    denom = z - 2.0 * lam2  # must be > 0 (validated by caller); == z for lasso

    def body(carry: tuple[jax.Array, jax.Array],
             xs: tuple[jax.Array, ...],
             ) -> tuple[tuple[jax.Array, jax.Array],
                        tuple[jax.Array, jax.Array]]:
        S, c = carry
        w_k, d_k, n_k, z_k, N_k, lam_k, den_k, a_old = xs
        g = d_k * S + z_k * a_old
        a_new = _soft(g, lam_k) / den_k
        delta = a_new - a_old
        S = S - delta * d_k * N_k          # residual suffix update (rank-1 column)
        c = c + a_new * d_k                # reconstruction prefix
        S = S - n_k * (w_k - c)            # drop row k from the suffix
        return (S, c), (a_new, jnp.abs(delta))

    (_, _), (alpha_new, deltas) = lax.scan(
        body, (S0, jnp.float32(0.0)), (w, d, n, z, N, lam1_vec, denom, alpha)
    )
    return alpha_new, jnp.max(deltas)


@functools.partial(jax.jit, static_argnames=("max_sweeps", "penalize_first"))
def cd_solve(
    problem: LSQProblem,
    lam1: float,
    lam2: float = 0.0,
    *,
    alpha0: jax.Array | None = None,
    max_sweeps: int = 200,
    tol: float = 1e-7,
    penalize_first: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Solve eq. 6 (lam2=0) or eq. 13 (lam2>0) by cyclic CD.

    Returns (alpha, n_sweeps). alpha has exact zeros on the pruned support.
    Init alpha0 = ones gives zero initial LS loss (paper §3.2.1).
    """
    m = problem.m
    if alpha0 is None:
        alpha0 = jnp.ones((m,), jnp.float32)
    lam1_vec = jnp.full((m,), jnp.float32(lam1))
    if not penalize_first:
        lam1_vec = lam1_vec.at[0].set(0.0)
    # scale tolerance to the data so convergence is size-independent
    scale = jnp.maximum(jnp.max(jnp.abs(problem.w_hat)), 1e-12)

    def cond(state: tuple[jax.Array, jax.Array, jax.Array]) -> jax.Array:
        _, sweep, max_delta = state
        return jnp.logical_and(sweep < max_sweeps, max_delta > tol * scale)

    def step(state: tuple[jax.Array, jax.Array, jax.Array],
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
        alpha, sweep, _ = state
        alpha, max_delta = cd_sweep(alpha, problem, lam1_vec, lam2)
        return alpha, sweep + 1, max_delta

    alpha, sweeps, _ = lax.while_loop(
        cond, step, (alpha0, jnp.int32(0), jnp.float32(jnp.inf))
    )
    return alpha, sweeps


def max_stable_lam2(problem: LSQProblem) -> float:
    """Largest lam2 keeping eq. 13 coordinate-wise convex: lam2 < min_k z_k / 2.

    The paper reports numerical instability when lam2 is 'too large' (§4.1);
    this is the exact threshold (DESIGN.md §8).
    """
    return float(0.5 * np.min(np.asarray(problem.z)))


def cd_solve_dense_reference(problem: LSQProblem, lam1: float,
                             lam2: float = 0.0, *,
                             alpha0: np.ndarray | None = None,
                             max_sweeps: int = 200, tol: float = 1e-7,
                             penalize_first: bool = True,
                             ) -> tuple[np.ndarray, int]:
    """Naive O(m^2)-per-sweep CD on the materialized V. Oracle for tests only."""
    w = np.asarray(problem.w_hat).astype(np.float64)
    d = np.asarray(problem.d).astype(np.float64)
    n = np.asarray(problem.counts).astype(np.float64)
    m = w.shape[0]
    V = np.tril(np.ones((m, m))) * d[None, :]
    z = (V * V * n[:, None]).sum(0)
    z = np.where(z <= 0, 1.0, z)
    alpha = np.ones(m) if alpha0 is None else np.array(alpha0, np.float64)
    lam1v = np.full(m, float(lam1))
    if not penalize_first:
        lam1v[0] = 0.0
    scale = max(np.abs(w).max(), 1e-12)
    for sweep in range(max_sweeps):
        max_delta = 0.0
        r = w - V @ alpha
        for k in range(m):
            g = (V[:, k] * n) @ r + z[k] * alpha[k]
            den = z[k] - 2.0 * lam2
            a_new = np.sign(g) * max(abs(g) - lam1v[k], 0.0) / den
            delta = a_new - alpha[k]
            r = r - V[:, k] * delta
            alpha[k] = a_new
            max_delta = max(max_delta, abs(delta))
        if max_delta <= tol * scale:
            break
    return alpha, sweep + 1
