"""Exact O(m) solver for the paper's eq. 6 via its weighted-TV reduction.

Beyond-paper (DESIGN.md §1.4, §5.2): substituting beta = alpha * d shows the
l1 objective is a weighted 1-D fused-lasso / total-variation problem on the
sorted unique values

    min_u  1/2 sum_i n_i (w_hat_i - u_i)^2  +  lam * sum_{j>=2} |u_j - u_{j-1}| / d_j

(the paper's extra lam*|alpha_1| boundary term is dropped here; cd_solve with
penalize_first=False solves the identical objective, used for cross-checks).
Solved exactly - global optimum, no iterations - by N. A. Johnson's dynamic
programming (2013) generalised to per-point weights and per-edge penalties.
Host-side numpy; O(m) time and memory (amortised knot insertion/deletion).
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:
    from .problem import LSQProblem


def tv1d_weighted(y: np.ndarray, w: np.ndarray, lam_edges: np.ndarray) -> np.ndarray:
    """min_u 1/2 sum w_i (y_i-u_i)^2 + sum_k lam_edges[k] |u_{k+1}-u_k|.

    y, w: (n,);  lam_edges: (n-1,) nonnegative. Returns u (n,).
    Derivative-knot DP: messages are convex piecewise-quadratic; their
    derivatives are piecewise-linear, stored as a base line plus per-knot
    (slope, intercept) increments; each inf-convolution with lam|.| clips the
    derivative at +/-lam, recorded as back-pointer thresholds (tm, tp).
    """
    y = np.asarray(y, np.float64)
    w = np.asarray(w, np.float64)
    lam_edges = np.asarray(lam_edges, np.float64)
    n = y.shape[0]
    if n == 1:
        return y.copy()

    SZ = 2 * n
    x = np.empty(SZ)
    a = np.empty(SZ)
    b = np.empty(SZ)
    tm = np.empty(n - 1)
    tp = np.empty(n - 1)

    lam = lam_edges[0]
    tm[0] = y[0] - lam / w[0]
    tp[0] = y[0] + lam / w[0]
    l = n - 1
    r = n
    x[l], x[r] = tm[0], tp[0]
    a[l], b[l] = w[0], -w[0] * y[0] + lam
    a[r], b[r] = -w[0], w[0] * y[0] + lam
    afirst, bfirst = w[1], -w[1] * y[1] - lam
    alast, blast = -w[1], w[1] * y[1] - lam  # negated right-side line

    for k in range(1, n - 1):
        lam = lam_edges[k]
        # left threshold: first point where derivative exceeds -lam
        alo, blo = afirst, bfirst
        lo = l
        while lo <= r and alo * x[lo] + blo < -lam:
            alo += a[lo]
            blo += b[lo]
            lo += 1
        # right threshold: last point (from the right) where derivative < lam
        ahi, bhi = alast, blast
        hi = r
        while hi >= lo and -(ahi * x[hi] + bhi) > lam:
            ahi += a[hi]
            bhi += b[hi]
            hi -= 1
        tm[k] = (-lam - blo) / alo
        tp[k] = -(lam + bhi) / ahi
        l = lo - 1
        r = hi + 1
        x[l], x[r] = tm[k], tp[k]
        a[l], b[l] = alo, blo + lam
        a[r], b[r] = ahi, bhi + lam
        afirst, bfirst = w[k + 1], -w[k + 1] * y[k + 1] - lam
        alast, blast = -w[k + 1], w[k + 1] * y[k + 1] - lam

    # minimise the final message: root of its derivative
    alo, blo = afirst, bfirst
    lo = l
    while lo <= r and alo * x[lo] + blo < 0.0:
        alo += a[lo]
        blo += b[lo]
        lo += 1
    u = np.empty(n)
    u[n - 1] = -blo / alo
    for k in range(n - 2, -1, -1):
        u[k] = min(max(u[k + 1], tm[k]), tp[k])
    return u


def tv_solve_problem(problem: "LSQProblem", lam: float) -> np.ndarray:
    """Exact solution of eq. 6 (penalize_first=False) on an LSQProblem."""
    y = np.asarray(problem.w_hat).astype(np.float64)
    n = np.asarray(problem.counts).astype(np.float64)
    d = np.asarray(problem.d).astype(np.float64)
    if y.shape[0] == 1:
        return y.copy()
    gaps = d[1:]
    lam_edges = lam / np.maximum(np.abs(gaps), 1e-30)
    return tv1d_weighted(y, n, lam_edges)
