"""Paper core: scalar quantization as sparse least-square optimization.

Wang et al., "Scalar Quantization as Sparse Least Square Optimization"
(DOI 10.1109/TPAMI.2019.2952096), plus beyond-paper exact solvers. See
DESIGN.md for the mapping from paper equations to modules.
"""
from . import registry
from .api import ALL_METHODS, COUNT_METHODS, LAM_METHODS, quantize, resolve_spec
from .cd import cd_solve, cd_sweep, max_stable_lam2
from .spec import QuantSpec, as_spec
from .dp_optimal import optimal_kmeans_1d
from .iterative import iterative_l1, tv_iterative
from .kmeans import kmeans_1d, kmeans_quantize_unique
from .kmeans_ls import kmeans_ls_quantize
from .l0 import l0_quantize, l0_solve
from .mog import mog_quantize_unique
from .problem import LSQProblem, make_problem, objective, reconstruct, unique_with_counts
from .refit import refit_support, support_of
from .tv_exact import tv1d_weighted, tv_solve_problem
from .types import QuantizedTensor, from_dense, hard_sigmoid, stack_quantized

__all__ = [
    "ALL_METHODS", "COUNT_METHODS", "LAM_METHODS", "quantize",
    "QuantSpec", "as_spec", "registry", "resolve_spec",
    "cd_solve", "cd_sweep", "max_stable_lam2",
    "optimal_kmeans_1d", "iterative_l1", "tv_iterative",
    "kmeans_1d", "kmeans_quantize_unique", "kmeans_ls_quantize",
    "l0_quantize", "l0_solve", "mog_quantize_unique",
    "LSQProblem", "make_problem", "objective", "reconstruct", "unique_with_counts",
    "refit_support", "support_of", "tv1d_weighted", "tv_solve_problem",
    "QuantizedTensor", "from_dense", "hard_sigmoid", "stack_quantized",
]
