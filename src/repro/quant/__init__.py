"""Quantization applications of the paper's solvers: PTQ, QAT, gradient
compression, and the quantized-serving matmul path."""
from .ptq import compression_ratio, dequantize_tree, quantize_tree
from .serve import estimate_decode_bytes, qmatmul

__all__ = ["quantize_tree", "dequantize_tree", "compression_ratio",
           "qmatmul", "estimate_decode_bytes"]
