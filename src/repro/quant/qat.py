"""Quantization-aware training: straight-through fake-quant + periodic
re-clustering (Deep-Compression-style retraining with the paper's
quantizers providing the codebooks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fake_quant(x, codebook):
    """Snap x to its nearest codebook value; identity gradient (STE)."""
    cb = jnp.sort(codebook)
    mid = 0.5 * (cb[1:] + cb[:-1])
    idx = jnp.searchsorted(mid, x)
    snapped = cb[idx]
    return x + jax.lax.stop_gradient(snapped - x)


def qat_params(params, codebooks):
    """Apply fake-quant everywhere a codebook is provided (path-keyed)."""

    def per_leaf(path, leaf):
        key = "/".join(getattr(k, "key", str(k)) for k in path)
        cb = codebooks.get(key)
        return fake_quant(leaf, cb) if cb is not None else leaf

    return jax.tree_util.tree_map_with_path(per_leaf, params)
