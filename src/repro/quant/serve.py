"""Quantized serving: value-shared weights feed the fused dequant matmul.

A QuantizedTensor leaf replaces `x @ W` with kernels.quant_matmul(x, idx,
codebook) - weights cross HBM as uint8 codes (+ tiny codebook), which is the
decode-bandwidth win the paper's compression buys at serving time.

Stacked leaves (``stack_quantized``'s (G, L) codebook / (G, n) indices form,
the shape that rides through ``lax.scan``) route to the stacked-group kernel
when the activations carry the matching leading group axis — one call serves
a whole scanned layer group from uint8 codes. When no kernel tiling applies
(activations without the group axis), qmatmul *densifies* the weight stack —
fp weight traffic the codes were supposed to eliminate. Every such call
bumps the module-level ``qmatmul_dequant_fallback`` count, which the serving
engines snapshot into their summaries (``serve.py`` epilog asserts it stays
0 for a PTQ'd scanned model).
"""
from __future__ import annotations

from repro.core import QuantizedTensor
from repro.kernels import quant_matmul, quant_matmul_stacked

# trace-time count of dense materializations (see fallback_count): qmatmul
# runs under jit, so each traced fallback site counts once per trace — zero
# means zero fp weight traffic in every compiled step
_FALLBACKS = {"qmatmul_dequant_fallback": 0}


def fallback_count() -> int:
    """Dense-materialization fallbacks traced so far (monotonic)."""
    return _FALLBACKS["qmatmul_dequant_fallback"]


def qmatmul(x, w):
    """Drop-in for x @ w accepting dense or QuantizedTensor weights."""
    if not isinstance(w, QuantizedTensor):
        return x @ w
    if w.stacked:
        G = w.indices.shape[0]
        if x.ndim >= 3 and x.shape[0] == G and x.shape[-1] == w.shape[0]:
            idx3d = w.indices.reshape((G,) + tuple(w.shape))
            orig = x.shape
            out = quant_matmul_stacked(x.reshape(G, -1, orig[-1]), idx3d,
                                       w.codebook, out_dtype=x.dtype)
            return out.reshape(*orig[:-1], w.shape[1])
        # no group axis to tile against: materialize the dense stack
        _FALLBACKS["qmatmul_dequant_fallback"] += 1
        return x @ w.to_dense().astype(x.dtype)
    idx2d = w.indices.reshape(w.shape)
    orig = x.shape
    out = quant_matmul(x.reshape(-1, orig[-1]), idx2d, w.codebook,
                       out_dtype=x.dtype)
    return out.reshape(*orig[:-1], w.shape[1])


def estimate_decode_bytes(params_bytes_dense: int, ratio: float,
                          cache_bytes: int) -> dict:
    """Decode is memory-bound: step time ~ (weights + cache) / HBM_bw."""
    from repro.analysis.roofline import HBM_BW

    dense = (params_bytes_dense + cache_bytes) / HBM_BW
    quant = (params_bytes_dense / ratio + cache_bytes) / HBM_BW
    return {"t_dense_s": dense, "t_quant_s": quant, "speedup": dense / quant}
