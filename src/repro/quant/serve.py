"""Quantized serving: value-shared weights feed the fused dequant matmul.

A QuantizedTensor leaf replaces `x @ W` with kernels.quant_matmul(x, idx,
codebook) - weights cross HBM as uint8 codes (+ tiny codebook), which is the
decode-bandwidth win the paper's compression buys at serving time.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import QuantizedTensor
from repro.kernels import quant_matmul


def qmatmul(x, w):
    """Drop-in for x @ w accepting dense or QuantizedTensor weights."""
    if isinstance(w, QuantizedTensor):
        idx2d = w.indices.reshape(w.shape)
        orig = x.shape
        out = quant_matmul(x.reshape(-1, orig[-1]), idx2d, w.codebook,
                           out_dtype=x.dtype)
        return out.reshape(*orig[:-1], w.shape[1])
    return x @ w


def estimate_decode_bytes(params_bytes_dense: int, ratio: float,
                          cache_bytes: int) -> dict:
    """Decode is memory-bound: step time ~ (weights + cache) / HBM_bw."""
    from repro.analysis.roofline import HBM_BW

    dense = (params_bytes_dense + cache_bytes) / HBM_BW
    quant = (params_bytes_dense / ratio + cache_bytes) / HBM_BW
    return {"t_dense_s": dense, "t_quant_s": quant, "speedup": dense / quant}
