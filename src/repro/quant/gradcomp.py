"""Quantized cross-pod gradient all-reduce with error feedback.

The paper's thesis - scalar quantization as cheap value-sharing - applied to
distributed training communication: pods train data-parallel; the cross-pod
gradient exchange (the slow inter-pod DCI hop) moves int8 codes + one f32
scale per tensor instead of bf16/f32 values: 2-4x less cross-pod traffic.
Error feedback (Seide et al.) accumulates the quantization residual locally
so the compression bias vanishes over steps.

Implemented as a manual `shard_map` over ONLY the 'pod' axis (data/model
stay GSPMD-auto): inside, each pod holds its own partial gradient; we
quantize, all_gather the codes across pods, dequantize and sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quantize_int8(g):
    """Symmetric uniform int8 scalar quantization (in-graph; the offline
    sparse-LSQ solvers refine codebooks for PTQ where latency permits)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def pod_quantized_allreduce(grads, err, *, axis: str = "pod"):
    """Inside shard_map(axis_names={'pod'}): per-pod partial grads ->
    identical summed grads + new error-feedback state."""
    n_pods = jax.lax.axis_size(axis)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_int8(g32)
        deq = _dequantize(q, scale)
        new_e = g32 - deq
        qs = jax.lax.all_gather(q, axis)            # int8 over the wire
        ss = jax.lax.all_gather(scale, axis)
        total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,)))
        return (total / n_pods).astype(g.dtype), new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = tree.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]))


def init_error_feedback(params_shape):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                        params_shape)


def wrap_pod_train_step(train_step_core, mesh, state_specs, batch_specs):
    """Lift a per-pod train step into a multi-pod one with compressed
    cross-pod gradient exchange.

    train_step_core(state, batch) must return (grads, metrics) - the caller
    applies the optimizer AFTER reduction so all pods stay bit-identical.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError("wrap_pod_train_step needs a 'pod' mesh axis")

    def stepped(state, err, batch):
        grads, metrics = train_step_core(state, batch)
        grads, new_err = pod_quantized_allreduce(grads, err)
        metrics = jax.tree.map(functools.partial(jax.lax.pmean,
                                                 axis_name="pod"), metrics)
        return grads, new_err, metrics

    # batch dim 0 is sharded over pod (manual) x data (auto); everything else
    # is replicated over 'pod'
    def batch_spec(_):
        return P("pod")

    return jax.shard_map(
        stepped,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), state_specs),
                  jax.tree.map(lambda _: P(), state_specs["params"]),
                  jax.tree.map(batch_spec, batch_specs)),
        out_specs=(jax.tree.map(lambda _: P(), state_specs["params"]),
                   jax.tree.map(lambda _: P(), state_specs["params"]),
                   P()),
        axis_names=frozenset({"pod"}),
        check_vma=False,
    )
