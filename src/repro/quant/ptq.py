"""Post-training quantization of whole checkpoints with the paper's methods.

Per-tensor (optionally per-output-channel) sparse-LSQ quantization; the
batched FISTA Pallas kernel quantizes many rows/tensors in one launch; CD is
the host path for small tensors. Returns a pytree mirroring params with
QuantizedTensor leaves (skips norms/routers/SSM-sensitive leaves per
cfg.quant_skip).
"""
from __future__ import annotations

import re

import jax
import numpy as np

from repro.core import QuantizedTensor, quantize, stack_quantized
from repro.core.problem import make_problem, unique_with_counts
from repro.core.refit import refit_support, support_of
from repro.core.types import from_dense
from repro.kernels import solve_fista_batch


def _names(path):
    return tuple(getattr(k, "key", getattr(k, "name", str(k))) for k in path)


def should_quantize(path, leaf, skip_patterns) -> bool:
    if leaf.ndim < 2:
        return False
    name = "/".join(_names(path))
    return not any(re.search(p, name) for p in skip_patterns)


def quantize_tree(params, *, method: str = "kmeans_ls", num_values: int = 256,
                  lam: float | None = None, weighted: bool = True,
                  skip_patterns=("ln", "norm", "router", "A_log", "mix",
                                 "dt_bias", "D_skip", "w0"),
                  stacked_paths=("groups",)):
    """Quantize every eligible leaf. Returns (qtree, report).

    Leaves under a ``stacked_paths`` subtree (the transformer's scanned
    layer groups) carry a leading group axis; each slice is quantized
    independently and restacked (``stack_quantized``), so the resulting
    QuantizedTensor still scans — lax.scan slices codebook and indices in
    lockstep.
    """
    report = {}

    def per_leaf(path, leaf):
        if not should_quantize(path, leaf, skip_patterns):
            return leaf
        kw = dict(num_values=num_values) if lam is None else dict(lam=lam)
        names = _names(path)
        arr = np.asarray(leaf)
        if names and names[0] in stacked_paths and arr.ndim >= 3:
            parts = [quantize(arr[g], method, weighted=weighted, **kw)
                     for g in range(arr.shape[0])]
            qt = stack_quantized([q for q, _ in parts])
            info = {"n_values": qt.num_values,
                    "l2_loss": float(sum(i["l2_loss"] for _, i in parts))}
        else:
            qt, info = quantize(arr, method, weighted=weighted, **kw)
        report["/".join(names)] = {
            "n_values": info["n_values"], "l2_loss": info["l2_loss"],
            "bytes": qt.nbytes(), "dense_bytes": leaf.size * leaf.dtype.itemsize,
        }
        return qt

    qtree = jax.tree_util.tree_map_with_path(per_leaf, params)
    return qtree, report


def quantize_tree_batched_fista(params, *, lam: float, n_iters: int = 1000,
                                weighted: bool = True, max_unique: int = 4096,
                                skip_patterns=("ln", "norm", "router",
                                               "A_log", "mix", "dt_bias",
                                               "D_skip", "w0")):
    """One Pallas launch per round: all eligible tensors padded to a common
    unique-value length and solved together (the PTQ throughput path)."""
    leaves = []
    jax.tree_util.tree_map_with_path(
        lambda p, l: leaves.append((p, l)) if should_quantize(p, l, skip_patterns)
        else None, params)
    probs = []
    for path, leaf in leaves:
        vals, counts, inv = unique_with_counts(np.asarray(leaf))
        if len(vals) > max_unique:   # bucket ultra-high-cardinality tensors
            edges = np.quantile(vals, np.linspace(0, 1, max_unique + 1)[1:-1])
            bucket = np.searchsorted(edges, vals)
            bvals = np.zeros(max_unique)
            bcnt = np.zeros(max_unique)
            np.add.at(bcnt, bucket, counts)
            np.add.at(bvals, bucket, counts * vals)
            nz = bcnt > 0
            vals2 = bvals[nz] / bcnt[nz]
            counts2 = bcnt[nz]
            remap = np.cumsum(nz) - 1
            inv = remap[bucket[inv]]
            vals, counts = vals2, counts2
        probs.append((path, leaf, vals, counts, inv))

    M = max(len(v) for _, _, v, _, _ in probs)
    B = len(probs)
    W = np.zeros((B, M), np.float32)
    D = np.zeros((B, M), np.float32)
    N = np.zeros((B, M), np.float32)
    for i, (_, _, vals, counts, _) in enumerate(probs):
        m = len(vals)
        W[i, :m] = vals
        D[i, :m] = np.diff(vals, prepend=0.0)
        N[i, :m] = counts if weighted else 1.0
    alpha = solve_fista_batch(W, D, N, lam, n_iters=n_iters)

    qtree_flat = {}
    report = {}
    for i, (path, leaf, vals, counts, inv) in enumerate(probs):
        m = len(vals)
        prob = make_problem(vals, counts, weighted=weighted)
        sup = support_of(alpha[i, :m])
        recon, _ = refit_support(prob, sup)
        qt = from_dense(np.asarray(leaf), np.asarray(recon), inv)
        key = "/".join(_names(path))
        qtree_flat[key] = qt
        report[key] = {"n_values": qt.num_values, "bytes": qt.nbytes()}

    def per_leaf(path, leaf):
        return qtree_flat.get("/".join(_names(path)), leaf)

    return jax.tree_util.tree_map_with_path(per_leaf, params), report


def dequantize_tree(qtree):
    return jax.tree.map(
        lambda l: l.to_dense() if isinstance(l, QuantizedTensor) else l,
        qtree, is_leaf=lambda l: isinstance(l, QuantizedTensor))


def compression_ratio(report) -> float:
    dense = sum(r.get("dense_bytes", 0) for r in report.values())
    comp = sum(r["bytes"] for r in report.values())
    return dense / max(comp, 1)
