"""Post-training quantization of whole checkpoints with the paper's methods.

Spec-driven: ``quantize_tree(params, spec)`` takes the same
:class:`~repro.core.QuantSpec` (object or compact string) as every other
quantization surface. Per-tensor host solves are the default path;
``batched=True`` routes lam-parameterised specs whose registry entry is
``tree_batched`` (l1_ls) through the batched FISTA Pallas kernel — every
eligible tensor padded to a common unique-value length and solved in one
launch (the PTQ throughput path, formerly the separate
``quantize_tree_batched_fista`` entry point, kept as a deprecated shim).
Returns a pytree mirroring params with QuantizedTensor leaves (skips
norms/routers/SSM-sensitive leaves per ``skip_patterns``).
"""
from __future__ import annotations

import re
import warnings

import jax
import numpy as np

from repro.core import (QuantizedTensor, QuantSpec, quantize, registry,
                        stack_quantized)
from repro.core.api import _UNSET, resolve_spec
from repro.core.problem import make_problem, unique_with_counts
from repro.core.refit import refit_support, support_of
from repro.core.types import from_dense
from repro.kernels import solve_fista_batch

DEFAULT_SKIP = ("ln", "norm", "router", "A_log", "mix", "dt_bias", "D_skip",
                "w0")


def _names(path):
    return tuple(getattr(k, "key", getattr(k, "name", str(k))) for k in path)


def should_quantize(path, leaf, skip_patterns) -> bool:
    if leaf.ndim < 2:
        return False
    name = "/".join(_names(path))
    return not any(re.search(p, name) for p in skip_patterns)


def _tree_spec(spec, method, num_values, lam, weighted) -> QuantSpec:
    """quantize_tree's shim defaults differ from quantize's (PTQ always
    optimized the full-vector loss): weighted defaults True, the count
    budget to 256."""
    if spec is None and method is _UNSET:
        method = "kmeans_ls"
    if (spec is not None and not isinstance(spec, QuantSpec)
            and ("@" not in spec and ":" not in spec)) or method is not _UNSET:
        # legacy path: apply the historical defaults before resolving
        if num_values is _UNSET and lam is _UNSET:
            num_values = 256
        if weighted is _UNSET:
            weighted = True
    return resolve_spec(spec, method=method, num_values=num_values, lam=lam,
                        weighted=weighted, _warn_stacklevel=4)


def quantize_tree(params, spec=None, *, method=_UNSET, num_values=_UNSET,
                  lam=_UNSET, weighted=_UNSET,
                  skip_patterns=DEFAULT_SKIP, stacked_paths=("groups",),
                  batched: bool = False, **solver_kw):
    """Quantize every eligible leaf. Returns (qtree, report).

    ``spec`` is a QuantSpec or compact string ("kmeans_ls@256:weighted=true",
    "l1_ls:lam=0.02"); the loose method/num_values/lam kwargs remain as a
    deprecation shim. ``batched=True`` solves every leaf in one FISTA
    kernel launch (lam methods with a ``tree_batched`` registry entry).

    In the per-leaf path, leaves under a ``stacked_paths`` subtree (the
    transformer's scanned layer groups) carry a leading group axis; each
    slice is quantized independently and restacked (``stack_quantized``),
    so the resulting QuantizedTensor still scans — lax.scan slices codebook
    and indices in lockstep. The batched path solves each leaf as one
    vector (stacked groups share a codebook).
    """
    spec = _tree_spec(spec, method, num_values, lam, weighted)
    if batched:
        if not registry.get(spec.method).tree_batched:
            raise ValueError(
                f"batched=True needs a tree-batched lam method "
                f"(registry: "
                f"{', '.join(n for n in registry.methods() if registry.get(n).tree_batched)}), "
                f"got {str(spec)!r}")
        return _quantize_tree_batched(params, spec,
                                      skip_patterns=skip_patterns,
                                      **solver_kw)
    report = {}

    def per_leaf(path, leaf):
        if not should_quantize(path, leaf, skip_patterns):
            return leaf
        names = _names(path)
        arr = np.asarray(leaf)
        if names and names[0] in stacked_paths and arr.ndim >= 3:
            parts = [quantize(arr[g], spec, **solver_kw)
                     for g in range(arr.shape[0])]
            qt = stack_quantized([q for q, _ in parts])
            info = {"n_values": qt.num_values,
                    "l2_loss": float(sum(i["l2_loss"] for _, i in parts))}
        else:
            qt, info = quantize(arr, spec, **solver_kw)
        report["/".join(names)] = {
            "n_values": info["n_values"], "l2_loss": info["l2_loss"],
            "bytes": qt.nbytes(), "dense_bytes": leaf.size * leaf.dtype.itemsize,
            "spec": str(spec),
        }
        return qt

    qtree = jax.tree_util.tree_map_with_path(per_leaf, params)
    return qtree, report


def _quantize_tree_batched(params, spec: QuantSpec, *, n_iters: int = 1000,
                           max_unique: int = 4096,
                           skip_patterns=DEFAULT_SKIP):
    """One Pallas launch per round: all eligible tensors padded to a common
    unique-value length and solved together, then LS-refit on their l1
    supports (the spec's method contract — l1_ls — solved by FISTA)."""
    leaves = []
    jax.tree_util.tree_map_with_path(
        lambda p, l: leaves.append((p, l)) if should_quantize(p, l, skip_patterns)
        else None, params)
    probs = []
    for path, leaf in leaves:
        vals, counts, inv = unique_with_counts(np.asarray(leaf))
        if len(vals) > max_unique:   # bucket ultra-high-cardinality tensors
            edges = np.quantile(vals, np.linspace(0, 1, max_unique + 1)[1:-1])
            bucket = np.searchsorted(edges, vals)
            bvals = np.zeros(max_unique)
            bcnt = np.zeros(max_unique)
            np.add.at(bcnt, bucket, counts)
            np.add.at(bvals, bucket, counts * vals)
            nz = bcnt > 0
            vals2 = bvals[nz] / bcnt[nz]
            counts2 = bcnt[nz]
            remap = np.cumsum(nz) - 1
            inv = remap[bucket[inv]]
            vals, counts = vals2, counts2
        probs.append((path, leaf, vals, counts, inv))

    M = max(len(v) for _, _, v, _, _ in probs)
    B = len(probs)
    W = np.zeros((B, M), np.float32)
    D = np.zeros((B, M), np.float32)
    N = np.zeros((B, M), np.float32)
    for i, (_, _, vals, counts, _) in enumerate(probs):
        m = len(vals)
        W[i, :m] = vals
        D[i, :m] = np.diff(vals, prepend=0.0)
        N[i, :m] = counts if spec.weighted else 1.0
    alpha = solve_fista_batch(W, D, N, spec.lam, n_iters=n_iters)

    qtree_flat = {}
    report = {}
    for i, (path, leaf, vals, counts, inv) in enumerate(probs):
        m = len(vals)
        prob = make_problem(vals, counts, weighted=spec.weighted)
        sup = support_of(alpha[i, :m])
        recon, _ = refit_support(prob, sup)
        recon = np.asarray(recon)
        if spec.clip is not None:
            recon = np.clip(recon, spec.clip[0], spec.clip[1])
        qt = from_dense(np.asarray(leaf), recon, inv)
        key = "/".join(_names(path))
        qtree_flat[key] = qt
        report[key] = {"n_values": qt.num_values, "bytes": qt.nbytes(),
                       "spec": str(spec)}

    def per_leaf(path, leaf):
        return qtree_flat.get("/".join(_names(path)), leaf)

    return jax.tree_util.tree_map_with_path(per_leaf, params), report


def quantize_tree_batched_fista(params, *, lam: float, n_iters: int = 1000,
                                weighted: bool = True, max_unique: int = 4096,
                                skip_patterns=DEFAULT_SKIP):
    """Deprecated: folded into ``quantize_tree(params, spec, batched=True)``."""
    spec = QuantSpec("l1_ls", lam=lam, weighted=weighted)
    warnings.warn(
        f"quantize_tree_batched_fista is deprecated; use "
        f"quantize_tree(params, {str(spec)!r}, batched=True)",
        DeprecationWarning, stacklevel=2)
    return quantize_tree(params, spec, batched=True, n_iters=n_iters,
                         max_unique=max_unique, skip_patterns=skip_patterns)


def dequantize_tree(qtree):
    return jax.tree.map(
        lambda l: l.to_dense() if isinstance(l, QuantizedTensor) else l,
        qtree, is_leaf=lambda l: isinstance(l, QuantizedTensor))


def compression_ratio(report) -> float:
    dense = sum(r.get("dense_bytes", 0) for r in report.values())
    comp = sum(r["bytes"] for r in report.values())
    return dense / max(comp, 1)
