"""Fused paged-attention flash-decode kernel - Pallas TPU (serving hot path).

One decode step reads the whole KV history of every batch slot. With the
paged cache (repro.serving.kv_cache) that history lives in two pools: an fp
pool for write-hot pages and a 4-bit codes + per-block codebook form for
frozen pages (the paper's sparse-LSQ quantizers). The pre-existing read path
(`PagedKVCache._gather`) dequantizes frozen pages to full width in HBM
before attention ever runs, so quantization compressed storage but decode
still crossed HBM at 32 bits/value.

This kernel walks each sequence's block table on-core instead:

  grid = (B,); block_table / kv_valid_len / blk_q ride in as scalar-prefetch
  (SMEM) so page ids are known before the body runs. Per page the kernel
  issues a *conditional* DMA - frozen pages copy packed codes + the two
  (L,) codebooks, hot pages copy the fp tile - so cold context crosses HBM
  at ~4 bits/value and is dequantized (`cb[codes]`) in VMEM. The DMA is
  double-buffered by default: two VMEM slots with ping-pong semaphore
  banks, page j+1's copy started before page j's wait so it overlaps the
  dequant + flash step (serial single-slot variant kept for the benchmark
  three-way). Attention is online-softmax (flash) over pages with
  per-sequence `kv_valid_len` masking; pages past `ceil(valid/bs)` skip
  their DMA entirely, which is what makes short sequences in a long-table
  batch cheap.

GQA is handled natively: a static per-kv-head loop computes (G, bs) score
tiles without repeating K/V across the group. `window` is not supported
(serving decodes are full-context); callers fall back to the gather path.

Query windows (speculative-decoding verify): ``q`` may carry a small extra
window axis (B, W, Hq, Dh). The W queries of one sequence are this step's
freshly written positions ``valid - W .. valid - 1``, so the kernel reads
each page ONCE and scores all W queries against it — the causal structure
is a per-query-row valid length ``valid - (W-1-w)`` folded into the same
online-softmax mask. Queries ride through the grid reordered kv-head-major
(``(Hkv, W, G)`` rows) so the static per-kv-head loop stays a contiguous
slice; W=1 reduces to the plain decode layout bit-for-bit.

The pure-jnp oracle is `ref.ref_paged_decode`; `_gather` + masked sdpa
remains the CPU fallback read path. `modeled_hbm_bytes_per_token` is the
analytic bytes model the paged-attention benchmark and tests use to compare
the two paths' HBM traffic.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

BIG_NEG = -2.3819763e38


# ------------------------------------------------------------ 4-bit packing


def pack4(codes: jax.Array) -> jax.Array:
    """Pack two 4-bit codes per byte along the last dim (must be even).

    Split-half layout: byte i holds codes[i] (low nibble) and codes[i + D/2]
    (high nibble), so unpacking is a concatenate - lane-friendly on TPU,
    where a minor-dim interleave would shuffle within vector registers.
    """
    D = codes.shape[-1]
    assert D % 2 == 0, f"pack4 needs an even last dim, got {D}"
    lo, hi = codes[..., : D // 2], codes[..., D // 2:]
    return (lo.astype(jnp.uint8) | (hi.astype(jnp.uint8) << 4))


def unpack4(packed: jax.Array) -> jax.Array:
    """Inverse of pack4: (..., Dc) uint8 -> (..., 2*Dc) int32 codes."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    return jnp.concatenate([lo, hi], axis=-1)


# ------------------------------------------------------------ kernel body


def _kernel(bs, Hkv, G, W, Dh, scale, softcap, quantized, packed,
            double_buffer,
            table_ref, valid_ref, blkq_ref,
            q_ref, kfp_ref, vfp_ref, kc_ref, vc_ref, kcb_ref, vcb_ref,
            o_ref,
            k_tile, v_tile, kc_tile, vc_tile, cb_tile, sems):
    b = pl.program_id(0)
    mb = table_ref.shape[1]
    WG = W * G                    # query rows per kv head ((Hkv, W, G) major)
    Hq = Hkv * WG
    valid = valid_ref[b]
    n_pages = lax.div(valid + bs - 1, bs)

    # Scratch tiles carry a leading slot axis: 2 slots in double-buffer
    # mode (page j computes out of slot j%2 while page j+1's DMA fills the
    # other), 1 slot serial. Each slot owns a bank of 4 DMA semaphores.

    def fp_copies(page, s):
        return [pltpu.make_async_copy(kfp_ref.at[page], k_tile.at[s],
                                      sems.at[s, 0]),
                pltpu.make_async_copy(vfp_ref.at[page], v_tile.at[s],
                                      sems.at[s, 1])]

    def code_copies(page, s):
        # ~4 bits/value across the wire: packed codes + two (L,) codebooks
        return [pltpu.make_async_copy(kc_ref.at[page], kc_tile.at[s],
                                      sems.at[s, 0]),
                pltpu.make_async_copy(vc_ref.at[page], vc_tile.at[s],
                                      sems.at[s, 1]),
                pltpu.make_async_copy(kcb_ref.at[page], cb_tile.at[s, 0],
                                      sems.at[s, 2]),
                pltpu.make_async_copy(vcb_ref.at[page], cb_tile.at[s, 1],
                                      sems.at[s, 3])]

    def start_page(j, s):
        page = table_ref[b, j]
        if not quantized:
            for c in fp_copies(page, s):
                c.start()
            return
        frozen = blkq_ref[page] != 0

        @pl.when(frozen)
        def _():
            for c in code_copies(page, s):
                c.start()

        @pl.when(jnp.logical_not(frozen))
        def _():
            for c in fp_copies(page, s):
                c.start()

    def finish_page(j, s):
        page = table_ref[b, j]
        if not quantized:
            for c in fp_copies(page, s):
                c.wait()
            return
        frozen = blkq_ref[page] != 0

        @pl.when(frozen)
        def _():
            for c in code_copies(page, s):
                c.wait()
            kc = kc_tile[s]
            vc = vc_tile[s]
            k_idx = unpack4(kc) if packed else kc.astype(jnp.int32)
            v_idx = unpack4(vc) if packed else vc.astype(jnp.int32)
            k_tile[s] = jnp.take(cb_tile[s, 0], k_idx.reshape(-1), axis=0
                                 ).reshape(bs, Hkv, Dh).astype(k_tile.dtype)
            v_tile[s] = jnp.take(cb_tile[s, 1], v_idx.reshape(-1), axis=0
                                 ).reshape(bs, Hkv, Dh).astype(v_tile.dtype)

        @pl.when(jnp.logical_not(frozen))
        def _():
            for c in fp_copies(page, s):
                c.wait()

    q = q_ref[0].astype(jnp.float32)                       # (Hq, Dh)

    if double_buffer:
        # warm-up: page 0's DMA is in flight before the loop body runs
        @pl.when(n_pages > 0)
        def _():
            start_page(0, 0)

    def body(j, carry):
        m, l, acc = carry
        s = lax.rem(j, 2) if double_buffer else 0

        if double_buffer:
            # start page j+1 into the other slot, then wait page j: the
            # copy overlaps this iteration's wait+dequant+flash step
            @pl.when(j + 1 < n_pages)
            def _():
                start_page(j + 1, lax.rem(j + 1, 2))

            @pl.when(j < n_pages)
            def _():
                finish_page(j, s)
        else:
            @pl.when(j < n_pages)
            def _():
                start_page(j, 0)
                finish_page(j, 0)

        # Positions >= valid are masked to BIG_NEG below, contributing
        # exp(BIG_NEG-m) = 0. Pages past n_pages never DMA'd into this
        # slot, so zero the tiles outright: stale (or, double-buffered
        # with n_pages == 1, never-written) VMEM must not reach the
        # matmuls — 0 * garbage is 0 but 0 * NaN is NaN.
        live = j < n_pages
        kt = jnp.where(live, k_tile[s].astype(jnp.float32), 0.0)
        vt = jnp.where(live, v_tile[s].astype(jnp.float32), 0.0)
        s = jnp.concatenate(
            [lax.dot_general(q[h * WG:(h + 1) * WG], kt[:, h, :],
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
             for h in range(Hkv)], axis=0) * scale         # (Hq, bs)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        pos = j * bs + lax.broadcasted_iota(jnp.int32, (Hq, bs), 1)
        # query row r sits at sequence position valid - (W-1-w): older
        # window rows see strictly shorter prefixes (causal within the
        # window); W=1 collapses to the plain `pos < valid` decode mask
        w_row = lax.rem(lax.broadcasted_iota(jnp.int32, (Hq, bs), 0),
                        WG) // G
        mask = pos < valid - (W - 1 - w_row)
        s = jnp.where(mask, s, BIG_NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jnp.concatenate(
            [lax.dot_general(p[h * WG:(h + 1) * WG], vt[:, h, :],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
             for h in range(Hkv)], axis=0)                 # (Hq, Dh)
        return m_new, l_new, acc * corr + pv

    init = (jnp.full((Hq, 1), BIG_NEG, jnp.float32),
            jnp.zeros((Hq, 1), jnp.float32),
            jnp.zeros((Hq, Dh), jnp.float32))
    _, l, acc = lax.fori_loop(0, mb, body, init)
    o_ref[0] = (acc / jnp.maximum(l, 1e-20)).astype(o_ref.dtype)


# ------------------------------------------------------------ entry point


@functools.partial(
    jax.jit, static_argnames=("softcap", "quantized", "packed",
                              "double_buffer", "interpret")
)
def paged_decode_attention(
    q: jax.Array,            # (B, Hq, Dh) queries, or (B, W, Hq, Dh) window
    k_fp: jax.Array,         # (nb, bs, Hkv, Dh) fp page pool
    v_fp: jax.Array,         # (nb, bs, Hkv, Dh)
    k_codes: jax.Array,      # (nb, bs, Hkv, Dc) packed 4-bit (or u8) codes
    v_codes: jax.Array,      # (nb, bs, Hkv, Dc)
    k_cb: jax.Array,         # (nb, L) per-block codebooks, f32
    v_cb: jax.Array,         # (nb, L)
    blk_q: jax.Array,        # (nb,) page is served from codes
    block_table: jax.Array,  # (B, mb) page ids (0 = null page)
    kv_valid_len: jax.Array,  # (B,) tokens valid per sequence (>= 1)
    *,
    softcap: float | None = None,
    quantized: bool = False,
    packed: bool = True,
    double_buffer: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Fused flash-decode over the paged pools.

    ``q`` may be a single decode step (B, Hq, Dh) -> (B, Hq, Dh), or a
    speculative verify window (B, W, Hq, Dh) -> (B, W, Hq, Dh) whose W
    queries sit at positions ``kv_valid_len - W .. kv_valid_len - 1``
    (causal within the window); each page is still read once per sequence.

    ``double_buffer`` ping-pongs the per-page DMA across two VMEM slots so
    page j+1's copy overlaps page j's dequant + flash step; the serial
    variant (one slot, copy-then-compute) is kept selectable for the
    paged-attention benchmark's three-way row. Both variants run the exact
    same per-page arithmetic, so results are bitwise identical.
    """
    windowed = q.ndim == 4
    if not windowed:
        q = q[:, None]
    B, W, Hq, Dh = q.shape
    nb, bs, Hkv, _ = k_fp.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    Dc = k_codes.shape[-1]
    L = k_cb.shape[1]
    scale = float(1.0 / np.sqrt(Dh))
    # kv-head-major query rows ((Hkv, W, G)) keep the kernel's static
    # per-kv-head loop a contiguous slice; identity when W == 1
    HqW = Hkv * W * G
    qr = q.reshape(B, W, Hkv, G, Dh).transpose(0, 2, 1, 3, 4)
    qr = qr.reshape(B, HqW, Dh)

    nslots = 2 if double_buffer else 1
    qspec = pl.BlockSpec((1, HqW, Dh), lambda b, *_: (b, 0, 0))
    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[qspec, hbm, hbm, hbm, hbm, hbm, hbm],
        out_specs=pl.BlockSpec((1, HqW, Dh), lambda b, *_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nslots, bs, Hkv, Dh), k_fp.dtype),
            pltpu.VMEM((nslots, bs, Hkv, Dh), v_fp.dtype),
            pltpu.VMEM((nslots, bs, Hkv, Dc), jnp.uint8),
            pltpu.VMEM((nslots, bs, Hkv, Dc), jnp.uint8),
            pltpu.VMEM((nslots, 2, L), jnp.float32),
            pltpu.SemaphoreType.DMA((nslots, 4)),
        ],
    )
    kern = functools.partial(_kernel, bs, Hkv, G, W, Dh, scale, softcap,
                             quantized, packed, double_buffer)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, HqW, Dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(block_table.astype(jnp.int32), kv_valid_len.astype(jnp.int32),
      blk_q.astype(jnp.int32), qr, k_fp, v_fp, k_codes, v_codes, k_cb, v_cb)
    out = out.reshape(B, Hkv, W, G, Dh).transpose(0, 2, 1, 3, 4)
    out = out.reshape(B, W, Hq, Dh)
    return out if windowed else out[:, 0]


# ------------------------------------------------------------ prefill entry


def paged_prefill_attention(
    q: jax.Array,            # (B, C, Hq, Dh) one prompt chunk of C queries
    k_fp: jax.Array,
    v_fp: jax.Array,
    k_codes: jax.Array,
    v_codes: jax.Array,
    k_cb: jax.Array,
    v_cb: jax.Array,
    blk_q: jax.Array,
    block_table: jax.Array,
    q_offset: jax.Array,     # (B,) chunk start position per sequence
    *,
    softcap: float | None = None,
    quantized: bool = False,
    packed: bool = True,
    double_buffer: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Fused chunked-prefill: score one prompt chunk against its prefix.

    The chunk's C queries sit at positions ``q_offset .. q_offset + C - 1``
    (the chunk's own K/V already written to the pool), attending causally
    over every earlier page through the *same* conditional-DMA + in-VMEM
    dequant path as decode — a pre-frozen prefix (shared context restored
    as codes) crosses HBM at ~4 bits/value instead of being gathered fp.

    This is exactly the decode kernel's query-window layout with W = C and
    ``kv_valid_len = q_offset + C``: row w's causal chunk mask
    ``pos < valid - (C-1-w)`` reduces to ``pos <= q_offset + w``. Because
    the online-softmax carry is per query row and pages are walked in the
    same order whatever the window size, chunked calls are bitwise
    identical to one whole-prompt call (the PR 5 verify-window discipline
    applied to prefill).
    """
    assert q.ndim == 4, "prefill queries are (B, C, Hq, Dh) chunks"
    C = q.shape[1]
    valid = jnp.asarray(q_offset, jnp.int32) + C
    return paged_decode_attention(
        q, k_fp, v_fp, k_codes, v_codes, k_cb, v_cb, blk_q, block_table,
        valid, softcap=softcap, quantized=quantized, packed=packed,
        double_buffer=double_buffer, interpret=interpret)


# ------------------------------------------------------------ bytes model


def modeled_hbm_bytes_per_token(
    block_table, seq_lens, blk_q, *, block_size: int, n_kv_heads: int,
    head_dim: int, num_values: int, quantized: bool, packed: bool,
    path: str, fp_bytes: int = 4,
) -> float:
    """Analytic HBM read bytes per decoded token, one attention layer.

    ``seq_lens`` are pre-write lengths (the kernel sees valid = len + 1).
    The gather path materializes every table column for every row at full
    width (frozen pages' reconstructions live in the fp pool, so every page
    crosses HBM at fp_bytes/value); the fused path reads, per sequence,
    only ``ceil((len+1)/bs)`` pages, each as *either* codes+codebooks
    (frozen, ~4 bits/value) or fp (hot). K and V both counted; q/output
    traffic is identical for both paths and excluded.
    """
    table = np.asarray(block_table)
    lens = np.asarray(seq_lens)
    bq = np.asarray(blk_q).astype(bool).reshape(-1)
    B, mb = table.shape
    bs = block_size
    elems = bs * n_kv_heads * head_dim
    fp_page = 2 * elems * fp_bytes
    Dc = head_dim // 2 if packed else head_dim
    code_page = 2 * (bs * n_kv_heads * Dc + num_values * 4)
    if path == "gather":
        return float(mb * fp_page)
    assert path == "fused", path
    total = 0
    for b in range(B):
        n_pages = -(-(int(lens[b]) + 1) // bs)
        for j in range(min(n_pages, mb)):
            frozen = quantized and bq[table[b, j]]
            total += code_page if frozen else fp_page
    return total / B


def modeled_prefill_hbm_bytes_per_token(
    block_table, prompt_lens, blk_q, *, chunk: int, block_size: int,
    n_kv_heads: int, head_dim: int, num_values: int, quantized: bool,
    packed: bool, path: str, fp_bytes: int = 4,
) -> float:
    """Analytic HBM read bytes per *prompt* token for chunked prefill, one
    attention layer.

    Prefill in chunks of ``chunk`` tokens re-reads the growing prefix once
    per chunk. The gather path materializes the sequence's whole block
    table at fp width for every chunk (what ``update`` + sdpa does); the
    fused path reads, per chunk, only the ``ceil((off + C) / bs)`` pages
    covering that chunk's prefix, each as either codes + codebooks (frozen
    shared context) or fp (hot). K and V both counted; q/output traffic is
    identical for both paths and excluded.
    """
    table = np.asarray(block_table)
    lens = np.asarray(prompt_lens)
    bq = np.asarray(blk_q).astype(bool).reshape(-1)
    B, mb = table.shape
    bs = block_size
    elems = bs * n_kv_heads * head_dim
    fp_page = 2 * elems * fp_bytes
    Dc = head_dim // 2 if packed else head_dim
    code_page = 2 * (bs * n_kv_heads * Dc + num_values * 4)
    total = 0
    n_tok = 0
    for b in range(B):
        P = int(lens[b])
        n_tok += P
        for off in range(0, P, chunk):
            C = min(chunk, P - off)
            if path == "gather":
                total += mb * fp_page
                continue
            assert path == "fused", path
            n_pages = -(-(off + C) // bs)
            for j in range(min(n_pages, mb)):
                frozen = quantized and bq[table[b, j]]
                total += code_page if frozen else fp_page
    return total / max(n_tok, 1)
