"""Pure-jnp oracles for the Pallas kernels (numerically identical math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ref_quant_matmul(x, idx, codebook, out_dtype=None):
    """Dense reference: materialize W = codebook[idx], plain matmul."""
    w = jnp.take(codebook, idx.astype(jnp.int32), axis=0).astype(x.dtype)
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def ref_quant_matmul_stacked(x, idx, codebook, out_dtype=None):
    """Per-group dense oracle for kernels.quant_matmul_stacked: materialize
    W[g] = codebook[g][idx[g]], batched matmul over the group axis."""
    G = idx.shape[0]
    flat = idx.reshape(G, -1).astype(jnp.int32)
    w = jnp.take_along_axis(codebook, flat, axis=1).reshape(idx.shape)
    out = jnp.einsum("gmk,gkn->gmn", x, w.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def ref_paged_decode(q, k_fp, v_fp, k_codes, v_codes, k_cb, v_cb, blk_q,
                     block_table, kv_valid_len, *, softcap=None,
                     quantized=False, packed=True):
    """Dense oracle for kernels.paged_attention: materialize every table
    page at full width (dequantizing frozen ones), then masked softmax.
    Numerically the same math as `PagedKVCache._gather` + decode-shaped
    `models.attention.sdpa`.

    ``q`` is (B, Hq, Dh) for a single decode step, or (B, W, Hq, Dh) for a
    speculative verify window whose query w sits at sequence position
    ``kv_valid_len - W + w`` (causal within the window).
    """
    from .paged_attention import BIG_NEG, unpack4

    windowed = q.ndim == 4
    if not windowed:
        q = q[:, None]
    B, W, Hq, Dh = q.shape
    nb, bs, Hkv, _ = k_fp.shape
    G = Hq // Hkv
    t = block_table
    mb = t.shape[1]

    def expand(fp, codes, cb):
        pages = fp[t]                                   # (B, mb, bs, H, D)
        if quantized:
            c = codes[t]
            if packed:
                c = unpack4(c)
            deq = jnp.take_along_axis(
                cb[t], c.reshape(B, mb, -1).astype(jnp.int32), axis=-1
            ).reshape(c.shape)
            frozen = blk_q.astype(bool)[t][:, :, None, None, None]
            pages = jnp.where(frozen, deq.astype(pages.dtype), pages)
        return pages.reshape(B, mb * bs, Hkv, Dh)

    k_all = expand(k_fp, k_codes, k_cb).astype(jnp.float32)
    v_all = expand(v_fp, v_codes, v_cb).astype(jnp.float32)
    qr = q.astype(jnp.float32).reshape(B, W, Hkv, G, Dh)
    s = jnp.einsum("bwhgd,bshd->bwhgs", qr, k_all,
                   preferred_element_type=jnp.float32) / jnp.sqrt(Dh * 1.0)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(mb * bs)[None, None]               # (1, 1, S)
    valid = jnp.asarray(kv_valid_len, jnp.int32)[:, None, None]
    valid_w = valid - (W - 1 - jnp.arange(W)[None, :, None])   # (B, W, 1)
    mask = pos < valid_w                                # (B, W, S)
    s = jnp.where(mask[:, :, None, None], s, BIG_NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, :, None, None], p, 0.0)
    out = jnp.einsum("bwhgs,bshd->bwhgd", p, v_all)
    out = out.reshape(B, W, Hq, Dh).astype(q.dtype)
    return out if windowed else out[:, 0]


def ref_fista(w, d, n, lam, eta, *, n_iters: int = 300):
    """FISTA with the same iterates as kernels.fista_quant, on (B, M) arrays."""
    B, M = w.shape
    eta = eta.reshape(B, 1)

    def body(i, carry):
        x_prev, y, t = carry
        recon = jnp.cumsum(y * d, axis=1)
        r = n * (w - recon)
        cums = jnp.cumsum(r, axis=1)
        total = cums[:, -1:]
        suffix = total - cums + r
        grad = -d * suffix
        v = y - eta * grad
        thr = eta * lam
        x = jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_next = x + ((t - 1.0) / t_next) * (x - x_prev)
        return (x, y_next, t_next)

    ones = jnp.ones_like(w)
    x, _, _ = lax.fori_loop(0, n_iters, body, (ones, ones, jnp.float32(1.0)))
    return x
