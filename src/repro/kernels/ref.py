"""Pure-jnp oracles for the Pallas kernels (numerically identical math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ref_quant_matmul(x, idx, codebook, out_dtype=None):
    """Dense reference: materialize W = codebook[idx], plain matmul."""
    w = jnp.take(codebook, idx.astype(jnp.int32), axis=0).astype(x.dtype)
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def ref_fista(w, d, n, lam, eta, *, n_iters: int = 300):
    """FISTA with the same iterates as kernels.fista_quant, on (B, M) arrays."""
    B, M = w.shape
    eta = eta.reshape(B, 1)

    def body(i, carry):
        x_prev, y, t = carry
        recon = jnp.cumsum(y * d, axis=1)
        r = n * (w - recon)
        cums = jnp.cumsum(r, axis=1)
        total = cums[:, -1:]
        suffix = total - cums + r
        grad = -d * suffix
        v = y - eta * grad
        thr = eta * lam
        x = jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_next = x + ((t - 1.0) / t_next) * (x - x_prev)
        return (x, y_next, t_next)

    ones = jnp.ones_like(w)
    x, _, _ = lax.fori_loop(0, n_iters, body, (ones, ones, jnp.float32(1.0)))
    return x
