"""Pallas TPU kernels for the perf-critical paths.

- fista_quant: batched sparse-LSQ solver (the paper's technique, MXU-native)
- quant_matmul: fused codebook-dequant matmul (quantized serving hot path)

Each kernel has a pure-jnp oracle in ref.py and a padded wrapper in ops.py;
tests sweep shapes/dtypes against the oracles in interpret mode.
"""
from .fista_quant import fista_quant
from .ops import default_interpret, power_iter_lipschitz, quant_matmul, solve_fista_batch
from .quant_matmul import quant_matmul as quant_matmul_raw
from .ref import ref_fista, ref_quant_matmul

__all__ = [
    "fista_quant", "quant_matmul", "quant_matmul_raw", "solve_fista_batch",
    "ref_fista", "ref_quant_matmul", "power_iter_lipschitz", "default_interpret",
]
