"""Pallas TPU kernels for the perf-critical paths.

- fista_quant: batched sparse-LSQ solver (the paper's technique, MXU-native)
- quant_matmul / quant_matmul_stacked: fused codebook-dequant matmul, flat
  and stacked-group (leading lax.scan group axis) forms (quantized serving
  hot path)
- paged_decode_attention / paged_prefill_attention: fused paged-attention
  flash decode and chunked prefill with double-buffered page DMA and
  in-VMEM codebook dequant (serving hot path)
- quantize_pages_device: batched on-device kmeans_ls for KV page freezing

Each kernel has a pure-jnp oracle in ref.py and a padded wrapper in ops.py;
tests sweep shapes/dtypes against the oracles in interpret mode.
"""
from .fista_quant import fista_quant
from .ops import (default_interpret, power_iter_lipschitz, quant_matmul,
                  quant_matmul_stacked, solve_fista_batch)
from .page_quant import quantize_pages_device, quantize_pages_fista
from .paged_attention import (modeled_hbm_bytes_per_token,
                              modeled_prefill_hbm_bytes_per_token, pack4,
                              paged_decode_attention,
                              paged_prefill_attention, unpack4)
from .quant_matmul import quant_matmul as quant_matmul_raw
from .quant_matmul import quant_matmul_stacked as quant_matmul_stacked_raw
from .ref import (ref_fista, ref_paged_decode, ref_quant_matmul,
                  ref_quant_matmul_stacked)

__all__ = [
    "fista_quant", "quant_matmul", "quant_matmul_raw", "quant_matmul_stacked",
    "quant_matmul_stacked_raw", "solve_fista_batch",
    "ref_fista", "ref_quant_matmul", "ref_quant_matmul_stacked",
    "power_iter_lipschitz", "default_interpret",
    "paged_decode_attention", "paged_prefill_attention", "ref_paged_decode",
    "pack4", "unpack4", "modeled_hbm_bytes_per_token",
    "modeled_prefill_hbm_bytes_per_token", "quantize_pages_device",
    "quantize_pages_fista",
]
