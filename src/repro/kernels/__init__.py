"""Pallas TPU kernels for the perf-critical paths.

- fista_quant: batched sparse-LSQ solver (the paper's technique, MXU-native)
- quant_matmul: fused codebook-dequant matmul (quantized serving hot path)
- paged_decode_attention: fused paged-attention flash decode with in-VMEM
  codebook dequant (serving decode hot path)
- quantize_pages_device: batched on-device kmeans_ls for KV page freezing

Each kernel has a pure-jnp oracle in ref.py and a padded wrapper in ops.py;
tests sweep shapes/dtypes against the oracles in interpret mode.
"""
from .fista_quant import fista_quant
from .ops import default_interpret, power_iter_lipschitz, quant_matmul, solve_fista_batch
from .page_quant import quantize_pages_device, quantize_pages_fista
from .paged_attention import (modeled_hbm_bytes_per_token, pack4,
                              paged_decode_attention, unpack4)
from .quant_matmul import quant_matmul as quant_matmul_raw
from .ref import ref_fista, ref_paged_decode, ref_quant_matmul

__all__ = [
    "fista_quant", "quant_matmul", "quant_matmul_raw", "solve_fista_batch",
    "ref_fista", "ref_quant_matmul", "power_iter_lipschitz", "default_interpret",
    "paged_decode_attention", "ref_paged_decode", "pack4", "unpack4",
    "modeled_hbm_bytes_per_token", "quantize_pages_device",
    "quantize_pages_fista",
]
