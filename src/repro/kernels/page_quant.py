"""Batched on-device page quantization for KV-cache freezing.

PR 1 froze full pages by pulling them to host and running the paper's
solvers one page at a time through `repro.core.quantize` (numpy CD /
host-orchestrated k-means), which stalls the serving engine for the whole
solve. This module runs the same clustering-based least-square recipe
(Algorithm 3: fix the membership matrix by clustering, then solve the
representative values by least squares) as one batched, jitted device
computation: every (page, group, k/v) row of a freeze event is solved in a
single dispatch, so the engine's freeze becomes an async device call that
overlaps subsequent decode steps.

Implementation, chosen for the serving hot loop:

  - each row is sketched to <= ``sketch_mult * L`` equal-mass quantiles
    *including both extremes* (the largest-magnitude KV values dominate
    attention logits; dropping the tail measurably breaks serve-time logit
    fidelity);
  - the clustering is the exact dynamic program for 1-D k-means on the
    sketch (`core.dp_optimal`'s method, vectorized over rows with O(1)
    interval costs from prefix sums) — globally optimal and fully
    deterministic, where restarted Lloyd is a local-optimum lottery whose
    realization wobbles with batch shape;
  - the final assignment (nearest center == midpoint intervals in 1-D) and
    LS refit run on the *full* row: per-cluster means are the eq. 17-20
    closed form on the chosen membership, so the reported codebook is the
    exact least-squares solution for its intervals (Algorithm 3 step 2).

The serving logit tolerance (abs<=2.5 / rel<=8% at 16 values) under this
solver is asserted in tests/test_serving.py.

``quantize_pages_fista`` is the lam-method device backend (registered for
``iter_l1`` in ``core.registry``): every row is sketched the same way,
solved by the batched FISTA Pallas kernel (`kernels.fista_quant`, the
paper's eq.-6 l1 objective) under a *per-row* lambda found by bisection so
the support fits the count budget, then assigned + LS-refit on the full
row exactly like the kmeans path. Count methods without a device entry
keep the host fallback in `serving.kv_cache`.

The ``*_spec`` wrappers at the bottom are the registry's device entry
points: ``(rows, spec) -> (codes, cb)`` keyed on one hashable QuantSpec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

_BIG = 1e30


def _assign(rows, centers):
    """Interval assignment: cluster id per value given sorted centers."""
    mid = 0.5 * (centers[:, 1:] + centers[:, :-1])           # (N, L-1)
    return jnp.sum(rows[:, :, None] > mid[:, None, :], axis=-1)


def _seg_mean(rows, idx, centers, L):
    """Per-cluster means (empty clusters keep their previous center)."""
    oh = jax.nn.one_hot(idx, L, dtype=jnp.float32)           # (N, E, L)
    num = jnp.einsum("re,rel->rl", rows, oh)
    den = jnp.sum(oh, axis=1)
    return jnp.where(den > 0, num / jnp.maximum(den, 1e-20), centers)


def _dp_centers(sketch, L):
    """Exact 1-D k-means on sorted rows via DP over segment boundaries.

    sketch: (R, Es) sorted. Returns (R, L) sorted centers (the segment
    means of the optimal L-partition; empty segments inherit the previous
    center). Interval SSE comes from prefix sums in O(1):
    cost[j, i] = sum_{t in [j, i)} (s_t - mean)^2.
    """
    R, Es = sketch.shape
    z = jnp.zeros((R, 1), jnp.float32)
    p1 = jnp.concatenate([z, jnp.cumsum(sketch, axis=1)], axis=1)
    p2 = jnp.concatenate([z, jnp.cumsum(sketch * sketch, axis=1)], axis=1)
    i = jnp.arange(Es + 1)
    n = jnp.maximum(i[None, :] - i[:, None], 1)              # (j, i)
    s1 = p1[:, None, :] - p1[:, :, None]                     # (R, j, i)
    s2 = p2[:, None, :] - p2[:, :, None]
    cost = s2 - s1 * s1 / n
    # j <= i are real (j == i is an empty segment at zero cost, which rows
    # with < L distinct values need); j > i is unreachable
    cost = jnp.where((i[None, :] >= i[:, None])[None],
                     jnp.maximum(cost, 0.0), _BIG)

    D = cost[:, 0, :]                                        # 1 segment
    def step(D, _):
        T = D[:, :, None] + cost                             # (R, j, i)
        return jnp.min(T, axis=1), jnp.argmin(T, axis=1)
    D, Js = lax.scan(step, D, None, length=L - 1)            # Js (L-1, R, Es+1)

    b = jnp.full((R,), Es, jnp.int32)                        # backtrack
    bounds = [b]
    for k in range(L - 2, -1, -1):
        b = Js[k][jnp.arange(R), b].astype(jnp.int32)
        bounds.append(b)
    bounds.append(jnp.zeros((R,), jnp.int32))
    bnd = jnp.stack(bounds[::-1], axis=1)                    # (R, L+1) ascending
    lo, hi = bnd[:, :-1], bnd[:, 1:]
    cnt = (hi - lo).astype(jnp.float32)
    seg = (jnp.take_along_axis(p1, hi, axis=1)
           - jnp.take_along_axis(p1, lo, axis=1))
    mean = seg / jnp.maximum(cnt, 1.0)
    # empty segments: carry the running max so centers stay sorted
    first = jnp.where(cnt[:, :1] > 0, mean[:, :1], sketch[:, :1])
    mean = jnp.concatenate([first, jnp.where(cnt[:, 1:] > 0, mean[:, 1:],
                                             -_BIG)], axis=1)
    return lax.associative_scan(jnp.maximum, mean, axis=1)


@functools.partial(jax.jit, static_argnames=("num_values", "refit",
                                             "sketch_mult"))
def quantize_pages_device(
    rows: jax.Array,        # (R, E) one row per (page, group, k/v) tensor
    *,
    num_values: int,
    refit: bool = True,
    sketch_mult: int = 4,   # DP runs on ~sketch_mult*L quantiles; DP cost
                            # is O(L * (sketch_mult*L)^2) per row
):
    """Batched exact-sketch kmeans_ls. Returns (codes (R, E) uint8,
    cb (R, L) f32).

    Deterministic: the DP is the global optimum of 1-D k-means on the
    quantile sketch, so results don't depend on batch composition or
    seeding. Codebooks are sorted ascending and always exactly
    ``num_values`` wide (empty clusters inherit their left neighbor,
    mirroring the host solver's pad-to-width behavior).
    """
    R, E = rows.shape
    L = num_values
    rows = rows.astype(jnp.float32)
    svals = jnp.sort(rows, axis=1)
    Es = min(E, max(L * sketch_mult, 2))
    # linspace ranks, *including both extremes* (see module docstring)
    spos = jnp.round(jnp.linspace(0, E - 1, Es)).astype(jnp.int32)
    centers = _dp_centers(svals[:, spos], L)
    idx = _assign(rows, centers)
    if refit:
        # eq. 20 closed form on the full-row assignment: per-cluster
        # (count-weighted) means == the LS refit on the interval support
        # (membership fixed, values solved — Algorithm 3's step 2)
        centers = _seg_mean(rows, idx, centers, L)
    return idx.astype(jnp.uint8), centers.astype(jnp.float32)


# ---------------------------------------------------------------- FISTA path


def _suffix_sum(x):
    cums = jnp.cumsum(x, axis=1)
    return cums[:, -1:] - cums + x


@functools.partial(jax.jit, static_argnames=("num_values", "n_iters",
                                             "bisect_steps", "lloyd_rounds",
                                             "interpret"))
def _fista_pages(rows, *, num_values, n_iters, bisect_steps, lloyd_rounds,
                 interpret):
    from .fista_quant import fista_quant

    R, E = rows.shape
    L = num_values
    T = 128                       # FISTA lane width
    rows = rows.astype(jnp.float32)
    svals = jnp.sort(rows, axis=1)
    Es = min(E, T)    # one lane block; a 2-block sketch measured *worse*
                      # (same budget spread over 2x the l1 coordinates)
    # equal-mass quantile sketch *including both row extremes* — same
    # fidelity argument as the kmeans path (module docstring)
    spos = jnp.round(jnp.linspace(0, E - 1, Es)).astype(jnp.int32)
    s = svals[:, spos]                                        # (R, Es) sorted
    nb = -(-Es // T)
    pad = nb * T - Es
    w = jnp.pad(s, ((0, 0), (0, pad)))
    d = jnp.pad(jnp.diff(s, axis=1, prepend=0.0), ((0, 0), (0, pad)))
    n = jnp.pad(jnp.full((R, Es), E / Es, jnp.float32), ((0, 0), (0, pad)))

    # precondition to unit column norms (same transform as ops.solve_fista_batch:
    # the solved problem is identical, the Lipschitz constant ~14x lower)
    nsuf = jnp.cumsum(n[:, ::-1], axis=1)[:, ::-1]
    z = d * d * nsuf
    scale = jnp.sqrt(jnp.where(z <= 0, 1.0, z))
    dt = d / scale

    def apply_op(x):              # x -> V^T diag(n) V x  (cumsum form)
        v = n * jnp.cumsum(x * dt, axis=1)
        return dt * _suffix_sum(v)

    def power_iter(i, carry):
        x, _ = carry
        y = apply_op(x)
        lam = jnp.maximum(jnp.sum(x * y, axis=1), 1e-30)
        x = y / (jnp.linalg.norm(y, axis=1, keepdims=True) + 1e-30)
        return x, lam

    x0 = jnp.broadcast_to(jnp.sin(jnp.arange(nb * T, dtype=jnp.float32)
                                  + 1.0), (R, nb * T))
    x0 = x0 / (jnp.linalg.norm(x0, axis=1, keepdims=True) + 1e-30)
    _, lip = lax.fori_loop(0, 40, power_iter, (x0, jnp.ones((R,))))
    eta = (1.0 / (lip * 1.01)).reshape(R, 1, 1)

    # lam_max: |gradient at alpha = 0|_inf per row in the *original*
    # coordinates (the per-coordinate threshold is lam/scale, the gradient
    # scales by 1/scale too) — alpha == 0 above it
    g0 = d * _suffix_sum(n * w)
    lam_hi = jnp.max(jnp.abs(g0), axis=1) * 1.001 + 1e-12

    def solve(lam_row):
        # lam scales 1/scale like d does, so the penalty stays lam*|alpha|
        # on the *original* coordinates (solve_fista_batch's transform)
        lam_full = lam_row[:, None] / scale * (n > 0)
        blk = lambda a: a.reshape(R, nb, T)
        alpha = fista_quant(blk(w), blk(dt), blk(n), blk(lam_full), eta,
                            n_iters=n_iters, block_t=T, interpret=interpret)
        return alpha.reshape(R, nb * T)

    def nnz_of(alpha):
        sup = jnp.abs(alpha) > 1e-12
        # distinct reconstruction levels: support size, +1 for the implicit
        # zero level when the first coordinate is off-support
        return jnp.sum(sup, axis=1) + (1 - sup[:, 0].astype(jnp.int32)), sup

    def bisect(i, carry):
        lo, hi, best = carry
        mid = 0.5 * (lo + hi)
        alpha = solve(mid)
        nnz, _ = nnz_of(alpha)
        feas = nnz <= L            # nnz is non-increasing in lambda
        lo = jnp.where(feas, lo, mid)
        hi = jnp.where(feas, mid, hi)
        best = jnp.where(feas[:, None], alpha, best)
        return lo, hi, best

    init = (jnp.zeros((R,)), lam_hi, jnp.zeros((R, nb * T)))
    _, _, alpha = lax.fori_loop(0, bisect_steps, bisect, init)

    # support -> level ids on the sketch (0-based, the implicit pre-support
    # zero segment is its own level), then count-weighted segment means =
    # the LS refit on the sketch support
    _, sup = nnz_of(alpha)
    sid = jnp.cumsum(sup.astype(jnp.int32), axis=1)
    lid = jnp.clip(sid - sup[:, :1].astype(jnp.int32), 0, L - 1)
    ohn = jax.nn.one_hot(lid, L, dtype=jnp.float32) * n[:, :, None]
    num = jnp.einsum("re,rel->rl", w, ohn)
    den = jnp.sum(ohn, axis=1)
    mean = jnp.where(den > 0, num / jnp.maximum(den, 1e-20), -_BIG)
    # segments are contiguous runs of sorted values, so nonempty means are
    # ascending; empty levels inherit their left neighbor (static width L)
    first = jnp.where(den[:, :1] > 0, mean[:, :1], s[:, :1])
    centers = lax.associative_scan(
        jnp.maximum, jnp.concatenate([first, mean[:, 1:]], axis=1), axis=1)
    # polish on the *full* row: each round re-fixes the membership and
    # re-solves the values (Algorithm 3's alternation, seeded by the l1
    # support instead of a random init), then a final assignment + eq. 20
    # LS refit — the same contract as the kmeans path: the returned
    # codebook is the exact least-squares solution for its membership
    def polish(_, c):
        return _seg_mean(rows, _assign(rows, c), c, L)

    centers = lax.fori_loop(0, lloyd_rounds, polish, centers)
    idx = _assign(rows, centers)
    centers = _seg_mean(rows, idx, centers, L)
    return idx.astype(jnp.uint8), centers.astype(jnp.float32)


def quantize_pages_fista(
    rows: jax.Array,        # (R, E) one row per (page, group, k/v) tensor
    *,
    num_values: int,
    n_iters: int = 100,
    bisect_steps: int = 14,
    lloyd_rounds: int = 0,
    interpret: bool | None = None,
):
    """Batched lam-method page solver: sketch -> per-row lambda bisection
    through the FISTA Pallas kernel -> full-row assignment + LS refit.

    Returns (codes (R, E) uint8, cb (R, L) f32) — the same contract as
    ``quantize_pages_device``, so the serving freeze path treats both as
    interchangeable device backends. The bisection finds, per row, the
    smallest lambda whose l1 support fits the ``num_values`` budget
    (support count is non-increasing in lambda), i.e. the largest support
    the budget admits; codebooks are sorted ascending, exactly L wide.

    ``lloyd_rounds`` optionally alternates assignment/values on the full
    row before the final refit (Algorithm 3's alternation seeded by the l1
    support). It lowers row MSE monotonically but measurably does NOT
    lower the serve-time max-logit deviation (one borderline codebook can
    move a single worst logit either way), so the default keeps the pure
    l1-support + eq. 20 contract, which also measures the best
    serve-verification margin.
    """
    if interpret is None:
        from .ops import default_interpret

        interpret = default_interpret()
    return _fista_pages(rows, num_values=num_values, n_iters=n_iters,
                        bisect_steps=bisect_steps, lloyd_rounds=lloyd_rounds,
                        interpret=interpret)


# ------------------------------------------------- registry device entries
# (rows, spec) -> (codes, cb); referenced by dotted name from core.registry
# so importing repro.core never pulls kernel code. The device solvers are
# deterministic (exact DP / FISTA), so spec.seed is meaningless here;
# spec.clip applies to the codebook exactly like the host path (eq. 21).


def _apply_clip(codes, cb, spec):
    if spec.clip is not None:
        cb = jnp.clip(cb, spec.clip[0], spec.clip[1])
    return codes, cb


def quantize_pages_kmeans_spec(rows, spec):
    return _apply_clip(*quantize_pages_device(
        rows, num_values=spec.num_values, refit=True), spec)


def quantize_pages_kmeans_raw_spec(rows, spec):
    return _apply_clip(*quantize_pages_device(
        rows, num_values=spec.num_values, refit=False), spec)


def quantize_pages_fista_spec(rows, spec):
    return _apply_clip(*quantize_pages_fista(
        rows, num_values=spec.num_values), spec)
