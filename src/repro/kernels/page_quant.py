"""Batched on-device page quantization for KV-cache freezing.

PR 1 froze full pages by pulling them to host and running the paper's
solvers one page at a time through `repro.core.quantize` (numpy CD /
host-orchestrated k-means), which stalls the serving engine for the whole
solve. This module runs the same clustering-based least-square recipe
(Algorithm 3: fix the membership matrix by clustering, then solve the
representative values by least squares) as one batched, jitted device
computation: every (page, group, k/v) row of a freeze event is solved in a
single dispatch, so the engine's freeze becomes an async device call that
overlaps subsequent decode steps.

Implementation, chosen for the serving hot loop:

  - each row is sketched to <= ``sketch_mult * L`` equal-mass quantiles
    *including both extremes* (the largest-magnitude KV values dominate
    attention logits; dropping the tail measurably breaks serve-time logit
    fidelity);
  - the clustering is the exact dynamic program for 1-D k-means on the
    sketch (`core.dp_optimal`'s method, vectorized over rows with O(1)
    interval costs from prefix sums) — globally optimal and fully
    deterministic, where restarted Lloyd is a local-optimum lottery whose
    realization wobbles with batch shape;
  - the final assignment (nearest center == midpoint intervals in 1-D) and
    LS refit run on the *full* row: per-cluster means are the eq. 17-20
    closed form on the chosen membership, so the reported codebook is the
    exact least-squares solution for its intervals (Algorithm 3 step 2).

The serving logit tolerance (abs<=2.5 / rel<=8% at 16 values) under this
solver is asserted in tests/test_serving.py.

lam-parameterized freezing (routing rows through the batched FISTA Pallas
kernel in `kernels.fista_quant` plus a per-row lambda bisection to hit the
4-bit budget) is the designed follow-on; count methods other than
kmeans/kmeans_ls keep the host fallback in `serving.kv_cache`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

_BIG = 1e30


def _assign(rows, centers):
    """Interval assignment: cluster id per value given sorted centers."""
    mid = 0.5 * (centers[:, 1:] + centers[:, :-1])           # (N, L-1)
    return jnp.sum(rows[:, :, None] > mid[:, None, :], axis=-1)


def _seg_mean(rows, idx, centers, L):
    """Per-cluster means (empty clusters keep their previous center)."""
    oh = jax.nn.one_hot(idx, L, dtype=jnp.float32)           # (N, E, L)
    num = jnp.einsum("re,rel->rl", rows, oh)
    den = jnp.sum(oh, axis=1)
    return jnp.where(den > 0, num / jnp.maximum(den, 1e-20), centers)


def _dp_centers(sketch, L):
    """Exact 1-D k-means on sorted rows via DP over segment boundaries.

    sketch: (R, Es) sorted. Returns (R, L) sorted centers (the segment
    means of the optimal L-partition; empty segments inherit the previous
    center). Interval SSE comes from prefix sums in O(1):
    cost[j, i] = sum_{t in [j, i)} (s_t - mean)^2.
    """
    R, Es = sketch.shape
    z = jnp.zeros((R, 1), jnp.float32)
    p1 = jnp.concatenate([z, jnp.cumsum(sketch, axis=1)], axis=1)
    p2 = jnp.concatenate([z, jnp.cumsum(sketch * sketch, axis=1)], axis=1)
    i = jnp.arange(Es + 1)
    n = jnp.maximum(i[None, :] - i[:, None], 1)              # (j, i)
    s1 = p1[:, None, :] - p1[:, :, None]                     # (R, j, i)
    s2 = p2[:, None, :] - p2[:, :, None]
    cost = s2 - s1 * s1 / n
    # j <= i are real (j == i is an empty segment at zero cost, which rows
    # with < L distinct values need); j > i is unreachable
    cost = jnp.where((i[None, :] >= i[:, None])[None],
                     jnp.maximum(cost, 0.0), _BIG)

    D = cost[:, 0, :]                                        # 1 segment
    def step(D, _):
        T = D[:, :, None] + cost                             # (R, j, i)
        return jnp.min(T, axis=1), jnp.argmin(T, axis=1)
    D, Js = lax.scan(step, D, None, length=L - 1)            # Js (L-1, R, Es+1)

    b = jnp.full((R,), Es, jnp.int32)                        # backtrack
    bounds = [b]
    for k in range(L - 2, -1, -1):
        b = Js[k][jnp.arange(R), b].astype(jnp.int32)
        bounds.append(b)
    bounds.append(jnp.zeros((R,), jnp.int32))
    bnd = jnp.stack(bounds[::-1], axis=1)                    # (R, L+1) ascending
    lo, hi = bnd[:, :-1], bnd[:, 1:]
    cnt = (hi - lo).astype(jnp.float32)
    seg = (jnp.take_along_axis(p1, hi, axis=1)
           - jnp.take_along_axis(p1, lo, axis=1))
    mean = seg / jnp.maximum(cnt, 1.0)
    # empty segments: carry the running max so centers stay sorted
    first = jnp.where(cnt[:, :1] > 0, mean[:, :1], sketch[:, :1])
    mean = jnp.concatenate([first, jnp.where(cnt[:, 1:] > 0, mean[:, 1:],
                                             -_BIG)], axis=1)
    return lax.associative_scan(jnp.maximum, mean, axis=1)


@functools.partial(jax.jit, static_argnames=("num_values", "refit",
                                             "sketch_mult"))
def quantize_pages_device(
    rows: jax.Array,        # (R, E) one row per (page, group, k/v) tensor
    *,
    num_values: int,
    refit: bool = True,
    sketch_mult: int = 4,   # DP runs on ~sketch_mult*L quantiles; DP cost
                            # is O(L * (sketch_mult*L)^2) per row
):
    """Batched exact-sketch kmeans_ls. Returns (codes (R, E) uint8,
    cb (R, L) f32).

    Deterministic: the DP is the global optimum of 1-D k-means on the
    quantile sketch, so results don't depend on batch composition or
    seeding. Codebooks are sorted ascending and always exactly
    ``num_values`` wide (empty clusters inherit their left neighbor,
    mirroring the host solver's pad-to-width behavior).
    """
    R, E = rows.shape
    L = num_values
    rows = rows.astype(jnp.float32)
    svals = jnp.sort(rows, axis=1)
    Es = min(E, max(L * sketch_mult, 2))
    # linspace ranks, *including both extremes* (see module docstring)
    spos = jnp.round(jnp.linspace(0, E - 1, Es)).astype(jnp.int32)
    centers = _dp_centers(svals[:, spos], L)
    idx = _assign(rows, centers)
    if refit:
        # eq. 20 closed form on the full-row assignment: per-cluster
        # (count-weighted) means == the LS refit on the interval support
        # (membership fixed, values solved — Algorithm 3's step 2)
        centers = _seg_mean(rows, idx, centers, L)
    return idx.astype(jnp.uint8), centers.astype(jnp.float32)
