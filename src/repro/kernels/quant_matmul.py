"""Fused codebook-dequant matmul Pallas TPU kernel (serving hot path).

Value-shared weights (the paper's output format) are stored as
(indices uintX, codebook fpN). Serving computes y = x @ W with W never
materialized in HBM: each (bk, bn) index tile is gathered against the
VMEM-resident codebook and fed straight to the MXU. This keeps weight HBM
traffic at ~1 byte/param (vs 2 for bf16), which is what makes the decode
step - memory-bound at batch*1 token - faster end to end.

Grid: (M/bm, N/bn, K/bk), k innermost ('arbitrary'); accumulation in an f32
VMEM scratch tile, written out on the last k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(x_ref, idx_ref, cb_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_tile = jnp.take(cb_ref[...], idx_ref[...].astype(jnp.int32), axis=0)
    acc_ref[...] += jnp.dot(
        x_ref[...], w_tile.astype(x_ref.dtype), preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def quant_matmul(
    x: jax.Array,            # (M, K)
    idx: jax.Array,          # (K, N) integer codes
    codebook: jax.Array,     # (C,) fp values
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    K2, N = idx.shape
    assert K == K2, (x.shape, idx.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"shapes ({M},{K},{N}) must tile by ({bm},{bk},{bn}); pad upstream")
    out_dtype = out_dtype or x.dtype
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((codebook.shape[0],), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, idx, codebook)
