"""Fused codebook-dequant matmul Pallas TPU kernel (serving hot path).

Value-shared weights (the paper's output format) are stored as
(indices uintX, codebook fpN). Serving computes y = x @ W with W never
materialized in HBM: each (bk, bn) index tile is gathered against the
VMEM-resident codebook and fed straight to the MXU. This keeps weight HBM
traffic at ~1 byte/param (vs 2 for bf16), which is what makes the decode
step - memory-bound at batch*1 token - faster end to end.

Grid: (M/bm, N/bn, K/bk), k innermost ('arbitrary'); accumulation in an f32
VMEM scratch tile, written out on the last k step.

``quant_matmul_stacked`` is the same tile with a leading group axis as the
outermost grid dimension: stacked weights (codebook (G, L) / indices
(G, K, N), the ``stack_quantized`` form that rides through ``lax.scan``)
are served group-by-group with that group's codebook VMEM-resident — one
call covers a whole scanned layer group with zero per-call dequant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(x_ref, idx_ref, cb_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_tile = jnp.take(cb_ref[...], idx_ref[...].astype(jnp.int32), axis=0)
    acc_ref[...] += jnp.dot(
        x_ref[...], w_tile.astype(x_ref.dtype), preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def quant_matmul(
    x: jax.Array,            # (M, K)
    idx: jax.Array,          # (K, N) integer codes
    codebook: jax.Array,     # (C,) fp values
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    K2, N = idx.shape
    assert K == K2, (x.shape, idx.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"shapes ({M},{K},{N}) must tile by ({bm},{bk},{bn}); pad upstream")
    out_dtype = out_dtype or x.dtype
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((codebook.shape[0],), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, idx, codebook)


def _stacked_kernel(x_ref, idx_ref, cb_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_tile = jnp.take(cb_ref[0], idx_ref[0].astype(jnp.int32), axis=0)
    acc_ref[...] += jnp.dot(
        x_ref[0], w_tile.astype(x_ref.dtype),
        preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def quant_matmul_stacked(
    x: jax.Array,            # (G, M, K) per-group activations
    idx: jax.Array,          # (G, K, N) integer codes
    codebook: jax.Array,     # (G, L) per-group fp values
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Stacked-group fused dequant matmul: y[g] = x[g] @ codebook[g][idx[g]].

    The group axis is the outermost grid dimension; each (g, i, j, k) step
    gathers its (bk, bn) index tile against group g's (L,) codebook held in
    VMEM, so scanned layer groups serve from uint8 codes without any
    per-call dense materialization.
    """
    G, M, K = x.shape
    G2, K2, N = idx.shape
    assert G == G2 and K == K2, (x.shape, idx.shape)
    assert codebook.ndim == 2 and codebook.shape[0] == G, codebook.shape
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"shapes ({M},{K},{N}) must tile by ({bm},{bk},{bn}); pad upstream")
    out_dtype = out_dtype or x.dtype
    grid = (G, M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _stacked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
            pl.BlockSpec((1, codebook.shape[1]), lambda g, i, j, k: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")
        ),
        interpret=interpret,
    )(x, idx, codebook)
