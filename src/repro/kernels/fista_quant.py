"""Batched FISTA sparse-LSQ quantization solver - Pallas TPU kernel.

TPU-native replacement for the paper's sequential coordinate descent
(DESIGN.md §3): every FISTA iteration on the cumulative design matrix V is

    recon   = cumsum(y * d)                  # V @ y
    r       = n * (w - recon)                # weighted residual
    grad    = -d * suffix_sum(r)             # V^T diag(n) r
    x       = shrink(y - eta*grad, eta*lam)

and both scans are lowered to *blocked triangular matmuls on the MXU*:
rows are laid out (nb, T) with T=128 lanes; within-block cumsum is
X @ triu_ones(T) (one MXU op), across-block offsets are a second tiny
triangular matmul; the suffix sum reuses the same cumsum
(suffix = total - cumsum + x). One grid step = one tensor row, so a whole
model's PTQ is a single kernel launch.

Sequential-scan CD remains the host/CPU path (repro.core.cd); this kernel is
validated against ref.ref_fista (identical iterates, pure jnp) across
shapes/dtypes in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _blocked_cumsum(x, triu_t, triu_nb_strict):
    """(nb, T) row-major cumulative sum via two triangular matmuls."""
    within = jnp.dot(x, triu_t, preferred_element_type=jnp.float32)   # (nb, T)
    bsums = within[:, -1]                                             # (nb,)
    offsets = jnp.dot(bsums[None, :], triu_nb_strict,
                      preferred_element_type=jnp.float32)[0]          # (nb,)
    return within + offsets[:, None]


def _kernel(nsteps, w_ref, d_ref, n_ref, lam_ref, eta_ref, triu_t_ref,
            triu_nb_ref, alpha_ref):
    w = w_ref[0]        # (nb, T)
    d = d_ref[0]
    n = n_ref[0]
    lam = lam_ref[0]
    eta = eta_ref[0, 0, 0]
    triu_t = triu_t_ref[...]
    triu_nb = triu_nb_ref[...]

    ones = jnp.ones_like(w)

    def body(i, carry):
        x_prev, y, t = carry
        recon = _blocked_cumsum(y * d, triu_t, triu_nb)
        r = n * (w - recon)
        cums = _blocked_cumsum(r, triu_t, triu_nb)
        total = cums[-1, -1]
        suffix = total - cums + r
        grad = -d * suffix
        v = y - eta * grad
        thr = eta * lam
        x = jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_next = x + ((t - 1.0) / t_next) * (x - x_prev)
        return (x, y_next, t_next)

    x, _, _ = lax.fori_loop(0, nsteps, body, (ones, ones, jnp.float32(1.0)))
    alpha_ref[0] = x


@functools.partial(
    jax.jit, static_argnames=("n_iters", "block_t", "interpret")
)
def fista_quant(
    w: jax.Array,      # (B, nb, T) unique values (padded with zeros)
    d: jax.Array,      # (B, nb, T) column scales (0 on padding)
    n: jax.Array,      # (B, nb, T) weights (0 on padding)
    lam: jax.Array,    # (B, nb, T) per-coordinate l1 penalty
    eta: jax.Array,    # (B, 1, 1) step size 1/L per problem
    *,
    n_iters: int = 300,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns alpha (B, nb, T). See ops.solve_fista for the padded wrapper."""
    B, nb, T = w.shape
    assert T == block_t, (w.shape, block_t)
    triu_t = jnp.triu(jnp.ones((T, T), jnp.float32))
    triu_nb = jnp.triu(jnp.ones((nb, nb), jnp.float32), k=1)  # strict: excl. own block
    row = pl.BlockSpec((1, nb, T), lambda b: (b, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, n_iters),
        grid=(B,),
        in_specs=[row, row, row, row,
                  pl.BlockSpec((1, 1, 1), lambda b: (b, 0, 0)),
                  pl.BlockSpec((T, T), lambda b: (0, 0)),
                  pl.BlockSpec((nb, nb), lambda b: (0, 0))],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((B, nb, T), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(w, d, n, lam, eta, triu_t, triu_nb)
