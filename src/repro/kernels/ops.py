"""Jit'd wrappers around the Pallas kernels: padding, step sizes, dispatch.

``interpret`` defaults to True off-TPU (the kernels validate on CPU via the
Pallas interpreter; on TPU they compile to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .fista_quant import fista_quant as _fista_kernel
from .quant_matmul import quant_matmul as _qmm_kernel
from .quant_matmul import quant_matmul_stacked as _qmm_stacked_kernel
from .ref import ref_fista, ref_quant_matmul


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=value)


def power_iter_lipschitz(d: np.ndarray, n: np.ndarray, iters: int = 50) -> np.ndarray:
    """sigma_max(diag(sqrt(n)) V)^2 per batch row via power iteration.

    d, n: (B, M). The operator is applied with cumsum/suffix-sum only -
    O(B*M) per iteration, no materialized V.
    """
    B, M = d.shape
    rng = np.random.default_rng(0)
    x = rng.normal(size=(B, M))
    x /= np.linalg.norm(x, axis=1, keepdims=True) + 1e-30
    lam = np.ones(B)
    for _ in range(iters):
        v = np.cumsum(x * d, axis=1)              # V x
        v *= n                                     # diag(n)
        cums = np.cumsum(v, axis=1)
        suffix = cums[:, -1:] - cums + v
        y = d * suffix                             # V^T diag(n) V x
        lam = np.maximum((x * y).sum(1), 1e-30)
        x = y / (np.linalg.norm(y, axis=1, keepdims=True) + 1e-30)
    return lam  # Rayleigh quotient at convergence = L


def solve_fista_batch(
    w_rows: np.ndarray,     # (B, M) sorted unique values, zero-padded
    d_rows: np.ndarray,     # (B, M) column scales, 0 on padding
    n_rows: np.ndarray,     # (B, M) weights, 0 on padding
    lam: float | np.ndarray,
    *,
    n_iters: int = 300,
    block_t: int = 128,
    penalize_first: bool = True,
    interpret: bool | None = None,
    use_kernel: bool = True,
    precondition: bool = True,
):
    """Batched eq.-6 solve. Returns alpha (B, M) as np.ndarray.

    precondition=True rescales columns to unit norm (alpha_bar = sqrt(z)*alpha,
    per-coordinate thresholds lam/sqrt(z)) - measured ~14x lower Lipschitz
    constant and ~4-10x fewer iterations to the CD objective (EXPERIMENTS.md
    §Perf/kernel). The solved problem is mathematically identical.
    """
    if interpret is None:
        interpret = default_interpret()
    B, M = w_rows.shape
    lam_rows = np.broadcast_to(
        np.asarray(lam, np.float32).reshape(-1, 1), (B, M)).copy()
    lam_rows[n_rows == 0] = 0.0      # padding: no penalty
    if not penalize_first:
        lam_rows[:, 0] = 0.0
    d_rows = np.asarray(d_rows, np.float32)
    if precondition:
        nsuf = np.cumsum(n_rows[:, ::-1], axis=1)[:, ::-1]
        z = d_rows * d_rows * nsuf
        scale = np.sqrt(np.where(z <= 0, 1.0, z)).astype(np.float32)
        d_rows = d_rows / scale
        lam_rows = lam_rows / scale
    else:
        scale = np.ones_like(d_rows)
    L = power_iter_lipschitz(d_rows, n_rows)
    eta = (1.0 / (L * 1.01)).astype(np.float32)

    if use_kernel:
        wp = _pad_to(w_rows.astype(np.float32), block_t, 1)
        dp = _pad_to(d_rows.astype(np.float32), block_t, 1)
        np_ = _pad_to(n_rows.astype(np.float32), block_t, 1)
        lp = _pad_to(lam_rows, block_t, 1)
        nb = wp.shape[1] // block_t
        shape3 = (B, nb, block_t)
        alpha = _fista_kernel(
            jnp.asarray(wp.reshape(shape3)), jnp.asarray(dp.reshape(shape3)),
            jnp.asarray(np_.reshape(shape3)), jnp.asarray(lp.reshape(shape3)),
            jnp.asarray(eta.reshape(B, 1, 1)),
            n_iters=n_iters, block_t=block_t, interpret=interpret,
        )
        alpha = np.array(alpha).reshape(B, -1)[:, :M]
    else:
        alpha = np.array(ref_fista(
            jnp.asarray(w_rows, jnp.float32), jnp.asarray(d_rows, jnp.float32),
            jnp.asarray(n_rows, jnp.float32), jnp.asarray(lam_rows),
            jnp.asarray(eta), n_iters=n_iters))
    alpha = alpha / scale   # undo preconditioning: alpha = alpha_bar / sqrt(z)
    alpha[n_rows == 0] = 0.0
    return alpha


def quant_matmul(x, idx, codebook, *, bm=None, bn=None, bk=None,
                 out_dtype=None, interpret: bool | None = None):
    """Shape-flexible fused dequant matmul: pads to tile multiples, unpads."""
    if interpret is None:
        interpret = default_interpret()
    M, K = x.shape
    _, N = idx.shape
    bm = bm or min(128, M)
    bn = bn or min(128, N)
    bk = bk or min(128, K)
    padM, padN, padK = (-M) % bm, (-N) % bn, (-K) % bk
    xp = jnp.pad(x, ((0, padM), (0, padK)))
    ip = jnp.pad(idx, ((0, padK), (0, padN)))
    out = _qmm_kernel(xp, ip, codebook, bm=bm, bn=bn, bk=bk,
                      out_dtype=out_dtype, interpret=interpret)
    return out[:M, :N]


def quant_matmul_stacked(x, idx, codebook, *, bm=None, bn=None, bk=None,
                         out_dtype=None, interpret: bool | None = None):
    """Shape-flexible stacked-group dequant matmul: x (G, M, K) against
    codes (G, K, N) + per-group codebooks (G, L); pads to tile multiples,
    unpads."""
    if interpret is None:
        interpret = default_interpret()
    G, M, K = x.shape
    _, _, N = idx.shape
    bm = bm or min(128, M)
    bn = bn or min(128, N)
    bk = bk or min(128, K)
    padM, padN, padK = (-M) % bm, (-N) % bn, (-K) % bk
    xp = jnp.pad(x, ((0, 0), (0, padM), (0, padK)))
    ip = jnp.pad(idx, ((0, 0), (0, padK), (0, padN)))
    out = _qmm_stacked_kernel(xp, ip, codebook, bm=bm, bn=bn, bk=bk,
                              out_dtype=out_dtype, interpret=interpret)
    return out[:, :M, :N]
