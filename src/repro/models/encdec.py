"""Encoder-decoder model (whisper-tiny backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, S_enc, d_model) to the encoder (bidirectional
attention). The decoder is the standard causal stack with per-layer
cross-attention; decode caches self-attention KV plus once-computed cross K/V.
Positions use RoPE for both stacks (architecture-equivalent stand-in for
whisper's learned absolute embeddings; noted in the config).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec

from . import attention as attn_lib
from .norms import init_rms, rms_norm
from .transformer import (_embed_in, _lm_head, _scan_groups, init_layer,
                          init_lm, init_lm_cache)


def _enc_cfg(cfg):
    """The encoder reuses the group machinery with its own (bidir) pattern."""
    return dataclasses.replace(
        cfg, group=(LayerSpec(mixer="attn", ffn="dense"),),
        head_layers=(), n_layers=cfg.n_enc_layers)


def init_encdec(cfg, rng):
    k_enc, k_dec = jax.random.split(rng)
    params = init_lm(cfg, k_dec)                     # decoder + embed + head
    ecfg = _enc_cfg(cfg)
    enc = init_lm(ecfg, k_enc)
    params["enc_groups"] = enc["groups"]
    params["enc_norm"] = init_rms(cfg.d_model, cfg.dtype("param"))
    return params


def encode(params, cfg, enc_embeds):
    ecfg = _enc_cfg(cfg)
    x = enc_embeds.astype(cfg.dtype("compute"))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _ = _scan_groups(params, ecfg, x, positions, causal=not cfg.enc_bidirectional,
                        groups_key="enc_groups")
    return rms_norm(x, params["enc_norm"])


def encdec_forward(params, cfg, batch, *, train=True, return_hidden=False):
    """batch: enc_embeds (B,Se,D), tokens (B,Sd) -> logits (B,Sd,V)."""
    enc_out = encode(params, cfg, batch["enc_embeds"])
    x = _embed_in(params, cfg, {"tokens": batch["tokens"]})
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _ = _scan_groups(params, cfg, x, positions, train=train,
                        cross={"enc_out": enc_out})
    if return_hidden:
        return x
    return _lm_head(params, cfg, x)


def init_encdec_cache(cfg, batch, max_len, enc_len):
    cache = init_lm_cache(cfg, batch, max_len)
    dtype = cfg.dtype("compute")
    kv = (cfg.n_groups, batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
    cache["cross"] = {"l0": {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}}
    return cache


def encdec_prefill(params, cfg, batch, cache):
    """Encode + decoder prefill; fills self KV and cross KV."""
    enc_out = encode(params, cfg, batch["enc_embeds"])
    # per-layer cross K/V (stacked over groups) via vmap over group params
    cross = {"l0": jax.vmap(
        lambda p: attn_lib.init_cross_kv(p["l0"]["mixer"], cfg, enc_out)
    )(params["groups"])}
    x = _embed_in(params, cfg, {"tokens": batch["tokens"]})
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, new_cache = _scan_groups(params, cfg, x, positions, cache=cache,
                                cache_index=0, cross=cross)
    new_cache["cross"] = cross
    return _lm_head(params, cfg, x), new_cache


def encdec_decode_step(params, cfg, tokens, cache, cache_index):
    x = _embed_in(params, cfg, {"tokens": tokens})
    B = x.shape[0]
    positions = jnp.broadcast_to(cache_index, (B, 1)).astype(jnp.int32)
    x, new_cache = _scan_groups(params, cfg, x, positions, cache=cache,
                                cache_index=cache_index, cross=cache["cross"])
    new_cache["cross"] = cache["cross"]
    return _lm_head(params, cfg, x), new_cache
