"""Token-choice top-k Mixture of Experts with shared experts.

Capacity-based scatter dispatch (global formulation; GSPMD shards it:
experts over 'model', token/capacity dims over the batch axes). Overflow
tokens beyond capacity_factor * T * K / E are dropped (standard). Shared
experts (deepseek) run densely on every token.

The router runs in float32 (cfg.router_dtype) regardless of compute dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.hints import hint
from .ffn import _act, _dense, init_ffn


def init_moe(cfg, rng, dtype):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.expert_ff
    ks = jax.random.split(rng, 5)
    scale = 1.0 / np.sqrt(D)
    p = {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * scale
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * scale
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * scale
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                   / np.sqrt(F)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_ffn(cfg, ks[4], dtype,
                               d_ff=cfg.expert_ff * cfg.n_shared_experts)
    return p


def _row_dispatch(flat_e, E, C):
    """Sort-based dispatch plan for ONE batch row (no scatter anywhere).

    flat_e: (SK,) expert id per (token,k) assignment. Returns
      slot_tok:  (E, C) assignment index filling each expert slot
      slot_ok:   (E, C) slot validity
      tok_pos:   (SK,) position of each assignment within its expert
    Everything is argsort/searchsorted/iota - GSPMD shards the vmapped batch
    dim cleanly, unlike computed-index scatter (which replicated the whole
    dispatch at 48 GiB/device; EXPERIMENTS.md §Perf)."""
    SK = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)                    # (SK,)
    sorted_e = flat_e[order]
    first_of = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")  # (E,)
    idx_in_sorted = first_of[:, None] + jnp.arange(C)[None, :]  # (E,C)
    safe_idx = jnp.clip(idx_in_sorted, 0, SK - 1)
    slot_ok = (idx_in_sorted < SK) & (sorted_e[safe_idx] == jnp.arange(E)[:, None])
    slot_tok = order[safe_idx]                                  # (E,C)
    # inverse: rank of each assignment within its expert
    first_all = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(SK) - first_all
    tok_pos = jnp.zeros((SK,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    return slot_tok, slot_ok, tok_pos


def moe_ffn(params, cfg, x):
    """x: (B, S, D) -> (B, S, D).

    Grouped token-choice top-k with per-row capacity C = ceil(cf*S*K/E):
    dispatch AND combine are batched gathers (take_along_axis), experts
    shard over 'model', rows over the batch axes.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                        # (B,S,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    C = int(np.ceil(cfg.capacity_factor * S * K / E))
    flat_e = top_i.reshape(B, S * K)
    slot_tok, slot_ok, tok_pos = jax.vmap(
        lambda fe: _row_dispatch(fe, E, C))(flat_e)               # (B,E,C)...

    # dispatch: gather tokens into (B, E, C, D); slot -> source token s = a//K
    src_tok = (slot_tok // K).reshape(B, E * C)                   # (B, E*C)
    buf = jnp.take_along_axis(x, src_tok[..., None], axis=1)      # (B,E*C,D)
    buf = buf.reshape(B, E, C, D) * slot_ok[..., None].astype(x.dtype)
    buf = hint(buf, "moe_buf")

    h = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    h = _act(h, cfg.act) * jnp.einsum("becd,edf->becf", buf, params["w_up"])
    h = hint(h, "moe_h")
    out = jnp.einsum("becf,efd->becd", h, params["w_down"])
    out = hint(out, "moe_buf")

    # combine: gather each assignment's slot back; dropped tokens get 0
    keep = tok_pos < C
    gather_idx = flat_e * C + jnp.where(keep, tok_pos, 0)         # (B,SK)
    y_tok = jnp.take_along_axis(out.reshape(B, E * C, D),
                                gather_idx[..., None], axis=1)
    y_tok = hint(y_tok, "moe_tok")
    y_tok = y_tok * keep[..., None].astype(out.dtype)
    y = (y_tok.reshape(B, S, K, D)
         * top_w[..., None].astype(out.dtype)).sum(axis=2)
    if "shared" in params:
        sh = params["shared"]
        y = y + (_act(x @ sh["w_gate"], cfg.act) * (x @ sh["w_up"])) @ sh["w_down"]
    return hint(y, "hidden")


def router_aux_loss(params, cfg, x):
    """Load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    B, S, D = x.shape
    xt = x.reshape(-1, D).astype(jnp.float32)
    probs = jax.nn.softmax(xt @ params["router"], axis=-1)
    top_i = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top_i, cfg.n_experts, dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(f * p)
