"""Feed-forward blocks: gated MLP (SwiGLU / GeGLU).

Projections go through ``quant.serve.qmatmul``: dense weights hit the plain
matmul, value-shared QuantizedTensor leaves (PTQ checkpoints served without
dequantizing) hit the fused codebook-dequant kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.serve import qmatmul
from repro.runtime.hints import hint


def _dense(rng, d_in, d_out, dtype):
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32)
            / np.sqrt(d_in)).astype(dtype)


def init_ffn(cfg, rng, dtype, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": _dense(ks[0], cfg.d_model, d_ff, dtype),
        "w_up": _dense(ks[1], cfg.d_model, d_ff, dtype),
        "w_down": _dense(ks[2], d_ff, cfg.d_model, dtype),
    }


def _act(x, kind: str):
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.silu(x)


def ffn(params, cfg, x):
    h = _act(qmatmul(x, params["w_gate"]), cfg.act) * qmatmul(x, params["w_up"])
    h = hint(h, "ffn")
    return hint(qmatmul(h, params["w_down"]), "hidden")
