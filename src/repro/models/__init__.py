"""Composable model library: one facade over LM and enc-dec families."""
from __future__ import annotations

import jax.numpy as jnp

from . import encdec as _ed
from . import transformer as _tf


def init_params(cfg, rng):
    if cfg.family == "encdec":
        return _ed.init_encdec(cfg, rng)
    return _tf.init_lm(cfg, rng)


def forward(params, cfg, batch, *, train=True, return_hidden=False):
    if cfg.family == "encdec":
        return _ed.encdec_forward(params, cfg, batch, train=train,
                                  return_hidden=return_hidden)
    return _tf.lm_forward(params, cfg, batch, train=train,
                          return_hidden=return_hidden)


def lm_head(params, cfg, hidden):
    return _tf._lm_head(params, cfg, hidden)


def init_cache(cfg, batch_size, max_len, *, enc_len=None):
    if cfg.family == "encdec":
        return _ed.init_encdec_cache(cfg, batch_size, max_len,
                                     enc_len or max_len)
    return _tf.init_lm_cache(cfg, batch_size, max_len)


def prefill(params, cfg, batch, cache):
    if cfg.family == "encdec":
        return _ed.encdec_prefill(params, cfg, batch, cache)
    return _tf.lm_prefill(params, cfg, batch, cache)


def decode_step(params, cfg, tokens, cache, cache_index):
    if cfg.family == "encdec":
        return _ed.encdec_decode_step(params, cfg, tokens, cache, cache_index)
    return _tf.lm_decode_step(params, cfg, tokens, cache, cache_index)


def decode_window(params, cfg, tokens, cache, cache_index):
    """Multi-token decode window (B, W) at per-sequence offsets — the
    speculative-decoding verify pass (LM family only)."""
    assert cfg.family == "lm", "decode_window drives decoder-only LMs"
    return _tf.lm_decode_window(params, cfg, tokens, cache, cache_index)


def param_count(params) -> int:
    import jax

    return sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
