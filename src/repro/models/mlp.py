"""The paper's experimental network: 784-256-128-64-10 fully-connected MLP
(§4.1). Used by the NN-weight quantization benchmarks and examples."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PAPER_SIZES = (784, 256, 128, 64, 10)


def init_mlp(rng, sizes=PAPER_SIZES):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, rng = jax.random.split(rng)
        params.append({
            "w": jax.random.normal(k1, (a, b), jnp.float32) * np.sqrt(2.0 / a),
            "b": jnp.zeros((b,), jnp.float32),
        })
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def mlp_loss(params, x, y):
    logits = mlp_apply(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def mlp_accuracy(params, x, y):
    return jnp.mean(jnp.argmax(mlp_apply(params, x), -1) == y)
