"""Normalization layers (pure functions over param dicts).

All RMSNorms use the (1 + w) parameterization with w initialized to zero
(effective scale 1). This is gemma's convention; for the other archs it is
numerically identical at init and keeps a single code path.
"""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x, scale, *, eps: float = 1e-6):
    """Mean-square in f32; the (B,S,D)-sized products stay in x.dtype so the
    backward residual chain is bf16, not f32 (halves norm-related HBM traffic
    - EXPERIMENTS.md §Perf)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = ((var + eps) ** -0.5).astype(x.dtype)
    w = (1.0 + scale.astype(jnp.float32)).astype(x.dtype)
    return x * inv * w


def init_rms(d: int, dtype) -> jnp.ndarray:
    return jnp.zeros((d,), dtype)


def layer_norm(x, scale, bias, *, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    return (y * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dtype)
