"""Decoder-only LM assembly: per-layer pattern, lax.scan over layer groups,
remat policies, KV/state caches, prefill and single-token decode.

Params are plain nested dicts. Layers inside one group are heterogeneous
(gemma2: [local, global]; jamba: 7 mamba + 1 attn with alternating MoE);
identical groups are stacked on a leading axis and scanned, which keeps HLO
size (and compile time) independent of depth - essential for the 80-layer
dry-runs on 512 host devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.hints import hint
from . import attention as attn_lib
from . import ssm as ssm_lib
from .ffn import ffn, init_ffn
from .moe import init_moe, moe_ffn
from .norms import init_rms, rms_norm

# ------------------------------------------------------------- layer init


def init_layer(cfg, spec, rng, dtype):
    ks = jax.random.split(rng, 4)
    p = {"ln1": init_rms(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["mixer"] = attn_lib.init_attention(cfg, spec, ks[0], dtype)
    elif spec.mixer == "mla":
        p["mixer"] = attn_lib.init_mla(cfg, spec, ks[0], dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = ssm_lib.init_mamba(cfg, ks[0], dtype)
    elif spec.mixer == "rwkv6":
        p["mixer"] = ssm_lib.init_rwkv6(cfg, ks[0], dtype)
    else:
        raise ValueError(spec.mixer)
    if cfg.post_block_norm:
        p["post_ln1"] = init_rms(cfg.d_model, dtype)
    if spec.ffn == "dense":
        p["ln2"] = init_rms(cfg.d_model, dtype)
        p["ffn"] = init_ffn(cfg, ks[1], dtype)
    elif spec.ffn == "moe":
        p["ln2"] = init_rms(cfg.d_model, dtype)
        p["ffn"] = init_moe(cfg, ks[1], dtype)
    elif spec.ffn == "cmix":
        p["ln2"] = init_rms(cfg.d_model, dtype)
        # rwkv6 channel-mix params live inside the mixer dict (c_*, cmix)
    if cfg.post_block_norm and spec.ffn != "none":
        p["post_ln2"] = init_rms(cfg.d_model, dtype)
    return p


def init_layer_cache(cfg, spec, batch, max_len, dtype):
    if spec.mixer == "attn":
        kv = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_cache_dtype == "int8":
            # scalar-quantized cache (the paper's value-sharing applied to
            # KV): int8 codes + one f32 scale per (token, head)
            sc = (batch, max_len, cfg.n_kv_heads, 1)
            return {"k": jnp.zeros(kv, jnp.int8), "v": jnp.zeros(kv, jnp.int8),
                    "k_s": jnp.zeros(sc, jnp.float32),
                    "v_s": jnp.zeros(sc, jnp.float32)}
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
    if spec.mixer == "mla":
        return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}
    if spec.mixer == "mamba":
        return ssm_lib.init_mamba_cache(cfg, batch, dtype)
    if spec.mixer == "rwkv6":
        return ssm_lib.init_rwkv_cache(cfg, batch, dtype)
    raise ValueError(spec.mixer)


# ------------------------------------------------------------- layer apply


def apply_layer(p, cfg, spec, x, positions, *, cache=None, cache_index=None,
                cross_kv=None, causal=True):
    h = rms_norm(x, p["ln1"])
    if spec.mixer in ("attn", "mla"):
        fn = attn_lib.attention if spec.mixer == "attn" else attn_lib.mla_attention
        out, new_c = fn(p["mixer"], cfg, spec, h, positions, cache=cache,
                        cache_index=cache_index, cross_kv=cross_kv,
                        causal=causal)
    elif spec.mixer == "mamba":
        out, new_c = ssm_lib.mamba(p["mixer"], cfg, h, cache=cache)
    elif spec.mixer == "rwkv6":
        shift = (cache["shift_t"] if cache is not None
                 else jnp.zeros_like(h[:, 0]))
        state = (cache["s"] if cache is not None
                 else jnp.zeros((h.shape[0], cfg.d_model // cfg.rwkv_head_dim,
                                 cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32))
        out, new_shift, new_state = ssm_lib.rwkv6_time_mix(
            p["mixer"], cfg, h, shift_state=shift, wkv_state=state)
        new_c = None
        if cache is not None:
            new_c = dict(cache, shift_t=new_shift, s=new_state)
    else:
        raise ValueError(spec.mixer)
    if cfg.post_block_norm:
        out = rms_norm(out, p["post_ln1"])
    x = x + out

    if spec.ffn != "none":
        h2 = rms_norm(x, p["ln2"])
        if spec.ffn == "dense":
            f = ffn(p["ffn"], cfg, h2)
        elif spec.ffn == "moe":
            f = moe_ffn(p["ffn"], cfg, h2)
        elif spec.ffn == "cmix":
            shift_c = (cache["shift_c"] if cache is not None
                       else jnp.zeros_like(h2[:, 0]))
            f, new_shift_c = ssm_lib.rwkv6_channel_mix(
                p["mixer"], cfg, h2, shift_state=shift_c)
            if new_c is not None:
                new_c = dict(new_c, shift_c=new_shift_c)
        if cfg.post_block_norm:
            f = rms_norm(f, p["post_ln2"])
        x = x + f
    return hint(x, "hidden"), new_c


# ------------------------------------------------------------- full model


def init_lm(cfg, rng):
    dtype = cfg.dtype("param")
    ks = jax.random.split(rng, 4 + len(cfg.head_layers))
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32)
                  ).astype(dtype),
        "final_norm": init_rms(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab), jnp.float32)
            / np.sqrt(cfg.d_model)).astype(dtype)
    for i, spec in enumerate(cfg.head_layers):
        params[f"head_{i}"] = init_layer(cfg, spec, ks[3 + i], dtype)
    group_keys = jax.random.split(ks[2], cfg.n_groups)

    def one_group(k):
        sub = jax.random.split(k, len(cfg.group))
        return {f"l{i}": init_layer(cfg, spec, sub[i], dtype)
                for i, spec in enumerate(cfg.group)}

    params["groups"] = jax.vmap(one_group)(group_keys)
    return params


def init_lm_cache(cfg, batch, max_len):
    dtype = cfg.dtype("compute")

    def stack(spec):
        one = init_layer_cache(cfg, spec, batch, max_len, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape).copy(), one)

    cache = {"groups": {f"l{i}": stack(spec) for i, spec in enumerate(cfg.group)}}
    for i, spec in enumerate(cfg.head_layers):
        cache[f"head_{i}"] = init_layer_cache(cfg, spec, batch, max_len, dtype)
    return cache


def _embed_in(params, cfg, batch):
    if "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype("compute"))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0
                     ).astype(cfg.dtype("compute"))
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    return hint(x, "hidden")


def _lm_head(params, cfg, x):
    x = rms_norm(x, params["final_norm"])
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return hint(logits, "logits")


def _scan_groups(params, cfg, x, positions, *, cache=None, cache_index=None,
                 train=False, causal=True, cross=None, groups_key="groups"):
    """Run head layers then the scanned groups. Returns (x, new_cache).

    cross: per-scan-group cross-attention source - either {"enc_out": (B,Se,D)}
    (projected per layer on the fly; training/prefill) or stacked precomputed
    {"k","v"} with leading group axis (decode).
    """
    new_cache = {}
    for i, spec in enumerate(cfg.head_layers):
        c = None if cache is None else cache[f"head_{i}"]
        x, nc = apply_layer(params[f"head_{i}"], cfg, spec, x, positions,
                            cache=c, cache_index=cache_index, causal=causal)
        if cache is not None:
            new_cache[f"head_{i}"] = nc

    cross_scanned = cross is not None and "enc_out" not in cross

    def body(carry, xs):
        h = carry
        it = iter(xs)
        gp = next(it)
        gc = next(it) if cache is not None else None
        gx = next(it) if cross_scanned else None
        ncs = {}
        for i, spec in enumerate(cfg.group):
            c = None if gc is None else gc[f"l{i}"]
            ckv = None
            if spec.cross_attn:
                ckv = gx[f"l{i}"] if cross_scanned else cross
            h, nc = apply_layer(gp[f"l{i}"], cfg, spec, h, positions,
                                cache=c, cache_index=cache_index,
                                cross_kv=ckv, causal=causal)
            ncs[f"l{i}"] = nc if nc is not None else 0
        return h, ncs

    if train and cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif train and cfg.remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = [params[groups_key]]
    if cache is not None:
        xs.append(cache["groups"])
    if cross_scanned:
        xs.append(cross)
    x, group_caches = jax.lax.scan(body, x, tuple(xs))
    if cache is not None:
        new_cache["groups"] = group_caches
    return x, (new_cache if cache is not None else None)


def lm_forward(params, cfg, batch, *, train=True, return_hidden=False):
    """Full-sequence forward -> logits (B, S, V) (or pre-head hidden when
    return_hidden - the chunked-CE loss applies the head per seq chunk)."""
    x = _embed_in(params, cfg, batch)
    positions = batch.get("positions")
    if positions is None:
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _ = _scan_groups(params, cfg, x, positions, train=train)
    if return_hidden:
        return x
    return _lm_head(params, cfg, x)


def lm_prefill(params, cfg, batch, cache):
    """Populate the cache from a full prompt; returns (logits, cache)."""
    x = _embed_in(params, cfg, batch)
    positions = batch.get("positions")
    if positions is None:
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, new_cache = _scan_groups(params, cfg, x, positions, cache=cache,
                                cache_index=0)
    return _lm_head(params, cfg, x), new_cache


def lm_decode_step(params, cfg, tokens, cache, cache_index):
    """One decode step: tokens (B, 1) -> (logits (B,1,V), new_cache).

    cache_index is a scalar (every row at the same length — static batch) or
    a (B,) vector of per-sequence lengths (continuous batching over a paged
    cache, which carries its own write positions).
    """
    return lm_decode_window(params, cfg, tokens, cache, cache_index)


def lm_decode_window(params, cfg, tokens, cache, cache_index):
    """Multi-token decode window: tokens (B, W) continue every sequence at
    its own offset -> (logits (B, W, V), new_cache).

    The speculative-decoding verify step: position w of row b is scored at
    ``cache_index[b] + w`` with causal masking inside the window, so one
    batched pass yields the target model's next-token logits after each of
    the W prefixes — bit-identical math to W sequential decode steps.
    W == 1 is exactly ``lm_decode_step``.
    """
    batch = {"tokens": tokens}
    x = _embed_in(params, cfg, batch)
    B, W = tokens.shape
    ci = jnp.asarray(cache_index, jnp.int32)
    base = ci.reshape(B, 1) if ci.ndim >= 1 else jnp.broadcast_to(ci, (B, 1))
    pos = base + jnp.arange(W, dtype=jnp.int32)[None]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(pos[None], (3, B, W)).astype(jnp.int32)
    else:
        positions = pos.astype(jnp.int32)
    x, new_cache = _scan_groups(params, cfg, x, positions, cache=cache,
                                cache_index=cache_index)
    return _lm_head(params, cfg, x), new_cache
