"""KV-cache adapters: the one interface between attention and cache storage.

Attention never touches cache layout directly; it calls

    new_cache, k_all, v_all, q_offset, kv_valid_len = adapter.update(k, v, idx)

where ``k, v`` are the new projected keys/values (B, S, Hkv, Dh) and ``idx``
the scalar write position for contiguous ring-buffer caches (ignored by
caches that track their own per-sequence lengths, e.g. the paged cache in
``repro.serving.kv_cache``). ``q_offset`` / ``kv_valid_len`` are either
scalars or per-sequence (B,) vectors and feed straight into ``sdpa``.

Built-in adapters wrap the plain-dict caches produced by
``transformer.init_layer_cache`` so the pytree that flows through
``lax.scan`` stays a dict; any object exposing ``.update`` (duck-typed) is
used as-is, which is how the paged serving cache plugs in without models
importing serving code.

Fused-decode extension (optional): an adapter may additionally expose

    new_cache, out = adapter.fused_decode(q, k, v, softcap=...)

guarded by a truthy ``use_fused_decode`` attribute. When present, attention
skips the gather-then-sdpa read for single-token decode steps and lets the
adapter run attention against its own storage — the paged serving cache
uses this to run the Pallas flash-decode kernel that dequantizes frozen
pages in VMEM instead of materializing them in HBM. ``supports_fused_decode``
below is the one gate attention consults; adapters without the extension
fall through to ``update`` + sdpa unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def supports_fused_decode(adapter, seq_len: int, window) -> bool:
    """True when this step can take the adapter's fused-attention path:
    full-context (no sliding window), the adapter opted in via
    ``use_fused_decode``, and the step is short enough — a single decode
    token, or up to the adapter's ``fused_window`` queries (the
    speculative-decoding verify window; prefill lengths stay on the
    gather path)."""
    if window is not None or not bool(getattr(adapter, "use_fused_decode",
                                              False)):
        return False
    return seq_len <= max(int(getattr(adapter, "fused_window", 1)), 1)


def supports_fused_prefill(adapter, seq_len: int, window) -> bool:
    """True when a prefill chunk can take the adapter's fused chunked-prefill
    path: full-context attention and the adapter opted in via
    ``use_fused_prefill`` (the paged cache's chunked-prefill view). Any
    chunk length qualifies — the fused kernel treats the chunk as the last
    ``seq_len`` query positions of the post-write valid length."""
    del seq_len
    return window is None and bool(getattr(adapter, "use_fused_prefill",
                                           False))


class DenseRingCache:
    """Contiguous (B, L, Hkv, Dh) ring buffers {"k","v"} written at idx."""

    def __init__(self, cache: dict):
        self.cache = cache

    def update(self, k, v, cache_index):
        c = self.cache
        k_all = jax.lax.dynamic_update_slice(
            c["k"], k.astype(c["k"].dtype), (0, cache_index, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            c["v"], v.astype(c["v"].dtype), (0, cache_index, 0, 0))
        valid = cache_index + k.shape[1]
        return {"k": k_all, "v": v_all}, k_all, v_all, cache_index, valid


class Int8RingCache:
    """Scalar-quantized ring buffer: int8 codes + one f32 scale per
    (token, head) — the paper's value-sharing idea applied per-token.

    Storage dict: {"k","v"} int8 (B, L, Hkv, Dh) + {"k_s","v_s"} f32
    (B, L, Hkv, 1). Reads dequantize the whole buffer (decode is
    bandwidth-bound, so the HBM win is the int8 crossing; the multiply is
    free on the VPU).
    """

    def __init__(self, cache: dict):
        self.cache = cache

    @staticmethod
    def _q8(t):
        s = jnp.max(jnp.abs(t), axis=-1, keepdims=True
                    ).astype(jnp.float32) / 127.0
        s = jnp.maximum(s, 1e-8)
        codes = jnp.clip(jnp.round(t.astype(jnp.float32) / s),
                         -127, 127).astype(jnp.int8)
        return codes, s

    def update(self, k, v, cache_index):
        c = self.cache
        kq, ks = self._q8(k)
        vq, vs = self._q8(v)
        upd = lambda buf, t: jax.lax.dynamic_update_slice(
            buf, t, (0, cache_index, 0, 0))
        new = {"k": upd(c["k"], kq), "v": upd(c["v"], vq),
               "k_s": upd(c["k_s"], ks), "v_s": upd(c["v_s"], vs)}
        k_all = new["k"].astype(k.dtype) * new["k_s"].astype(k.dtype)
        v_all = new["v"].astype(v.dtype) * new["v_s"].astype(v.dtype)
        valid = cache_index + k.shape[1]
        return new, k_all, v_all, cache_index, valid


def as_adapter(cache):
    """Dispatch a cache pytree to its adapter (ducks pass through).

    Dicts are checked first — a plain dict's own ``.update`` is not the
    adapter protocol.
    """
    if isinstance(cache, dict):
        return Int8RingCache(cache) if "k_s" in cache else DenseRingCache(cache)
    if hasattr(cache, "update"):
        return cache
    raise TypeError(f"no KV-cache adapter for {type(cache)!r}")
