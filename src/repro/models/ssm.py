"""Attention-free sequence mixers: Mamba (selective SSM) and RWKV-6 (Finch).

Both use chunked sequence scans for training (outer lax.scan over
cfg.scan_chunk-sized chunks carrying the recurrent state; within-chunk the
Mamba recurrence is a log-depth associative scan, the RWKV-6 recurrence a
short inner scan). Decode is a single O(1) state update - this is why these
archs run the long_500k shape while full-attention archs skip it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.hints import hint
from .norms import init_rms, rms_norm


def _dense(rng, d_in, d_out, dtype, scale=None):
    scale = scale or 1.0 / np.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# =================================================================== Mamba

def init_mamba(cfg, rng, dtype):
    D, E, N, R, dc = (cfg.d_model, cfg.d_inner, cfg.ssm_d_state, cfg.dt_rank,
                      cfg.ssm_d_conv)
    ks = jax.random.split(rng, 8)
    return {
        "w_in": _dense(ks[0], D, 2 * E, dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, E), jnp.float32) / dc).astype(dtype),
        "w_bcdt": _dense(ks[2], E, 2 * N + R, dtype),
        "w_dt": _dense(ks[3], R, E, dtype, scale=1.0 / np.sqrt(R)),
        "dt_bias": jnp.full((E,), -2.0, dtype),   # softplus(-2) ~ 0.12
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (E, N)).copy()),
        "D_skip": jnp.ones((E,), jnp.float32),
        "w_out": _dense(ks[4], E, D, dtype),
    }


def _mamba_scan_chunk(a, b, h0):
    """Diagonal-SSM chunk via associative scan. a,b: (B,c,E,N); h0: (B,E,N)."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = b_cum + a_cum * h0[:, None]
    return h, h[:, -1]


def mamba(params, cfg, x, *, cache=None):
    """x: (B,S,D). cache (decode): {"h": (B,E,N), "conv": (B,dc-1,E)}."""
    B, S, D = x.shape
    E, N, R, dc = cfg.d_inner, cfg.ssm_d_state, cfg.dt_rank, cfg.ssm_d_conv
    xz = x @ params["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)                  # (B,S,E) each
    xs = hint(xs, "ssm_inner")

    # causal depthwise conv
    if cache is not None:
        ctx = jnp.concatenate([cache["conv"].astype(xs.dtype), xs], axis=1)
        new_conv = ctx[:, -(dc - 1):]
    else:
        ctx = jnp.pad(xs, ((0, 0), (dc - 1, 0), (0, 0)))
        new_conv = ctx[:, -(dc - 1):]
    xc = sum(ctx[:, i:i + S] * params["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(xc)

    bcdt = xc @ params["w_bcdt"]                        # (B,S,2N+R)
    B_t, C_t, dt_low = jnp.split(bcdt, [N, 2 * N], axis=-1)
    dt = jax.nn.softplus(dt_low @ params["w_dt"]
                         + params["dt_bias"].astype(xc.dtype))  # (B,S,E)
    A = -jnp.exp(params["A_log"])                       # (E,N) f32

    def discretize(xc_c, dt_c, B_c):
        """(B,c,E),(B,c,E),(B,c,N) -> a, b (B,c,E,N) f32 - built per chunk so
        the full-sequence (B,S,E,N) tensors (4 GiB/device/layer for jamba)
        never exist."""
        dtf = dt_c.astype(jnp.float32)
        a = jnp.exp(dtf[..., None] * A)
        b = (dtf * xc_c.astype(jnp.float32))[..., None] \
            * B_c.astype(jnp.float32)[:, :, None, :]
        return a, b

    h0 = (cache["h"] if cache is not None
          else jnp.zeros((B, E, N), jnp.float32))
    if S == 1:                                          # decode: O(1) update
        a, b = discretize(xc, dt, B_t)
        h = a[:, 0] * h0 + b[:, 0]
        y = jnp.einsum("ben,bn->be", h, C_t[:, 0].astype(jnp.float32))[:, None]
        h_last = h
    else:
        c = min(cfg.scan_chunk, S)
        pad = (-S) % c
        padded = lambda t: (jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
                            if pad else t)
        Sp = S + pad
        resh = lambda t: padded(t).reshape(B, Sp // c, c, *t.shape[2:]).swapaxes(0, 1)

        def step(h_in, xs):
            xc_c, dt_c, B_c, C_c = xs
            # pads carry dt=0, xc=0 -> a=exp(0)=1, b=0: state-preserving
            a, b = discretize(xc_c, dt_c, B_c)
            states, h_out = _mamba_scan_chunk(a, b, h_in)
            y_c = jnp.einsum("bsen,bsn->bse", states, C_c.astype(jnp.float32))
            return h_out, y_c

        step = jax.checkpoint(step, prevent_cse=False)
        h_last, y = jax.lax.scan(
            step, h0, (resh(xc), resh(dt), resh(B_t), resh(C_t)))
        y = y.swapaxes(0, 1).reshape(B, Sp, E)[:, :S]

    y = y + params["D_skip"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["w_out"]
    new_cache = {"h": h_last, "conv": new_conv} if cache is not None else None
    return hint(y, "hidden"), new_cache


def init_mamba_cache(cfg, batch, dtype):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, cfg.d_inner), dtype),
    }


# =================================================================== RWKV-6

def init_rwkv6(cfg, rng, dtype):
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = D // hd
    lora = 64
    ks = jax.random.split(rng, 12)
    return {
        "mix": (jax.random.uniform(ks[0], (5, D), jnp.float32)).astype(dtype),
        "w_r": _dense(ks[1], D, D, dtype),
        "w_k": _dense(ks[2], D, D, dtype),
        "w_v": _dense(ks[3], D, D, dtype),
        "w_g": _dense(ks[4], D, D, dtype),
        "w0": jnp.full((D,), -6.0, jnp.float32),       # decay bias (Finch)
        "w_lora_a": _dense(ks[5], D, lora, dtype),
        "w_lora_b": _dense(ks[6], lora, D, dtype, scale=0.01),
        "u": (jax.random.normal(ks[7], (D,), jnp.float32) * 0.1).astype(jnp.float32),
        "ln_out": init_rms(D, dtype),
        "w_o": _dense(ks[8], D, D, dtype),
        # channel mix
        "cmix": (jax.random.uniform(ks[9], (2, D), jnp.float32)).astype(dtype),
        "c_k": _dense(ks[10], D, cfg.d_ff, dtype),
        "c_v": _dense(ks[11], cfg.d_ff, D, dtype),
        "c_r": _dense(ks[0], D, D, dtype),
    }


def _token_shift(x, shift_state):
    """Previous-token features: (B,S,D) with carry (B,D)."""
    prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    return prev, x[:, -1]


def _wkv6_chunk_matmul(r, k, v, w, u, s0, *, clamp: float = 15.0):
    """Chunked parallel WKV6 (GLA-style): the whole chunk as matmuls.

    With cumulative decay W_t = prod_{tau<=t} w_tau:
      y_t = (r_t*W_{t-1}) . S_0  +  sum_{tau<t} [(r_t*W_{t-1}/W_tau).k_tau] v_tau
            + (r_t.(u*k_tau)) v_t
      S_c = W_c*S_0 + sum_tau (W_c/W_tau)*k_tau v_tau^T
    i.e. one strictly-lower-triangular (c,c) score matmul + one (hd,hd) state
    matmul per head - MXU-dense, no sequential scan. log-decay exponents are
    clamped to +-clamp for stability (W_c/W_tau <= 1 always; the r~/k~ split
    can individually overflow without it). Replaces 32 sequential VPU steps
    per chunk with 2 matmuls (EXPERIMENTS.md §Perf, rwkv hillclimb).
    r,k,v,w: (B,c,H,hd) f32; u: (1,H,hd,1); s0: (B,H,hd,hd).
    """
    B, c, H, hd = r.shape
    logw = jnp.cumsum(jnp.log(jnp.maximum(w, 1e-12)), axis=1)   # <= 0
    logw_prev = logw - jnp.log(jnp.maximum(w, 1e-12))           # W_{t-1}; W_0=1
    r_dec = r * jnp.exp(logw_prev)                              # underflow->0 ok
    # pairwise decay factors, exact: on the causal (t>s) region
    # logW_{t-1} - logW_s <= 0 so exp() never overflows; the acausal region
    # is clipped then masked. (A factorized r~ @ k~^T splits the exponent
    # into halves that overflow under strong decay - refuted, see §Perf log.)
    F = jnp.exp(jnp.minimum(
        logw_prev[:, :, None] - logw[:, None, :], 0.0))         # (B,c,c,H,hd)
    A = jnp.einsum("bthd,bshd,btshd->bhts", r, k, F)            # (B,H,c,c)
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)                # strictly lower
    A = jnp.where(tri[None, None], A, 0.0)
    uu = u[0, :, :, 0]                                          # (H,hd)
    diag = jnp.einsum("bthd,hd,bthd->bth", r, uu, k)
    y = (jnp.einsum("bhts,bshd->bthd", A, v)
         + diag[..., None] * v
         + jnp.einsum("bthd,bhdv->bthv", r_dec, s0))
    k_tail = k * jnp.exp(jnp.minimum(logw[:, -1:] - logw, clamp))  # W_c/W_tau<=1
    w_c = jnp.exp(jnp.maximum(logw[:, -1], -clamp))             # (B,H,hd)
    s_new = w_c[..., None] * s0 + jnp.einsum("bshd,bshv->bhdv", k_tail, v)
    return y, s_new


def _wkv6_chunk(r, k, v, w, u, s0):
    """Sequential WKV inner scan (reference oracle for the matmul version).

    y_t = r_t . (S_{t-1} + u * k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                        # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]     # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    rr = r.swapaxes(0, 1)
    kk = k.swapaxes(0, 1)
    vv = v.swapaxes(0, 1)
    ww = w.swapaxes(0, 1)
    s_last, ys = jax.lax.scan(step, s0, (rr, kk, vv, ww))
    return ys.swapaxes(0, 1), s_last                 # (B,c,H,hd)


def rwkv6_time_mix(params, cfg, x, *, shift_state, wkv_state):
    B, S, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    prev, new_shift = _token_shift(x, shift_state)
    mix = params["mix"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + (prev - x) * mix[i] for i in range(5))
    r = (xr @ params["w_r"]).reshape(B, S, H, hd)
    k = (xk @ params["w_k"]).reshape(B, S, H, hd)
    v = (xv @ params["w_v"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ params["w_g"])
    # data-dependent decay (the Finch contribution)
    dec = params["w0"] + (jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
                          ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(B, S, H, hd)  # in (0,1)
    u = params["u"].reshape(H, hd)[None, :, :, None] * jnp.ones((1,), jnp.float32)

    rf, kf, vf, wf = (hint(t.astype(jnp.float32), "wkv")
                      for t in (r, k, v, w))
    if S == 1:
        ys, s_last = _wkv6_chunk(rf, kf, vf, wf, u, wkv_state)
    else:
        c = min(cfg.scan_chunk, S)
        pad = (-S) % c
        if pad:   # w=1, k=0 leaves the wkv state untouched through padding
            zpad = jnp.zeros((B, pad, H, hd), jnp.float32)
            rf = jnp.concatenate([rf, zpad], 1)
            kf = jnp.concatenate([kf, zpad], 1)
            vf = jnp.concatenate([vf, zpad], 1)
            wf = jnp.concatenate([wf, jnp.ones((B, pad, H, hd), jnp.float32)], 1)
        Sp = S + pad

        def outer(s_in, rkvw):
            ys, s_out = _wkv6_chunk_matmul(*rkvw, u, s_in)
            return s_out, ys

        outer = jax.checkpoint(outer, prevent_cse=False)
        resh = lambda t: t.reshape(B, Sp // c, c, H, hd).swapaxes(0, 1)
        s_last, ys = jax.lax.scan(outer, wkv_state,
                                  (resh(rf), resh(kf), resh(vf), resh(wf)))
        ys = ys.swapaxes(0, 1).reshape(B, Sp, H, hd)[:, :S]
    y = ys.reshape(B, S, D).astype(x.dtype)
    y = rms_norm(y, params["ln_out"]) * g
    return hint(y @ params["w_o"], "hidden"), new_shift, s_last


def rwkv6_channel_mix(params, cfg, x, *, shift_state):
    prev, new_shift = _token_shift(x, shift_state)
    cmix = params["cmix"].astype(x.dtype)
    xk = x + (prev - x) * cmix[0]
    xr = x + (prev - x) * cmix[1]
    k = jnp.square(jax.nn.relu(xk @ params["c_k"]))
    return jax.nn.sigmoid(xr @ params["c_r"]) * (k @ params["c_v"]), new_shift


def init_rwkv_cache(cfg, batch, dtype):
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    return {
        "shift_t": jnp.zeros((batch, D), dtype),
        "shift_c": jnp.zeros((batch, D), dtype),
        "s": jnp.zeros((batch, D // hd, hd, hd), jnp.float32),
    }
