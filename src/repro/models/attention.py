"""Attention mixers: GQA (RoPE / M-RoPE / qk-norm / softcap / local window),
MLA (deepseek multi-head latent attention), and cross-attention (whisper).

Pure functions over parameter dicts; a KV cache (decode) is any pytree a
cache adapter understands (see .cache): dict ring buffers plus a scalar
length carried by the caller, or an object carrying its own layout (the
paged serving cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.serve import qmatmul
from repro.runtime.hints import hint
from .cache import as_adapter, supports_fused_decode, supports_fused_prefill
from .norms import init_rms, rms_norm
from .rope import apply_mrope, apply_rope

BIG_NEG = -2.3819763e38


def _dense(rng, d_in, d_out, dtype, scale=None):
    scale = scale or (1.0 / np.sqrt(d_in))
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------ GQA

def init_attention(cfg, spec, rng, dtype):
    H, Hkv, Dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(rng, 8)
    p = {
        "wq": _dense(ks[0], D, H * Dh, dtype),
        "wk": _dense(ks[1], D, Hkv * Dh, dtype),
        "wv": _dense(ks[2], D, Hkv * Dh, dtype),
        "wo": _dense(ks[3], H * Dh, D, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(Dh, dtype)
        p["k_norm"] = init_rms(Dh, dtype)
    if spec.cross_attn:
        p["c_wq"] = _dense(ks[4], D, H * Dh, dtype)
        p["c_wk"] = _dense(ks[5], D, Hkv * Dh, dtype)
        p["c_wv"] = _dense(ks[6], D, Hkv * Dh, dtype)
        p["c_wo"] = _dense(ks[7], H * Dh, D, dtype)
    return p


def _pos_mask(Sq, Skv, *, k_start, causal, window, q_offset, kv_valid_len):
    """Position mask (Bm, Sq, Skv) with Bm in {1, B}.

    q_offset / kv_valid_len may be scalars (all rows share one length — the
    classic single-sequence ring cache) or (B,) vectors (continuous batching:
    every slot is at its own decode position).
    """
    q_off = jnp.asarray(q_offset, jnp.int32).reshape(-1)          # (Bm,)
    q_pos = q_off[:, None, None] + jnp.arange(Sq)[None, :, None]  # (Bm,Sq,1)
    k_pos = k_start + jnp.arange(Skv)[None, None, :]              # (1,1,Skv)
    mask = jnp.ones((q_off.shape[0], Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    if kv_valid_len is not None:
        kv = jnp.asarray(kv_valid_len, jnp.int32).reshape(-1)
        mask &= k_pos < kv[:, None, None]
    return mask


def _sdpa_block(q, k, v, *, causal, window, softcap, q_offset, kv_valid_len,
                repeat_kv=True):
    """One q-block of grouped attention. q: (B,Sq,Hq,Dh); k,v: (B,Skv,Hkv,*).

    repeat_kv=True expands K/V across the GQA group so the logits head dim is
    Hq (always divisible by the model axis) - without it GSPMD leaves the
    (B,Hkv,G,Sq,Skv) buffer partially replicated whenever Hkv < model axis
    (glm4 kv=2, yi/jamba kv=8), blowing the activation budget.
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = Hq // Hkv
    if repeat_kv and G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        Hkv, G = Hq, 1
        Dv = v.shape[-1]
    qr = q.reshape(B, Sq, Hkv, G, Dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                        preferred_element_type=jnp.float32)
    logits = logits / np.sqrt(Dh).astype(np.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = _pos_mask(Sq, Skv, k_start=0, causal=causal, window=window,
                     q_offset=q_offset, kv_valid_len=kv_valid_len)
    logits = jnp.where(mask[:, None, None], logits, BIG_NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, Dv)


def _sdpa_flash(q, k, v, *, causal, window, softcap, q_offset, kv_valid_len,
                kv_chunk, repeat_kv=True):
    """Online-softmax over kv chunks (flash-attention schedule in XLA).

    Bounds score tiles at (B, Hq, Sq, kv_chunk) f32 and never materializes
    full-row probabilities - the pure-JAX analogue of the VMEM-resident
    Mosaic kernel a TPU build would use.
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = Hq // Hkv
    n = Skv // kv_chunk
    scale = 1.0 / np.sqrt(Dh).astype(np.float32)
    k_ch = k.reshape(B, n, kv_chunk, Hkv, Dh).swapaxes(0, 1)
    v_ch = v.reshape(B, n, kv_chunk, Hkv, Dv).swapaxes(0, 1)

    q5 = q.reshape(B, Sq, Hkv, G, Dh)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, j = xs
        if G > 1 and repeat_kv:
            kc = jnp.repeat(kc, G, axis=2)
            vc = jnp.repeat(vc, G, axis=2)
        if G > 1 and not repeat_kv:
            # grouped einsum (context-parallel path): KV stays un-repeated
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, kc,
                           preferred_element_type=jnp.float32) * scale
            s = s.reshape(B, Hq, Sq, kv_chunk)
        else:
            s = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                           preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mask = _pos_mask(Sq, kv_chunk, k_start=j * kv_chunk, causal=causal,
                         window=window, q_offset=q_offset,
                         kv_valid_len=kv_valid_len)
        s = jnp.where(mask[:, None], s, BIG_NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[:, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        if G > 1 and not repeat_kv:
            p5 = p.reshape(B, Hkv, G, Sq, kv_chunk).astype(vc.dtype)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p5, vc,
                            preferred_element_type=jnp.float32)
            pv = pv.reshape(B, Hq, Sq, Dv)
        else:
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    init = (jnp.full((B, Hq, Sq), BIG_NEG, jnp.float32),
            jnp.zeros((B, Hq, Sq), jnp.float32),
            jnp.zeros((B, Hq, Sq, Dv), jnp.float32))
    # remat per kv tile: backward re-forms each score tile instead of
    # stacking every (B,H,Sq,kc) f32 tile across the scan
    body = jax.checkpoint(body, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(body, init, (k_ch, v_ch, jnp.arange(n)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.swapaxes(1, 2).astype(v.dtype)       # (B,Sq,Hq,Dv)


def sdpa(q, k, v, *, causal, window=None, softcap=None, q_offset=0,
         kv_valid_len=None, q_chunk=None, kv_chunk=1024):
    """Grouped SDPA, chunked over the query axis; long KV additionally runs
    the online-softmax kv-chunk schedule (see _sdpa_flash).

    q-chunking bounds the live logits buffer at (B, H, q_chunk, Skv) f32
    instead of (B, H, Sq, Skv) - without it the 32k prefill would
    materialize terabytes of S^2 logits (memory notes in EXPERIMENTS.md).
    """
    B, Sq, Hq, Dh = q.shape
    Skv = k.shape[1]
    # Repeat KV across the GQA group only when (a) not decoding (Sq==1 would
    # re-read the whole cache G times) and (b) heads shard evenly - in the
    # context-parallel fallback the grouped einsum keeps KV un-repeated.
    from repro.runtime.hints import model_axis_size

    rep = Sq > 1 and (Hq % model_axis_size() == 0)
    use_flash = (kv_chunk and Sq > 1 and Skv >= 2 * kv_chunk
                 and Skv % kv_chunk == 0)

    def one_chunk(qi, off):
        if use_flash:
            return _sdpa_flash(qi, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=off,
                               kv_valid_len=kv_valid_len, kv_chunk=kv_chunk,
                               repeat_kv=rep)
        return _sdpa_block(qi, k, v, causal=causal, window=window,
                           softcap=softcap, q_offset=off,
                           kv_valid_len=kv_valid_len, repeat_kv=rep)

    if not q_chunk or Sq <= q_chunk or Sq % q_chunk != 0:
        return one_chunk(q, q_offset)
    nc = Sq // q_chunk
    q_ch = q.reshape(B, nc, q_chunk, Hq, Dh).swapaxes(0, 1)  # (nc,B,qc,H,D)

    def body(_, xs):
        qi, i = xs
        return None, one_chunk(qi, q_offset + i * q_chunk)

    body = jax.checkpoint(body, prevent_cse=False)   # tiles recompute in bwd
    _, outs = jax.lax.scan(body, None, (q_ch, jnp.arange(nc)))
    Dv = v.shape[-1]
    return outs.swapaxes(0, 1).reshape(B, Sq, Hq, Dv)


def attention(params, cfg, spec, x, positions, *, cache=None, cache_index=None,
              causal=True, cross_kv=None):
    """Self-attention (+ optional appended cross-attention for whisper).

    cache (decode/prefill-extend): any pytree ``cache.as_adapter`` accepts —
    {"k","v"} ring buffers (B, L, Hkv, Dh), the int8 variant, or a paged
    cache object; cache_index: scalar current length (ring caches only;
    adapters that track per-sequence lengths ignore it). Returns
    (out, new_cache).
    """
    B, S, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # qmatmul: dense weights -> plain matmul; QuantizedTensor leaves -> the
    # fused codebook-dequant kernel (PTQ'd checkpoints serve undequantized)
    q = qmatmul(x, params["wq"]).reshape(B, S, H, Dh)
    k = qmatmul(x, params["wk"]).reshape(B, S, Hkv, Dh)
    v = qmatmul(x, params["wv"]).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if positions is not None:
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    q, k, v = hint(q, "qkv"), hint(k, "kv"), hint(v, "kv")

    new_cache = None
    if cache is not None:
        adapter = as_adapter(cache)
        if supports_fused_decode(adapter, S, spec.window):
            # paged decode hot path: the adapter attends against its own
            # storage (Pallas flash-decode kernel, frozen pages dequantized
            # in VMEM) instead of gathering dense K/V through HBM
            new_cache, out = adapter.fused_decode(
                q, k, v, softcap=cfg.attn_softcap)
        elif supports_fused_prefill(adapter, S, spec.window):
            # chunked-prefill hot path: score this chunk against every
            # earlier page through the same kernel as decode (frozen pages
            # cross HBM as packed codes), causal within the chunk
            new_cache, out = adapter.fused_prefill(
                q, k, v, softcap=cfg.attn_softcap)
        else:
            new_cache, k_all, v_all, q_off, valid = adapter.update(
                k, v, cache_index)
            out = sdpa(q, k_all, v_all, causal=causal, window=spec.window,
                       softcap=cfg.attn_softcap, q_offset=q_off,
                       kv_valid_len=valid, q_chunk=cfg.attn_q_chunk)
    else:
        out = sdpa(q, k, v, causal=causal, window=spec.window,
                   softcap=cfg.attn_softcap, q_chunk=cfg.attn_q_chunk)
    y = qmatmul(out.reshape(B, S, H * Dh), params["wo"])

    if spec.cross_attn:
        assert cross_kv is not None, "cross-attention needs encoder kv"
        ckv = (init_cross_kv(params, cfg, cross_kv["enc_out"])
               if "enc_out" in cross_kv else cross_kv)
        cq = (x @ params["c_wq"]).reshape(B, S, H, Dh)
        co = sdpa(cq, ckv["k"], ckv["v"], causal=False,
                  q_chunk=cfg.attn_q_chunk)
        y = y + co.reshape(B, S, H * Dh) @ params["c_wo"]
    return hint(y, "hidden"), new_cache


def init_cross_kv(params, cfg, enc_out):
    """Precompute encoder K/V once (prefill); reused every decode step."""
    B, Se, D = enc_out.shape
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ params["c_wk"]).reshape(B, Se, Hkv, Dh)
    v = (enc_out @ params["c_wv"]).reshape(B, Se, Hkv, Dh)
    return {"k": k, "v": v}


# ------------------------------------------------------------------ MLA

def init_mla(cfg, spec, rng, dtype):
    D, H = cfg.d_model, cfg.n_heads
    r, nope, ropd, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 5)
    return {
        "wq": _dense(ks[0], D, H * (nope + ropd), dtype),
        "wdkv": _dense(ks[1], D, r, dtype),
        "wkr": _dense(ks[2], D, ropd, dtype),
        "wukv": _dense(ks[3], r, H * (nope + dv), dtype),
        "wo": _dense(ks[4], H * dv, D, dtype),
    }


def mla_attention(params, cfg, spec, x, positions, *, cache=None,
                  cache_index=None, causal=True, cross_kv=None):
    """Multi-head latent attention (deepseek-v2). The cache stores only the
    compressed latent (B, L, r) + shared rope key (B, L, ropd) - the MLA
    memory saving that makes 32k decode cheap."""
    B, S, D = x.shape
    H = cfg.n_heads
    r, nope, ropd, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = (x @ params["wq"]).reshape(B, S, H, nope + ropd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv = x @ params["wdkv"]                       # (B,S,r)
    krope = (x @ params["wkr"]).reshape(B, S, 1, ropd)
    if positions is not None:
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        krope = apply_rope(krope, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        ckv_all = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_index, 0))
        kr_all = jax.lax.dynamic_update_slice(
            cache["krope"], krope[:, :, 0].astype(cache["krope"].dtype),
            (0, cache_index, 0))
        new_cache = {"ckv": ckv_all, "krope": kr_all}
        ckv_use, kr_use, q_off = ckv_all, kr_all[:, :, None], cache_index
        valid = cache_index + S
    else:
        ckv_use, kr_use, q_off, valid = ckv, krope, 0, None

    L = ckv_use.shape[1]
    kv = (ckv_use @ params["wukv"]).reshape(B, L, H, nope + dv)
    k_nope, vv = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr_use, (B, L, H, ropd)).astype(k_nope.dtype)], -1)
    qq = jnp.concatenate([q_nope, q_rope], -1)
    out = sdpa(qq, k, vv, causal=causal, window=spec.window,
               softcap=cfg.attn_softcap, q_offset=q_off, kv_valid_len=valid,
               q_chunk=cfg.attn_q_chunk)
    y = out.reshape(B, S, H * dv) @ params["wo"]
    return hint(y, "hidden"), new_cache
