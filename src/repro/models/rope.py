"""Rotary position embeddings: standard RoPE and qwen2-vl M-RoPE.

positions: (B, S) int32 for RoPE; (3, B, S) for M-RoPE (temporal, h, w) -
the VLM frontend is a stub per the assignment, so text positions replicate
the temporal index across the three sections, which is exactly what
qwen2-vl does for pure-text tokens.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) -> rotated x."""
    B, S, H, D = x.shape
    freqs = rope_freqs(D, theta)                        # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections):
    """M-RoPE: frequency bands split across (t, h, w) position streams.

    x: (B, S, H, D); positions: (3, B, S); sections: per-stream half-dims
    summing to D/2.
    """
    B, S, H, D = x.shape
    half = D // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(D, theta)                        # (half,)
    # band s uses position stream s
    parts = []
    start = 0
    for s, sec in enumerate(sections):
        f = freqs[start:start + sec]
        ang = positions[s].astype(jnp.float32)[..., None] * f   # (B,S,sec)
        parts.append(ang)
        start += sec
    ang = jnp.concatenate(parts, axis=-1)               # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
