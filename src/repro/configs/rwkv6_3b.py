"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 -
Finch: data-dependent decay [arXiv:2404.05892; hf].

Sub-quadratic (O(1) decode state) -> runs the long_500k shape. 40 heads of
64 do not divide the 16-way model axis evenly; GSPMD pads (roofline note)."""
from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="lm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=8960, vocab=65536,
        group=(LayerSpec(mixer="rwkv6", ffn="cmix"),),
        rwkv_head_dim=64, scan_chunk=64, subquadratic=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-reduced", family="lm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=224, vocab=263,
        group=(LayerSpec(mixer="rwkv6", ffn="cmix"),),
        rwkv_head_dim=16, scan_chunk=8, subquadratic=True,
        param_dtype="float32", compute_dtype="float32",
    )
