"""Config system: one dataclass drives model shape, sharding, and dry-run.

Every assigned architecture is a ``ModelConfig`` in its own module under
repro.configs; ``get_config(arch_id)`` resolves it, ``reduced()`` produces the
CPU smoke-test variant of the same family (small widths/depths, same layer
pattern and feature set).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Sequence

import jax.numpy as jnp

# ----------------------------------------------------------------- layer spec


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer: a sequence mixer plus a feed-forward block."""

    mixer: str = "attn"          # "attn" | "mla" | "mamba" | "rwkv6"
    ffn: str = "dense"           # "dense" | "moe" | "none"
    window: int | None = None    # local attention window (gemma2)
    cross_attn: bool = False     # decoder cross-attention (whisper)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # "lm" | "encdec"
    # shapes
    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 128
    d_ff: int = 4096
    vocab: int = 32000
    # layer pattern: `group` repeated n_layers/len(group) times via lax.scan;
    # `head_layers` run unscanned before the groups (e.g. deepseek dense layer 0)
    group: Sequence[LayerSpec] = (LayerSpec(),)
    head_layers: Sequence[LayerSpec] = ()
    # attention features
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    mrope_sections: Sequence[int] | None = None   # qwen2-vl M-RoPE
    post_block_norm: bool = False                 # gemma2 post-norms
    attn_q_chunk: int = 512    # q-chunked attention (bounds S^2 logits memory)
    kv_cache_dtype: str = "compute"   # "compute" | "int8" (quantized cache)
    # MLA (deepseek)
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    expert_ff: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # SSM / RWKV
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0          # 0 -> d_model/16
    rwkv_head_dim: int = 64
    scan_chunk: int = 128         # sequence chunking for SSM/RWKV scans
    # enc-dec
    n_enc_layers: int = 0
    enc_bidirectional: bool = True
    # embeddings / IO
    input_kind: str = "tokens"    # "tokens" | "embeds" (stub frontends)
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma-style sqrt(d_model) scaling
    act: str = "silu"             # "silu" (swiglu) | "gelu" (geglu)
    # numerics / training
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"      # "adamw" | "adafactor"
    opt_state_dtype: str = "float32"
    remat: str = "full"           # "none" | "full" | "dots"
    # quantization integration (the paper's technique)
    quant_skip: Sequence[str] = ("norm", "router", "A_log", "decay")
    # long-context capability: run long_500k only if sub-quadratic
    subquadratic: bool = False
    notes: str = ""

    @property
    def n_groups(self) -> int:
        return (self.n_layers - len(self.head_layers)) // len(self.group)

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(self.d_model // 16, 8)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def dtype(self, kind: str):
        return jnp.dtype(getattr(self, kind + "_dtype"))

    def validate(self) -> "ModelConfig":
        assert (self.n_layers - len(self.head_layers)) % len(self.group) == 0, (
            self.name, self.n_layers, len(self.group))
        if self.family == "encdec":
            assert self.n_enc_layers > 0
        return self


ARCHS = [
    "gemma2_27b", "yi_34b", "qwen3_0_6b", "glm4_9b", "deepseek_v2_lite_16b",
    "granite_moe_3b_a800m", "qwen2_vl_72b", "whisper_tiny", "rwkv6_3b",
    "jamba_1_5_large_398b",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.config().validate()


def get_reduced_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.reduced().validate()


def list_archs() -> list[str]:
    return list(ARCHS)
