"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 - qk_norm, GQA [hf:Qwen/Qwen3-8B family; hf]."""
from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="lm",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=3072, vocab=151936, group=(LayerSpec(),),
        qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-reduced", family="lm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab=499, group=(LayerSpec(),),
        qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32", scan_chunk=8,
    )
