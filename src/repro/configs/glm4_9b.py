"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552 -
RoPE, extreme GQA (2 KV heads) [hf:THUDM/glm-4-9b; hf].

kv=2 < model-axis 16: KV projections replicate across the model axis (the
sharding rules fall back; flagged in the roofline notes)."""
from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="lm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab=151552, group=(LayerSpec(),),
        rope_theta=10000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="glm4-reduced", family="lm",
        n_layers=4, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab=307, group=(LayerSpec(),),
        param_dtype="float32", compute_dtype="float32", scan_chunk=8,
    )
