"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H d_ff=1536
vocab=51865 - enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].

input_specs() supplies precomputed frame embeddings (B, S_enc, 384). RoPE
stands in for whisper's learned absolute positions (backbone-equivalent;
the assignment marks this arch 'unverified'). long_500k skipped: full
attention enc-dec is not sub-quadratic."""
from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec",
        n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        head_dim=64, d_ff=1536, vocab=51865,
        group=(LayerSpec(mixer="attn", ffn="dense", cross_attn=True),),
        act="gelu", input_kind="embeds",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-reduced", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=269,
        group=(LayerSpec(mixer="attn", ffn="dense", cross_attn=True),),
        act="gelu", input_kind="embeds",
        param_dtype="float32", compute_dtype="float32", scan_chunk=8,
    )
