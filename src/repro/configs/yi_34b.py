"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 -
llama-arch GQA [arXiv:2403.04652; hf]."""
from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="lm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=20480, vocab=64000, group=(LayerSpec(),),
        rope_theta=5_000_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="yi-reduced", family="lm",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab=257, group=(LayerSpec(),),
        rope_theta=5_000_000.0,
        param_dtype="float32", compute_dtype="float32", scan_chunk=8,
    )
