"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8 [hf:ibm-granite family; hf].

Assignment header says 'MoE 40e top-8'; the inline note says '32 experts'.
We follow the structured header: 40 experts, top-8. 40 is not divisible by
the 16-way model axis - GSPMD pads expert shards (flagged in roofline notes;
the hillclimb evaluates an 8-way expert factorization instead)."""
from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="lm",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab=49155,
        group=(LayerSpec(mixer="attn", ffn="moe"),),
        n_experts=40, top_k=8, expert_ff=512,
        tie_embeddings=True, rope_theta=10000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-reduced", family="lm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=48, vocab=293,
        group=(LayerSpec(mixer="attn", ffn="moe"),),
        n_experts=10, top_k=4, expert_ff=48,
        tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32", scan_chunk=8,
    )
