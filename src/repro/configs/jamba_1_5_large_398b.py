"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 - Mamba+attention 1:7 interleave
[arXiv:2403.19887; hf].

Pattern: 8-layer blocks, attention at in-block index 4 (the Jamba layout),
MoE on every odd layer. Sub-quadratic overall (9 attention layers use
sequence-parallel flash-decode at 512k) -> runs long_500k. adafactor +
bf16 states: 398B params would not fit 256 chips with f32 Adam
(DESIGN.md §6)."""
from .base import LayerSpec, ModelConfig


def _group():
    specs = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(specs)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="lm",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab=65536, group=_group(),
        n_experts=16, top_k=2, expert_ff=24576,
        ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
        scan_chunk=128, subquadratic=True,
        optimizer="adafactor", opt_state_dtype="bfloat16",
    )


def reduced() -> ModelConfig:
    g = []
    for i in range(4):
        g.append(LayerSpec(mixer="attn" if i == 2 else "mamba",
                           ffn="moe" if i % 2 == 1 else "dense"))
    return ModelConfig(
        name="jamba-reduced", family="lm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=281, group=tuple(g),
        n_experts=4, top_k=2, expert_ff=128,
        ssm_d_state=4, ssm_d_conv=4, ssm_expand=2, scan_chunk=8,
        subquadratic=True,
        param_dtype="float32", compute_dtype="float32",
    )
