from .base import (ARCHS, LayerSpec, ModelConfig, get_config,
                   get_reduced_config, list_archs)

__all__ = ["ARCHS", "LayerSpec", "ModelConfig", "get_config",
           "get_reduced_config", "list_archs"]
