"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MLA kv_lora=512, MoE 64 routed top-6 + 2 shared
[arXiv:2405.04434; hf].

Assignment header says '64e top-6'; the inline note says '160 routed' - we
follow the structured header (matches the real V2-Lite). Layer 0 is dense
(d_ff=10944) per the reference model; layers 1-26 are MLA+MoE and scanned.
"""
from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="lm",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=192,
        d_ff=10944, vocab=102400,
        head_layers=(LayerSpec(mixer="mla", ffn="dense"),),
        group=(LayerSpec(mixer="mla", ffn="moe"),),
        kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=64, n_shared_experts=2, top_k=6, expert_ff=1408,
        rope_theta=10000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-reduced", family="lm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=160, vocab=401,
        head_layers=(LayerSpec(mixer="mla", ffn="dense"),),
        group=(LayerSpec(mixer="mla", ffn="moe"),),
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        n_experts=8, n_shared_experts=2, top_k=3, expert_ff=32,
        param_dtype="float32", compute_dtype="float32", scan_chunk=8,
    )
