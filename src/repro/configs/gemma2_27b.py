"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 - local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf]."""
from .base import LayerSpec, ModelConfig

_GROUP = (LayerSpec(mixer="attn", ffn="dense", window=4096),   # local
          LayerSpec(mixer="attn", ffn="dense"))                # global


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="lm",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=36864, vocab=256000, group=_GROUP,
        attn_softcap=50.0, final_softcap=30.0, post_block_norm=True,
        act="gelu", tie_embeddings=True, embed_scale=True,
        rope_theta=10000.0,
        notes="full global layers every other block -> long_500k skipped "
              "(not sub-quadratic).",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma2-reduced", family="lm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=211,
        group=(LayerSpec(mixer="attn", ffn="dense", window=8),
               LayerSpec(mixer="attn", ffn="dense")),
        attn_softcap=50.0, final_softcap=30.0, post_block_norm=True,
        act="gelu", tie_embeddings=True, embed_scale=True,
        param_dtype="float32", compute_dtype="float32", scan_chunk=8,
    )
