"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 - M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision frontend is a STUB per the assignment -
input_specs() supplies precomputed patch embeddings (B, S, d_model) plus
(3, B, S) M-RoPE position streams. adafactor + bf16 master keeps the 72B
params + optimizer inside 256 x 16 GB."""
from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="lm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=29568, vocab=152064, group=(LayerSpec(),),
        mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
        input_kind="embeds", optimizer="adafactor", opt_state_dtype="bfloat16",
        kv_cache_dtype="int8",   # §Perf hillclimb: 4.3x decode memory term
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2vl-reduced", family="lm",
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab=311, group=(LayerSpec(),),
        mrope_sections=(2, 3, 3), rope_theta=1_000_000.0,
        input_kind="embeds",
        param_dtype="float32", compute_dtype="float32", scan_chunk=8,
    )
