"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, peak_lr, warmup_steps, total_steps,
                       final_frac=0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)
