"""AdamW with configurable state dtype (f32 default; bf16 for the biggest
archs so params+states fit the pod - DESIGN.md §6). Pure-pytree functional
optimizer; math in f32 regardless of storage dtype."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params, state_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
           weight_decay=0.1):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}


def state_shardings(param_shardings, mesh):
    """Optimizer state mirrors parameter sharding (ZeRO via GSPMD 2-D FSDP)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    return {
        "m": param_shardings,
        "v": param_shardings,
        "count": NamedSharding(mesh, P()),
    }
