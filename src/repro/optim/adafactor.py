"""Adafactor (factored second moments) - the memory-frugal optimizer for the
398B/72B archs: O(n+m) second-moment storage per (n,m) matrix instead of
O(nm), optional bf16 first moment."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(p) -> bool:
    return p.ndim >= 2


def init(params, state_dtype=jnp.float32, use_momentum=True):
    def v_init(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], state_dtype),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], state_dtype)}
        return {"v": jnp.zeros(p.shape, state_dtype)}

    state = {"v": jax.tree.map(v_init, params,
                               is_leaf=lambda x: isinstance(x, jax.Array)),
             "count": jnp.zeros((), jnp.int32)}
    if use_momentum:
        state["m"] = jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype),
                                  params)
    return state


def update(grads, state, params, *, lr, b2=0.999, eps=1e-30, clip=1.0,
           weight_decay=0.0, b1=0.9):
    count = state["count"] + 1
    has_m = "m" in state

    def upd(g, vdict, p, m=None):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p):
            vr = b2 * vdict["vr"].astype(jnp.float32) + (1 - b2) * g2.mean(-1)
            vc = b2 * vdict["vc"].astype(jnp.float32) + (1 - b2) * g2.mean(-2)
            denom = (vr[..., None] / jnp.maximum(
                vr.mean(-1, keepdims=True)[..., None], eps)) * vc[..., None, :]
            u = g32 / jnp.sqrt(denom + eps)
            new_v = {"vr": vr.astype(vdict["vr"].dtype),
                     "vc": vc.astype(vdict["vc"].dtype)}
        else:
            v = b2 * vdict["v"].astype(jnp.float32) + (1 - b2) * g2
            u = g32 / jnp.sqrt(v + eps)
            new_v = {"v": v.astype(vdict["v"].dtype)}
        # update clipping (RMS <= clip)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms / clip)
        if m is not None:
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * u
            u_out = m32
            new_m = m32.astype(m.dtype)
        else:
            u_out, new_m = u, None
        new_p = (p.astype(jnp.float32)
                 - lr * (u_out + weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), new_v, new_m

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_v = treedef.flatten_up_to(state["v"])
    leaves_p = treedef.flatten_up_to(params)
    leaves_m = (treedef.flatten_up_to(state["m"]) if has_m
                else [None] * len(leaves_g))
    outs = [upd(g, v, p, m) for g, v, p, m in
            zip(leaves_g, leaves_v, leaves_p, leaves_m)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_state = {"v": jax.tree.unflatten(treedef, [o[1] for o in outs]),
                 "count": count}
    if has_m:
        new_state["m"] = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_params, new_state


# optimizer-state shardings are derived structurally from the state tree in
# repro.train.step.opt_state_shardings (handles vr/vc factored leaves).
