"""Optimizers built in-repo (no external deps): AdamW + Adafactor."""
from __future__ import annotations

import jax.numpy as jnp

from . import adafactor, adamw
from .schedule import cosine_with_warmup


class Optimizer:
    """Thin dispatch facade: cfg.optimizer -> module with init/update."""

    def __init__(self, kind: str, state_dtype: str = "float32", **hyper):
        self.kind = kind
        self.mod = {"adamw": adamw, "adafactor": adafactor}[kind]
        self.state_dtype = jnp.dtype(state_dtype)
        self.hyper = hyper

    def init(self, params):
        return self.mod.init(params, self.state_dtype)

    def update(self, grads, state, params, *, lr):
        return self.mod.update(grads, state, params, lr=lr, **self.hyper)


def for_config(cfg, **hyper) -> Optimizer:
    return Optimizer(cfg.optimizer, cfg.opt_state_dtype, **hyper)


__all__ = ["Optimizer", "for_config", "adamw", "adafactor",
           "cosine_with_warmup"]
