"""Structured tracing for the serving stack: span / instant / async-span /
counter events in the Chrome trace-event JSON format, loadable directly by
Perfetto (https://ui.perfetto.dev) and ``chrome://tracing``.

Design constraints, in order:

  ~zero cost when disabled   The hot loop is instrumented unconditionally,
      so the disabled path must be a handful of no-op attribute calls.
      ``NullTracer`` (the engines' default) implements the full surface as
      no-ops and returns one shared null context manager from ``span`` —
      nothing allocates, nothing formats, nothing appends.

  injectable clock           ``Tracer(clock=...)`` takes any zero-arg
      monotonic-seconds callable. Tests inject ``FakeClock`` (a fixed tick
      per call) so a seeded run emits byte-identical trace JSON — the
      observability analogue of the golden-trace fixture. Production uses
      ``time.perf_counter``.

  one track per component    Tracks are named strings ("router",
      "decode/w0", "freeze/w0", ...) mapped to Chrome ``tid``s in
      first-use order; ``to_dict`` emits the matching ``thread_name`` /
      ``thread_sort_index`` metadata so Perfetto shows one labeled lane
      per component.

Event kinds (Chrome ``ph`` phases):

  span          "X" complete event with ts+dur — a timed phase. Use the
                ``span()`` context manager when args are known up front, or
                ``t0 = tracer.now(); ...; tracer.complete(...)`` when args
                (e.g. payload bytes) only exist at the end.
  instant       "i" — a decision point (route, accept, reject).
  counter       "C" — a per-step gauge (occupancy, modeled HBM bytes).
  async span    "b"/"n"/"e" with an id — a lifecycle that outlives any one
                call frame and overlaps its neighbours on the same track.
                The page-freeze lifecycle (queued -> dispatched ->
                installed | dropped | rolled_back) and in-flight prefills
                are async spans keyed by a caller-chosen id.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Iterable


class FakeClock:
    """Deterministic test clock: advances ``tick`` seconds per call.

    Timestamps become call counts, so a seeded run's trace depends only on
    its event sequence — byte-identical across runs and platforms.
    """

    def __init__(self, tick: float = 0.001, t0: float = 0.0) -> None:
        self.tick = tick
        self._t = t0

    def __call__(self) -> float:
        t = self._t
        self._t += self.tick
        return t


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer: the default everywhere, so instrumentation points pay
    only an attribute call + early return when tracing is off."""

    enabled = False
    events: tuple = ()

    def now(self) -> float:
        return 0.0

    def span(self, track: str, name: str, **args: Any) -> "_NullSpan":
        return _NULL_SPAN

    def complete(self, track: str, name: str, t0: float,
                 **args: Any) -> None:
        pass

    def instant(self, track: str, name: str, **args: Any) -> None:
        pass

    def counter(self, track: str, name: str, **values: Any) -> None:
        pass

    def async_begin(self, track: str, name: str, id: Any,
                    **args: Any) -> None:
        pass

    def async_instant(self, track: str, name: str, id: Any,
                      **args: Any) -> None:
        pass

    def async_end(self, track: str, name: str, id: Any,
                  **args: Any) -> None:
        pass

    def to_dict(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        pass


NULL_TRACER = NullTracer()


class _Span:
    """Context manager emitting one complete ("X") event on exit."""

    __slots__ = ("tr", "track", "name", "args", "t0")

    def __init__(self, tr: "Tracer", track: str, name: str,
                 args: dict[str, Any]) -> None:
        self.tr, self.track, self.name, self.args = tr, track, name, args

    def __enter__(self) -> "_Span":
        self.t0 = self.tr.clock()
        return self

    def __exit__(self, *exc: object) -> bool:
        self.tr._emit_complete(self.track, self.name, self.t0,
                               self.tr.clock(), self.args)
        return False


class Tracer:
    """Collects trace events in memory; ``write()`` emits Perfetto-loadable
    Chrome trace JSON. All timestamps come from the injected ``clock``."""

    enabled = True

    def __init__(self, clock: Callable[[], float] | None = None, *,
                 pid: int = 0) -> None:
        self.clock = clock if clock is not None else time.perf_counter
        self.pid = pid
        self._t0 = self.clock()
        self.events: list[dict] = []
        self._tids: dict[str, int] = {}

    # ------------------------------------------------------------- clock

    def now(self) -> float:
        """Seconds on the tracer clock (pair with ``complete``)."""
        return self.clock()

    def _us(self, t: float) -> float:
        return round((t - self._t0) * 1e6, 3)

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids) + 1
        return tid

    # ------------------------------------------------------------ events

    def _emit_complete(self, track: str, name: str, t0: float, t1: float,
                       args: dict[str, Any]) -> None:
        ev = {"ph": "X", "name": name, "pid": self.pid,
              "tid": self._tid(track), "ts": self._us(t0),
              "dur": round((t1 - t0) * 1e6, 3)}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def span(self, track: str, name: str, **args: Any) -> _Span:
        return _Span(self, track, name, args)

    def complete(self, track: str, name: str, t0: float,
                 **args: Any) -> None:
        """Close an explicitly-timed region opened at ``t0 = tracer.now()``
        — for spans whose args (payload bytes, ...) exist only at the end."""
        self._emit_complete(track, name, t0, self.clock(), args)

    def instant(self, track: str, name: str, **args: Any) -> None:
        ev = {"ph": "i", "s": "t", "name": name, "pid": self.pid,
              "tid": self._tid(track), "ts": self._us(self.clock())}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, track: str, name: str, **values: Any) -> None:
        self.events.append({"ph": "C", "name": name, "pid": self.pid,
                            "tid": self._tid(track),
                            "ts": self._us(self.clock()), "args": values})

    def _async(self, ph: str, track: str, name: str, id: Any,
               args: dict[str, Any]) -> None:
        ev = {"ph": ph, "cat": track, "name": name, "id": str(id),
              "pid": self.pid, "tid": self._tid(track),
              "ts": self._us(self.clock())}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def async_begin(self, track: str, name: str, id: Any,
                    **args: Any) -> None:
        self._async("b", track, name, id, args)

    def async_instant(self, track: str, name: str, id: Any,
                      **args: Any) -> None:
        self._async("n", track, name, id, args)

    def async_end(self, track: str, name: str, id: Any,
                  **args: Any) -> None:
        self._async("e", track, name, id, args)

    # ------------------------------------------------------------ output

    def _metadata(self) -> list[dict]:
        meta = [{"ph": "M", "name": "process_name", "pid": self.pid,
                 "tid": 0, "args": {"name": "repro.serving"}}]
        for track, tid in sorted(self._tids.items(), key=lambda kv: kv[1]):
            meta.append({"ph": "M", "name": "thread_name", "pid": self.pid,
                         "tid": tid, "args": {"name": track}})
            meta.append({"ph": "M", "name": "thread_sort_index",
                         "pid": self.pid, "tid": tid,
                         "args": {"sort_index": tid}})
        return meta

    def to_dict(self) -> dict:
        return {"traceEvents": self._metadata() + self.events,
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        """Write Perfetto-loadable JSON. ``sort_keys`` + fixed separators
        keep the bytes deterministic for the fake-clock golden tests."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, sort_keys=True,
                      separators=(",", ":"), allow_nan=False)


# ------------------------------------------------------------ inspection


def count_events(events: Iterable[dict], *, track: str | None = None,
                 name: str | None = None, ph: str | None = None) -> int:
    """Count events matching the filters (trace-vs-counter reconciliation;
    ``track`` matches the async ``cat`` field or is resolved by callers that
    hold the tracer via ``select_events``)."""
    return len(select_events(events, track=track, name=name, ph=ph))


def select_events(events: Iterable[dict], *, track: str | None = None,
                  name: str | None = None,
                  ph: str | None = None) -> list[dict]:
    out = []
    for ev in events:
        if name is not None and ev.get("name") != name:
            continue
        if ph is not None and ev.get("ph") != ph:
            continue
        if track is not None and ev.get("cat") != track:
            continue
        out.append(ev)
    return out


def tracks_of(tracer: Tracer) -> dict[str, int]:
    """Track-name -> tid mapping of a live tracer (schema tests)."""
    return dict(tracer._tids)
