"""Streaming metric primitives: counters, gauges, and fixed-bucket log
histograms with O(1)-memory windowed percentiles.

The previous ``MetricsCollector`` kept raw sample lists (``occupancy``,
``cache_bytes``, inter-token ``gaps``) that grow O(tokens) — fine for a
bench, an OOM for a long-lived service. Everything here is fixed-size:

  Counter       monotonically increasing int.
  Gauge         streaming last/n/sum/min/max (mean derivable).
  LogHistogram  geometric buckets over [lo, hi) with underflow/overflow
                bins; ``percentile(p)`` answers from bucket counts with
                relative error bounded by the bucket ratio (~8%/bucket at
                the default 16 buckets/decade). A snapshot of the counts
                array ("counts-delta") gives *windowed* percentiles
                between two exporter ticks without storing samples.

``Registry`` is a flat name -> metric map; ``snapshot()`` renders every
metric to plain JSON-safe scalars for the JSONL/Prometheus exporters.
"""
from __future__ import annotations

import math
from typing import TypeVar, Union


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Streaming scalar: remembers last/min/max and running sum/count."""

    __slots__ = ("last", "n", "total", "vmin", "vmax")

    def __init__(self) -> None:
        self.last: float | None = None
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def set(self, v: float) -> None:
        v = float(v)
        self.last = v
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> float | None:
        return self.total / self.n if self.n else None

    def snapshot(self) -> dict:
        return {"type": "gauge", "last": self.last, "n": self.n,
                "mean": self.mean,
                "min": self.vmin if self.n else None,
                "max": self.vmax if self.n else None}


class LogHistogram:
    """Fixed-bucket log histogram over [lo, hi).

    Bucket i covers [lo * r**i, lo * r**(i+1)) with r chosen so there are
    ``per_decade`` buckets per decade. Values below ``lo`` land in the
    underflow bin (reported as ``lo``); values >= ``hi`` in the overflow
    bin (reported as ``hi``). Exact min/max/sum are tracked alongside so
    p0/p100 and the mean stay exact; interior percentiles are bucket
    midpoints (geometric), error bounded by sqrt(r).

    Defaults suit latencies in seconds: 100ns .. 1000s.
    """

    __slots__ = ("lo", "hi", "per_decade", "_log_lo", "_inv_log_r",
                 "nbuckets", "counts", "underflow", "overflow",
                 "n", "total", "vmin", "vmax")

    def __init__(self, lo: float = 1e-7, hi: float = 1e3,
                 per_decade: int = 16) -> None:
        assert 0 < lo < hi
        self.lo, self.hi, self.per_decade = lo, hi, per_decade
        self._log_lo = math.log10(lo)
        self._inv_log_r = per_decade  # buckets per decade
        self.nbuckets = int(math.ceil(
            (math.log10(hi) - self._log_lo) * per_decade))
        self.counts = [0] * self.nbuckets
        self.underflow = 0
        self.overflow = 0
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _bucket(self, v: float) -> int:
        return int((math.log10(v) - self._log_lo) * self._inv_log_r)

    def observe(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v < self.lo:
            self.underflow += 1
        elif v >= self.hi:
            self.overflow += 1
        else:
            i = self._bucket(v)
            if i < 0:
                i = 0
            elif i >= self.nbuckets:
                i = self.nbuckets - 1
            self.counts[i] += 1

    # --------------------------------------------------------- percentile

    def _bucket_value(self, i: int) -> float:
        # geometric midpoint of bucket i
        return 10.0 ** (self._log_lo + (i + 0.5) / self.per_decade)

    def percentile(self, p: float, *, counts: list[int] | None = None,
                   underflow: int | None = None,
                   overflow: int | None = None,
                   n: int | None = None) -> float | None:
        """p in [0, 100]. Pass the delta fields to answer over a window."""
        counts = self.counts if counts is None else counts
        underflow = self.underflow if underflow is None else underflow
        overflow = self.overflow if overflow is None else overflow
        n = self.n if n is None else n
        if n <= 0:
            return None
        rank = p / 100.0 * n
        seen = underflow
        if rank <= seen and underflow:
            return max(self.vmin, 0.0) if self.vmin < self.lo else self.lo
        for i, c in enumerate(counts):
            if not c:
                continue
            seen += c
            if rank <= seen:
                v = self._bucket_value(i)
                # clamp to the exact observed range
                if self.vmin != math.inf:
                    v = min(max(v, self.vmin), self.vmax)
                return v
        # falls in overflow (or rounding): report the exact max
        return self.vmax if self.vmax != -math.inf else self.hi

    @property
    def mean(self) -> float | None:
        return self.total / self.n if self.n else None

    def state(self) -> dict:
        """Copy of the count state — store it, then pass ``delta(prev)``
        results back into ``percentile`` for windowed answers."""
        return {"counts": list(self.counts), "underflow": self.underflow,
                "overflow": self.overflow, "n": self.n}

    def delta(self, prev: dict) -> dict:
        return {"counts": [a - b for a, b in zip(self.counts,
                                                 prev["counts"])],
                "underflow": self.underflow - prev["underflow"],
                "overflow": self.overflow - prev["overflow"],
                "n": self.n - prev["n"]}

    def snapshot(self) -> dict:
        return {"type": "histogram", "n": self.n, "mean": self.mean,
                "min": self.vmin if self.n else None,
                "max": self.vmax if self.n else None,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}


Metric = Union[Counter, Gauge, LogHistogram]
_M = TypeVar("_M", Counter, Gauge, LogHistogram)


class Registry:
    """Flat name -> metric map with get-or-create accessors."""

    def __init__(self) -> None:
        self._metrics: dict[str, Metric] = {}

    def _get(self, name: str, cls: type[_M], *args: object,
             **kw: object) -> _M:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(*args, **kw)
        assert isinstance(m, cls), f"{name} registered as {type(m).__name__}"
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw: object) -> LogHistogram:
        return self._get(name, LogHistogram, **kw)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str) -> Metric:
        return self._metrics[name]

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-safe dump of every metric (sorted for determinism)."""
        return {name: self._metrics[name].snapshot()
                for name in self.names()}
