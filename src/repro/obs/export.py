"""Metrics export: periodic JSONL snapshots, Prometheus-style text
exposition, and host-side modeled roofline counters for the decode loop.

``MetricsExporter`` hangs off an engine run loop: ``maybe_emit()`` is
called every iteration but only writes when ``interval_s`` elapsed on the
injected clock (fake clock in tests -> deterministic snapshot cadence).
Each line is strict JSON (``allow_nan=False``) so downstream ``json.loads``
round-trips, and windowed percentiles for every log histogram come from a
counts-delta against the previous emit — no samples stored.

``modeled_decode_hbm_bytes`` is the live-gauge twin of
``kernels.paged_attention.modeled_hbm_bytes_per_token``: it prices the
next decode step's KV traffic from host state only (block tables, lens,
installed-frozen page set, per-page byte model) — no device sync — so the
run loop can publish bytes/token and a roofline ``t_memory`` every step.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable

from repro.analysis.roofline import HBM_BW

from .stats import Counter, Gauge, LogHistogram, Registry


class MetricsExporter:
    """Periodic JSONL metrics snapshots with windowed histogram
    percentiles. ``path=None`` keeps lines in ``self.lines`` only (tests).
    """

    def __init__(self, path: str | None = None, *, interval_s: float = 1.0,
                 clock: Callable[[], float] | None = None,
                 registry: Registry | None = None) -> None:
        self.path = path
        self.interval_s = interval_s
        self.clock = clock if clock is not None else time.perf_counter
        self.registry = registry
        self.lines: list[dict] = []
        self._file = open(path, "w") if path else None
        self._last_emit: float | None = None
        self._hist_states: dict[str, dict] = {}
        self.seq = 0

    def _windowed(self, registry: Registry) -> dict:
        """p50/p99 over just the interval since the previous emit, from
        histogram counts-deltas (O(buckets), no samples retained)."""
        out: dict[str, dict] = {}
        for name in registry.names():
            m = registry[name]
            if not isinstance(m, LogHistogram):
                continue
            prev = self._hist_states.get(name)
            if prev is None:
                delta = m.state()
            else:
                delta = m.delta(prev)
            self._hist_states[name] = m.state()
            if delta["n"] > 0:
                out[name] = {"n": delta["n"],
                             "p50": m.percentile(50, **delta),
                             "p99": m.percentile(99, **delta)}
        return out

    def maybe_emit(self, metrics: Any = None, *, force: bool = False,
                   extra: dict | None = None) -> dict | None:
        """Emit one snapshot line if ``interval_s`` elapsed (or ``force``).

        ``metrics`` is anything with ``snapshot()`` + ``stats`` (a
        ``MetricsCollector``) or a bare ``Registry``; defaults to the
        registry bound at construction.
        """
        now = self.clock()
        if not force and self._last_emit is not None \
                and now - self._last_emit < self.interval_s:
            return None
        self._last_emit = now
        src = metrics if metrics is not None else self.registry
        registry = getattr(src, "stats", src)
        line = {"seq": self.seq, "t": round(now, 6)}
        self.seq += 1
        snap = src.snapshot() if hasattr(src, "snapshot") else {}
        line.update(snap)
        win = self._windowed(registry) if registry is not None else {}
        if win:
            line["window"] = win
        if extra:
            line.update(extra)
        self.lines.append(line)
        if self._file is not None:
            json.dump(line, self._file, sort_keys=True, allow_nan=False)
            self._file.write("\n")
            self._file.flush()
        return line

    def close(self, metrics: Any = None) -> None:
        """Final forced snapshot, then release the file."""
        self.maybe_emit(metrics, force=True)
        if self._file is not None:
            self._file.close()
            self._file = None


# -------------------------------------------------------------- prometheus


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    return "".join(out)


def prometheus_text(snapshot: dict, *, prefix: str = "repro") -> str:
    """Render a ``Registry.snapshot()`` / ``MetricsCollector.snapshot()``
    dict as Prometheus text exposition (counters -> _total, gauges ->
    last + _mean/_max, histograms -> quantile-labeled gauges)."""
    lines: list[str] = []
    for name in sorted(snapshot):
        v = snapshot[name]
        base = f"{prefix}_{_prom_name(name)}"
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            lines.append(f"# TYPE {base} gauge")
            lines.append(f"{base} {v}")
            continue
        if not isinstance(v, dict):
            continue
        kind = v.get("type")
        if kind == "counter":
            lines.append(f"# TYPE {base}_total counter")
            lines.append(f"{base}_total {v['value']}")
        elif kind == "gauge":
            lines.append(f"# TYPE {base} gauge")
            if v.get("last") is not None:
                lines.append(f"{base} {v['last']}")
            for stat in ("mean", "max"):
                if v.get(stat) is not None:
                    lines.append(f"{base}_{stat} {v[stat]}")
        elif kind == "histogram":
            lines.append(f"# TYPE {base} summary")
            for q, key in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99")):
                if v.get(key) is not None:
                    lines.append(
                        f'{base}{{quantile="{q}"}} {v[key]}')
            lines.append(f"{base}_count {v['n']}")
            if v.get("mean") is not None:
                lines.append(f"{base}_sum {v['mean'] * v['n']}")
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------- roofline


def modeled_decode_hbm_bytes(worker: Any) -> dict | None:
    """Price the KV traffic of the next decode step for a ``DecodeWorker``
    from host state only (no device sync).

    gather impl reads every gathered page at fp width:
        bytes = mb_used * page_fp
    fused impl reads each live sequence's own pages at their installed
    width (frozen pages serve codes + codebooks):
        bytes = sum over active seqs, pages of page[frozen? : fp]

    Returns per-step totals, bytes/token (token = one step of one active
    sequence), and the roofline ``t_memory`` for the modeled impl; None
    when no sequence is live.
    """
    active = worker.sched.active_slots()
    if not active:
        return None
    bs = worker.block_size
    pb = worker._pb
    need = int(worker.lens.max()) + 1
    mb_used = max(1, -(-need // bs))
    gather = mb_used * pb["fp"]
    fused = 0.0
    for i in active:
        npages = -(-(int(worker.lens[i]) + 1) // bs)
        for j in range(npages):
            blk = int(worker.table[i, j])
            fused += pb["frozen"] if blk in worker._frozen_pages else pb["fp"]
    step_bytes = gather if worker.attn_impl == "gather" else fused
    n_tok = len(active)
    return {"hbm_bytes_step": float(step_bytes),
            "hbm_bytes_per_token": float(step_bytes) / n_tok,
            "hbm_bytes_step_gather": float(gather),
            "hbm_bytes_step_fused": float(fused),
            "t_memory_s": float(step_bytes) / HBM_BW}


def modeled_prefill_hbm_bytes(pb: dict, blocks, frozen_pages, *,
                              block_size: int, off: int, chunk: int,
                              fused: bool) -> dict:
    """Price one prefill chunk's KV page traffic from host state only —
    the chunked-prefill twin of ``modeled_decode_hbm_bytes`` (and the live
    counterpart of ``kernels.modeled_prefill_hbm_bytes_per_token``).

    The chunk at token offset ``off`` attends pages 0..ceil((off+chunk)/bs).
    fused (kernel) pricing reads each of those pages at its installed width
    — frozen pages cross as packed codes + codebooks, the shared-context
    reuse the fused chunked path monetizes; gather pricing expands every
    table page (the whole worst-case table) at fp width.
    """
    npages = max(1, -(-(off + chunk) // block_size))
    if fused:
        hbm = sum(pb["frozen"] if int(b) in frozen_pages else pb["fp"]
                  for b in blocks[:npages])
    else:
        hbm = len(blocks) * pb["fp"]
    return {"hbm_bytes_chunk": float(hbm),
            "hbm_bytes_per_token": float(hbm) / max(chunk, 1),
            "t_memory_s": float(hbm) / HBM_BW}
