"""Low-overhead observability for the serving stack.

``trace``   span/instant/async/counter events -> Chrome trace-event JSON
            (Perfetto-loadable), injectable clock, ``NullTracer`` no-op
            default.
``stats``   streaming counters/gauges/log-histograms with O(1)-memory
            windowed percentiles.
``export``  periodic JSONL snapshots, Prometheus text exposition, and
            host-side modeled roofline gauges for the decode loop.
"""
from .export import (MetricsExporter, modeled_decode_hbm_bytes,
                     prometheus_text)
from .stats import Counter, Gauge, LogHistogram, Registry
from .trace import (FakeClock, NULL_TRACER, NullTracer, Tracer,
                    count_events, select_events, tracks_of)

__all__ = [
    "Counter", "FakeClock", "Gauge", "LogHistogram", "MetricsExporter",
    "NULL_TRACER", "NullTracer", "Registry", "Tracer", "count_events",
    "modeled_decode_hbm_bytes", "prometheus_text", "select_events",
    "tracks_of",
]
