"""KV page handoff between serving workers (prefill -> decode).

A finished prefill's pages leave the prefill worker's pool as a
``PagePayload`` and land in a decode worker's pool via ``splice_payload``:
the decode worker allocates fresh block ids, the payload's pages are
scattered into its pools at those ids, and the sequence's block table row
points at them — a page-table splice, not a pool copy.

Three migration modes:

  "splice"   Colocated no-op: prefill wrote directly into the decode
             worker's (shared) pool, so the payload carries block ids and
             no arrays. Zero bytes move.

  "fp"       Baseline: every written row crosses as full-width fp
             (full pages whole, the trailing partial page only its valid
             rows). This is what disaggregated serving without codebook
             compression pays per handoff.

  "frozen"   The sparse-LSQ payoff: full pages are routed through the
             existing ``dispatch_freeze`` spec path on the *source* pool,
             so they cross the wire as packed 4-bit codes + one per-block
             codebook (~7x fewer bytes than fp at 16 values) and are
             installed on the destination through the same
             ``install_freeze`` used by in-place freezing — which scatters
             codes/codebooks, flips ``blk_q``, and materializes the
             reconstruction into the fp rows, so the landed pages are
             directly servable by both the fused kernel (codes) and the
             gather path (fp reconstruction). Only the trailing partial
             page still crosses fp.

  "resident" Overload demotion (``extract_resident_pages``): capture a
             LIVE sequence's pages exactly as currently served — pages
             already installed frozen cross as their existing codes +
             codebooks (read straight off the pool, NO re-solve, so the
             restored values are bit-identical to what attention was
             reading), everything else (unfrozen full pages + tail rows)
             crosses fp. ``frozen_idx`` records which sequence-order page
             positions carry codes. This is the tiered-paging wire format:
             re-solving would quantize not-yet-frozen pages early and
             diverge from the never-offloaded trace.

Payloads stage through host memory (``to_host``), which is both where the
byte accounting happens and where a NIC would sit in a multi-host
deployment; ``nbytes`` vs ``fp_equiv_bytes`` is the measured migration
compression.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.obs.trace import NULL_TRACER

from .kv_cache import (PagedKVCache, PendingFreeze, dispatch_freeze,
                       install_freeze, map_layers)


def collect_leaves(tree) -> list[PagedKVCache]:
    """Layer leaves in deterministic tree order (extract and splice must
    walk source and destination trees identically)."""
    out: list[PagedKVCache] = []
    map_layers(out.append, tree)
    return out


@dataclasses.dataclass
class PagePayload:
    """One migrated sequence's KV pages, staged for transfer.

    ``blocks`` are source-pool ids in sequence page order; array layouts
    per layer leaf (G? = stacked group axis when present):

      full    (2, G?, n_full, bs, Hkv, Dh)   fp full pages       [fp]
      frozen  ((2, G?, n_full, bs, Hkv, Dc), (2, G?, n_full, L)) [frozen]
      tail    (2, G?, tail_rows, Hkv, Dh)    partial-page rows   [fp+frozen]

    "resident" payloads split the full pages between ``full`` (unfrozen,
    fp) and ``frozen`` (already-installed codes); ``frozen_idx`` names the
    sequence-order page positions the ``frozen`` arrays cover, in order.

    ``shared_pages`` is refcount-aware ownership: the sequence's leading
    pages that came from (splice payloads) or stayed behind in (resident
    payloads) the prefix index — referenced by other live tables, so never
    captured in this payload's arrays; consumers account/queue-freeze only
    the owned remainder.
    """

    mode: str
    blocks: list[int]
    n_tokens: int
    block_size: int
    n_full: int
    tail_rows: int
    shared_pages: int = 0
    full: list | None = None
    frozen: list | None = None
    tail: list | None = None
    frozen_idx: list | None = None
    nbytes: int = 0
    fp_equiv_bytes: int = 0
    staged: bool = False

    @property
    def n_pages(self) -> int:
        return self.n_full + (1 if self.tail_rows else 0)

    def _arrays(self):
        for name in ("full", "tail"):
            v = getattr(self, name)
            if v is not None:
                yield from v
        if self.frozen is not None:
            for c, cb in self.frozen:
                yield c
                yield cb

    def is_ready(self) -> bool:
        """True once every device array (including a chained freeze solve)
        has landed — ``to_host`` would not block. Callers poll this before
        harvesting so a long solve never stalls their loop."""
        return (self.staged or self.mode == "splice"
                or all(a.is_ready() for a in self._arrays()
                       if hasattr(a, "is_ready")))

    def to_host(self) -> "PagePayload":
        """Materialize every array to host numpy (blocking on any still-
        computing source-side solve) and account the bytes crossing."""
        if self.staged or self.mode == "splice":
            self.staged = True
            return self

        def host(x):
            return np.asarray(x)

        n = 0
        for name in ("full", "tail"):
            arrs = getattr(self, name)
            if arrs is not None:
                arrs = [host(a) for a in arrs]
                setattr(self, name, arrs)
                n += sum(a.nbytes for a in arrs)
        if self.frozen is not None:
            self.frozen = [(host(c), host(cb)) for c, cb in self.frozen]
            n += sum(c.nbytes + cb.nbytes for c, cb in self.frozen)
        self.nbytes = n
        self.staged = True
        return self


@dataclasses.dataclass
class FinishedPrefill:
    """Artifact a prefill worker hands the router: sampled first token (+
    its logits when recorded), the sampler state to continue decoding with,
    and the staged pages."""

    req: object
    first_token: int
    payload: PagePayload
    rng: np.random.Generator
    last_logits: np.ndarray | None = None
    worker_id: int = -1


def _take_pages(leaf: PagedKVCache, bids) -> jnp.ndarray:
    """k and v pages ``bids`` stacked on a leading axis:
    (2, G?, P, bs, Hkv, Dh)."""
    axis = 1 if leaf.k_fp.ndim == 5 else 0
    jb = jnp.asarray(np.asarray(bids, np.int32))
    return jnp.stack([jnp.take(leaf.k_fp, jb, axis=axis),
                      jnp.take(leaf.v_fp, jb, axis=axis)])


def extract_pages(tree, blocks, n_tokens: int, *, block_size: int,
                  mode: str, spec=None, tracer=NULL_TRACER) -> PagePayload:
    """Pull one sequence's first ``n_tokens`` of KV out of ``tree``.

    ``blocks`` is the sequence's block-table prefix (sequence page order).
    Returns a payload of device arrays — the frozen-mode solve is one async
    ``dispatch_freeze`` per layer, so extraction does not block the host;
    ``to_host()`` is where the transfer (and any waiting) happens.
    """
    assert mode in ("fp", "frozen"), mode
    t0 = tracer.now()
    n_full, tail_rows = divmod(n_tokens, block_size)
    used = blocks[:n_full + (1 if tail_rows else 0)]
    leaves = collect_leaves(tree)
    payload = PagePayload(mode=mode, blocks=list(map(int, used)),
                          n_tokens=n_tokens, block_size=block_size,
                          n_full=n_full, tail_rows=tail_rows)

    fp_equiv = 0
    for leaf in leaves:
        G = leaf.k_fp.shape[0] if leaf.k_fp.ndim == 5 else 1
        _, _, Hkv, Dh = leaf.k_fp.shape[-4:]
        fp_equiv += (2 * G * (n_full * block_size + tail_rows)
                     * Hkv * Dh * leaf.k_fp.dtype.itemsize)
    payload.fp_equiv_bytes = fp_equiv

    full_bids = used[:n_full]
    if mode == "fp":
        if n_full:
            payload.full = [_take_pages(leaf, full_bids) for leaf in leaves]
    elif n_full:
        if spec is None:
            raise ValueError("frozen migration needs a kv_quant spec")
        # the existing freeze path IS the wire format: one batched device
        # solve over every (page, group, k/v) row, emitting packed codes +
        # per-block codebooks. Pad to a power-of-two page count (repeating
        # one page) like the in-place flush does, so varied prompt lengths
        # share a handful of solver compiles instead of one per distinct
        # page count; dispatch_freeze sorts its block ids, so map each
        # sequence-order page to its slot in the sorted padded batch (the
        # duplicate's first occurrence is fine — identical rows, identical
        # codes), which also drops the padding from the payload.
        bucket = 1 << (n_full - 1).bit_length()
        padded = list(full_bids) + [full_bids[-1]] * (bucket - n_full)
        pending = dispatch_freeze(tree, padded, spec)
        order = np.searchsorted(np.sort(np.asarray(padded)),
                                np.asarray(full_bids))
        frozen = []
        for (codes, cb), leaf in zip(pending.results, leaves):
            paxis = 2 if leaf.k_fp.ndim == 5 else 1
            frozen.append((jnp.take(codes, order, axis=paxis),
                           jnp.take(cb, order, axis=paxis)))
        payload.frozen = frozen
    if tail_rows:
        tail_bid = [used[n_full]]
        payload.tail = [_take_pages(leaf, tail_bid)[:, ..., 0, :tail_rows, :, :]
                        for leaf in leaves]
    tracer.complete("transfer", "extract", t0, mode=mode,
                    pages=payload.n_pages, n_tokens=n_tokens,
                    fp_equiv_bytes=payload.fp_equiv_bytes)
    return payload


def extract_resident_pages(tree, blocks, n_tokens: int, frozen_idx, *,
                           block_size: int,
                           tracer=NULL_TRACER) -> PagePayload:
    """Demote one LIVE sequence's first ``n_tokens`` of KV exactly as
    currently served (overload tiered paging).

    ``frozen_idx`` lists the sequence-order positions of pages already
    *installed* frozen: those cross as their existing packed codes +
    codebooks, read straight off the pool — never re-solved, so a restore
    reproduces the exact values attention was serving. Unfrozen full pages
    and the tail cross fp (their exact values ARE the fp rows; queued or
    in-flight solves for them are dropped by the caller and re-queued
    after restore). Pure gathers — no device solve — so ``to_host`` never
    waits on a solver.
    """
    t0 = tracer.now()
    n_full, tail_rows = divmod(n_tokens, block_size)
    fset = {int(j) for j in frozen_idx if int(j) < n_full}
    fidx = sorted(fset)
    used = blocks[:n_full + (1 if tail_rows else 0)]
    leaves = collect_leaves(tree)
    payload = PagePayload(mode="resident", blocks=list(map(int, used)),
                          n_tokens=n_tokens, block_size=block_size,
                          n_full=n_full, tail_rows=tail_rows,
                          frozen_idx=fidx)
    fp_equiv = 0
    for leaf in leaves:
        G = leaf.k_fp.shape[0] if leaf.k_fp.ndim == 5 else 1
        _, _, Hkv, Dh = leaf.k_fp.shape[-4:]
        fp_equiv += (2 * G * (n_full * block_size + tail_rows)
                     * Hkv * Dh * leaf.k_fp.dtype.itemsize)
    payload.fp_equiv_bytes = fp_equiv

    fp_pos = [j for j in range(n_full) if j not in fset]
    if fp_pos:
        fp_bids = [used[j] for j in fp_pos]
        payload.full = [_take_pages(leaf, fp_bids) for leaf in leaves]
    if fidx:
        jb = jnp.asarray(np.asarray([used[j] for j in fidx], np.int32))
        frozen = []
        for leaf in leaves:
            axis = 1 if leaf.k_fp.ndim == 5 else 0
            frozen.append((
                jnp.stack([jnp.take(leaf.k_codes, jb, axis=axis),
                           jnp.take(leaf.v_codes, jb, axis=axis)]),
                jnp.stack([jnp.take(leaf.k_cb, jb, axis=axis),
                           jnp.take(leaf.v_cb, jb, axis=axis)])))
        payload.frozen = frozen
    if tail_rows:
        tail_bid = [used[n_full]]
        payload.tail = [_take_pages(leaf, tail_bid)[:, ..., 0, :tail_rows, :, :]
                        for leaf in leaves]
    tracer.complete("transfer", "extract", t0, mode="resident",
                    pages=payload.n_pages, n_tokens=n_tokens,
                    frozen_pages=len(fidx),
                    fp_equiv_bytes=payload.fp_equiv_bytes)
    return payload


def splice_payload(tree, payload: PagePayload, new_blocks, *,
                   tracer=NULL_TRACER):
    """Land a staged payload in the destination pool at ``new_blocks``
    (sequence page order, already allocated by the caller). Returns the
    updated tree; the caller installs the block-table row."""
    if payload.mode == "splice":
        return tree          # pages already live in this pool
    t0 = tracer.now()
    payload.to_host()
    leaves = collect_leaves(tree)
    # "resident" payloads interleave fp and frozen full pages: the fp
    # arrays cover the positions NOT in frozen_idx, the frozen arrays the
    # rest — other modes are the frozen_idx = all-or-nothing special case
    if payload.mode == "resident":
        fset = set(payload.frozen_idx or ())
        fp_pos = [j for j in range(payload.n_full) if j not in fset]
        fp_full = np.asarray([new_blocks[j] for j in fp_pos], np.int32)
        fro_full = np.asarray([new_blocks[j] for j in sorted(fset)],
                              np.int32)
    else:
        fp_full = fro_full = np.asarray(new_blocks[:payload.n_full],
                                        np.int32)
    out: list[PagedKVCache] = []
    for li, leaf in enumerate(leaves):
        stacked = leaf.k_fp.ndim == 5
        k_fp, v_fp = leaf.k_fp, leaf.v_fp
        if payload.full is not None:
            both = jnp.asarray(payload.full[li])
            sel = (slice(None), fp_full) if stacked else (fp_full,)
            k_fp = k_fp.at[sel].set(both[0])
            v_fp = v_fp.at[sel].set(both[1])
        if payload.tail is not None:
            both = jnp.asarray(payload.tail[li])
            b = int(new_blocks[payload.n_full])
            r = payload.tail_rows
            sel = ((slice(None), b, slice(0, r)) if stacked
                   else (b, slice(0, r)))
            k_fp = k_fp.at[sel].set(both[0])
            v_fp = v_fp.at[sel].set(both[1])
        out.append(dataclasses.replace(leaf, k_fp=k_fp, v_fp=v_fp))
    it = iter(out)
    tree = map_layers(lambda _leaf: next(it), tree)
    if payload.frozen is not None:
        # same install path as in-place freezing: scatters codes/codebooks,
        # flips blk_q, and materializes the reconstruction into the fp rows
        pending = PendingFreeze(
            fro_full, [(jnp.asarray(c), jnp.asarray(cb))
                       for c, cb in payload.frozen])
        tree = install_freeze(tree, pending)
    tracer.complete("transfer", "splice", t0, mode=payload.mode,
                    pages=payload.n_pages, bytes=payload.nbytes,
                    fp_equiv_bytes=payload.fp_equiv_bytes)
    return tree
