"""Speculative decoding, draft side.

``DraftWorker`` mirrors a decode worker's slot geometry on its own small
(unquantized, gather-path) paged pool and proposes ``k`` tokens per verify
step with a reduced draft model. The target-side verify/accept/rollback
lives in ``workers.DecodeWorker._spec_decode_step``; the contract between
the two is the per-slot length invariant

    draft.lens[slot] <= worker.lens[slot]            (always)
    rows 0..draft.lens-1 of the draft cache hold KV of the ACCEPTED
    context only (prompt + emitted tokens)

so a rejected suffix needs no explicit cache surgery on either side:
``sync`` just shrinks ``lens`` to the accepted watermark, and the next
propose call's catch-up window rewrites the stale rows in place (writes
always land contiguously at ``lens``).

Proposing is two jitted shapes regardless of k: one (B, 2) catch-up
window — after a fully-accepted step the draft is exactly one token behind
the target (the last draft's KV plus the bonus token), after a rollback
zero behind, so the pending suffix is never longer than 2 — followed by
k-1 single-token decode steps.

``derive_draft`` builds the default draft: the target model truncated to
its first scanned layer groups (embed / final norm / lm head shared).
Half-depth random-init reduced models greedy-agree with their full-depth
parent on ~90% of positions, which is what makes the acceptance rate (and
the tokens/step win) real without any trained checkpoint; the draft is a
genuine reduced config sharing the target's vocab, not a copy.

Composition with prefix sharing (``prefix_cache``): the draft always
prefills the full prompt on its OWN pool (``attach`` receives the whole
prompt, never a shared-page splice — draft pages are per-slot private), so
target-side page sharing is invisible here. On the target, a shared page
is installed-frozen before it is ever published, and rollback only touches
pages past the accepted watermark — which is always past the shared prompt
prefix — so speculative rollback can never un-freeze or mutate a page
another table references; ``_queue_freeze``'s bid dedupe covers the rest.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.obs.trace import NULL_TRACER

from .kv_cache import (BlockAllocator, init_paged_cache, merge_pools,
                       with_tables)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _draft_prefill(params, toks, tree, *, cfg):
    return models.prefill(params, cfg, {"tokens": toks}, tree)


@functools.partial(jax.jit, static_argnames=("cfg",))
def window_step(params, toks, tree, lens, *, cfg):
    """Jitted multi-token decode window (the verify pass and the draft's
    catch-up/propose steps share this entry; W=1 is a plain decode step)."""
    return models.decode_window(params, cfg, toks, tree, lens)


def derive_draft(params, cfg, *, n_groups: int | None = None):
    """Layer-truncated draft from a target model: keep the first
    ``n_groups`` scanned groups (default: half, at least one) plus the
    shared embed / final norm / head weights. Returns (draft_params,
    draft_cfg) — a real reduced config (half the depth, half the decode
    FLOPs) that shares the target's vocab by construction."""
    assert cfg.family == "lm" and not cfg.head_layers, (
        "derive_draft truncates the scanned groups of a plain decoder LM")
    keep = n_groups if n_groups is not None else max(cfg.n_groups // 2, 1)
    assert 1 <= keep <= cfg.n_groups, (keep, cfg.n_groups)
    draft_cfg = dataclasses.replace(
        cfg, name=f"{cfg.name}-draft{keep}",
        n_layers=keep * len(cfg.group)).validate()
    draft_params = dict(params)
    draft_params["groups"] = jax.tree.map(lambda a: a[:keep],
                                          params["groups"])
    return draft_params, draft_cfg


class DraftWorker:
    """Draft-model mirror of one decode worker: same slot indexing, own
    page pool/allocator/table, fp cache only (draft KV is throwaway)."""

    def __init__(self, params, cfg, *, max_slots: int, block_size: int,
                 max_blocks: int, num_blocks: int | None = None,
                 worker_id: int = 0, tracer=None):
        self.params, self.cfg = params, cfg
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._trk = f"draft/w{worker_id}"
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.num_blocks = (num_blocks if num_blocks is not None
                           else max_slots * max_blocks + 1)
        self.tree = init_paged_cache(
            cfg, num_blocks=self.num_blocks, block_size=block_size,
            batch=max_slots, max_blocks=max_blocks)
        self.alloc = BlockAllocator(self.num_blocks)
        self.table = np.zeros((max_slots, max_blocks), np.int32)
        self.lens = np.zeros((max_slots,), np.int32)   # valid draft KV rows
        self.plen = np.zeros((max_slots,), np.int32)   # prompt length
        self.blocks: list[list[int]] = [[] for _ in range(max_slots)]
        self._prefill_fn = functools.partial(_draft_prefill, cfg=cfg)
        self._window_fn = functools.partial(window_step, cfg=cfg)

    # ------------------------------------------------------------ lifecycle

    def attach(self, slot: int, prompt, n_blocks: int) -> None:
        """Prefill the prompt on the draft model into this slot's pages
        (same worst-case block count as the target side, so the verify
        window's optimistic writes always fit here too)."""
        t0 = self.tracer.now()
        blocks = self.alloc.alloc(n_blocks)
        self.blocks[slot] = blocks
        self.table[slot] = 0
        self.table[slot, :len(blocks)] = blocks
        P = len(prompt)
        ppad = -(-P // self.block_size) * self.block_size
        toks = np.zeros((1, ppad), np.int32)
        toks[0, :P] = prompt
        tbl = np.asarray([blocks[:ppad // self.block_size]], np.int32)
        tree1 = with_tables(self.tree, tbl, np.zeros((1,), np.int32))
        _, new = self._prefill_fn(self.params, jnp.asarray(toks), tree1)
        self.tree = merge_pools(self.tree, new)
        self.lens[slot] = P
        self.plen[slot] = P
        self.tracer.complete(self._trk, "draft_prefill", t0, slot=slot,
                             prompt_len=P)

    def release(self, slot: int) -> None:
        self.alloc.free(self.blocks[slot])
        self.blocks[slot] = []
        self.table[slot] = 0
        self.lens[slot] = 0
        self.plen[slot] = 0

    def sync(self, slot: int, accepted_len: int) -> None:
        """Roll this slot back to the target's accepted watermark. Rows at
        or past it hold rejected drafts' KV; shrinking ``lens`` is the
        whole rollback — the next catch-up window overwrites them."""
        self.lens[slot] = min(int(self.lens[slot]), int(accepted_len))

    # ------------------------------------------------------------ proposing

    def _mb(self, W: int) -> int:
        need = int(self.lens.max()) + W
        return max(1, -(-need // self.block_size))

    def _step(self, toks: np.ndarray) -> np.ndarray:
        W = toks.shape[1]
        tree = with_tables(self.tree, self.table[:, :self._mb(W)], self.lens)
        logits, new = self._window_fn(self.params, jnp.asarray(toks), tree,
                                      jnp.asarray(self.lens))
        self.tree = merge_pools(self.tree, new)
        # lint: sync(draft tokens feed the host-side proposal loop)
        return np.asarray(jnp.argmax(logits, -1))          # (B, W)

    def propose(self, active, slots, k: int) -> dict[int, list[int]]:
        """k draft tokens per active slot, batched across slots.

        ``slots`` is the decode worker's slot list (``out`` carries the
        accepted token history; token j's KV row is ``plen + j``). First a
        fixed (B, 2) catch-up window writes whatever accepted rows this
        cache is missing and yields draft #1, then k-1 single-token steps
        yield the rest. Rows written past a slot's true pending length are
        scratch — contiguous writes at ``lens`` overwrite them before
        ``lens`` ever covers them.
        """
        t0 = self.tracer.now()
        B = self.table.shape[0]
        Wc = 2
        toks = np.zeros((B, Wc), np.int32)
        wlen = np.ones((B,), np.int32)
        for i in active:
            pend = slots[i].out[int(self.lens[i]) - int(self.plen[i]):]
            assert 1 <= len(pend) <= Wc, (len(pend), Wc)
            toks[i, :len(pend)] = pend
            toks[i, len(pend):] = pend[-1]
            wlen[i] = len(pend)
        preds = self._step(toks)
        out: dict[int, list[int]] = {}
        for i in active:
            out[i] = [int(preds[i, wlen[i] - 1])]
            self.lens[i] += int(wlen[i])
        for _ in range(k - 1):
            toks1 = np.zeros((B, 1), np.int32)
            for i in active:
                toks1[i, 0] = out[i][-1]
            preds = self._step(toks1)
            for i in active:
                out[i].append(int(preds[i, 0]))
                self.lens[i] += 1
        self.tracer.complete(self._trk, "draft_propose", t0, k=k,
                             active=len(active))
        return out
