"""Serving subsystem: role-based workers (prefill/decode) over a paged
(codebook-quantized) KV cache, composed either colocated
(ContinuousBatchingEngine) or disaggregated behind a global router with
fp/frozen KV page migration (DisaggEngine). Both engines optionally run
speculative decoding (``speculate=k`` + a reduced draft model — see
``speculative.derive_draft``): k drafted tokens verified per step in one
batched window pass, accept/rollback on the paged cache, greedy
token-identical to plain decoding by construction.

Observability: pass ``tracer=obs.Tracer(...)`` to either engine for a
Perfetto-loadable trace of every component (router, prefill, decode-step
phases, transfer, per-page freeze lifecycle, speculative verify) and
``exporter=obs.MetricsExporter(...)`` for periodic JSONL snapshots; both
default to no-ops (``obs.NULL_TRACER`` / None) with ~zero hot-loop cost.

Overload survival (``overload``): tiered frozen-page host offload
(``HostPageStore`` + "resident" payloads), preempt-and-requeue with a
restore-vs-recompute cost model, and SLO-aware admission
(``SLOAdmission``) shedding/deferring best_effort requests off windowed
itl_p99 + occupancy — wired into both engines via ``offload_pages`` /
``preempt`` / ``admission="slo"``.

Prefix sharing (``prefix_cache=True``, colocated engine): a rolling
token-hash ``PrefixIndex`` over immutable full pages plus per-page
refcounts in ``BlockAllocator`` let sequences with a common prompt prefix
splice the same resident pages (rc+1 per table) instead of re-prefilling
them; the write-hot tail page is materialized privately (copy-on-write),
and a page releases to the free list only when its last reference drops —
the pool-conservation invariant becomes "free list + refcounted live
tables partition the pool"."""
from repro.obs import (FakeClock, MetricsExporter, NULL_TRACER, NullTracer,
                       Tracer)

from .engine import ContinuousBatchingEngine, DisaggEngine
from .kv_cache import (BlockAllocator, DEVICE_FREEZE_METHODS, DoubleFree,
                       PagedKVCache, PoolExhausted, PrefixIndex,
                       freeze_blocks, freeze_markers, init_paged_cache,
                       page_bytes, resolve_kv_spec, thaw_blocks, with_tables)
from .metrics import MetricsCollector, percentile
from .overload import (HostPageStore, OverloadManager, ResumeEntry,
                       SLOAdmission, choose_resume)
from .scheduler import (ContinuousBatchingScheduler, DisaggRouter, Request,
                        SeqState)
from .speculative import DraftWorker, derive_draft
from .transfer import (FinishedPrefill, PagePayload, extract_pages,
                       extract_resident_pages, splice_payload)
from .workers import DecodeWorker, PrefillWorker, sample_token

__all__ = [
    "ContinuousBatchingEngine", "DisaggEngine", "ContinuousBatchingScheduler",
    "DisaggRouter", "Request", "SeqState", "BlockAllocator", "PagedKVCache",
    "PoolExhausted", "DoubleFree", "PrefixIndex",
    "DecodeWorker", "PrefillWorker", "DraftWorker", "derive_draft",
    "FinishedPrefill", "PagePayload",
    "extract_pages", "extract_resident_pages", "splice_payload",
    "sample_token", "init_paged_cache",
    "freeze_blocks", "freeze_markers", "thaw_blocks", "with_tables",
    "page_bytes", "resolve_kv_spec", "DEVICE_FREEZE_METHODS",
    "MetricsCollector", "percentile",
    "HostPageStore", "OverloadManager", "ResumeEntry", "SLOAdmission",
    "choose_resume",
    "Tracer", "NullTracer", "NULL_TRACER", "FakeClock", "MetricsExporter",
]
