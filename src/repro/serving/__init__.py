"""Continuous-batching serving subsystem: scheduler + paged
(codebook-quantized) KV cache + engine + metrics."""
from .engine import ContinuousBatchingEngine
from .kv_cache import (BlockAllocator, PagedKVCache, freeze_blocks,
                       init_paged_cache, page_bytes, thaw_blocks, with_tables)
from .metrics import MetricsCollector, percentile
from .scheduler import ContinuousBatchingScheduler, Request, SeqState

__all__ = [
    "ContinuousBatchingEngine", "ContinuousBatchingScheduler", "Request",
    "SeqState", "BlockAllocator", "PagedKVCache", "init_paged_cache",
    "freeze_blocks", "thaw_blocks", "with_tables", "page_bytes",
    "MetricsCollector", "percentile",
]
