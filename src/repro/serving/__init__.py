"""Continuous-batching serving subsystem: scheduler + paged
(codebook-quantized) KV cache + engine + metrics."""
from .engine import ContinuousBatchingEngine
from .kv_cache import (BlockAllocator, DEVICE_FREEZE_METHODS, PagedKVCache,
                       freeze_blocks, freeze_markers, init_paged_cache,
                       page_bytes, resolve_kv_spec, thaw_blocks, with_tables)
from .metrics import MetricsCollector, percentile
from .scheduler import ContinuousBatchingScheduler, Request, SeqState

__all__ = [
    "ContinuousBatchingEngine", "ContinuousBatchingScheduler", "Request",
    "SeqState", "BlockAllocator", "PagedKVCache", "init_paged_cache",
    "freeze_blocks", "freeze_markers", "thaw_blocks", "with_tables",
    "page_bytes", "resolve_kv_spec", "DEVICE_FREEZE_METHODS",
    "MetricsCollector", "percentile",
]
