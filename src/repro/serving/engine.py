"""Serving engines: thin run loops composed from the role-based workers in
``serving.workers`` (``PrefillWorker``/``DecodeWorker``), the page-handoff
layer in ``serving.transfer``, and the schedulers in ``serving.scheduler``.

``ContinuousBatchingEngine`` is the colocated composition — one decode
worker plus a prefill worker *borrowing its pool*, so prefill runs inline
per admission and the handoff is a no-op page-table splice. Its public
behavior is the original monolithic engine's: iteration-level batching,
FCFS admission, async budgeted page freezing, the clamped gather window.

``DisaggEngine`` is the disaggregated composition — N prefill workers with
their own pools feeding M decode workers through a global ``DisaggRouter``.
Prefill is dispatched asynchronously (a long prompt never blocks a decode
iteration; the worker-ratio N:M is the TTFT/TPOT tradeoff knob), and
finished pages migrate via ``transfer``:

    migrate="fp"      rows cross the handoff at full fp width (baseline)
    migrate="frozen"  full pages cross as packed 4-bit codes + per-block
                      sparse-LSQ codebooks (the paper's solvers via the
                      existing dispatch_freeze path, ~7x fewer bytes) and
                      land directly servable by the fused kernel

``attn_impl`` picks the decode read path: "fused" routes decode steps
through the Pallas paged-attention kernel (frozen pages dequantized in
VMEM), "gather" expands pages to dense K/V in HBM first, "auto" fuses on
TPU and gathers elsewhere (the kernel only interprets off-TPU).

``kv_quant`` is a QuantSpec (object or compact string like "kmeans_ls@16")
validated against the solver registry at construction, so an unfreezable
configuration fails here, naming the device-capable methods, rather than
mid-serve.

``speculate=k`` with ``draft=(params, cfg)`` (see
``serving.speculative.derive_draft``) turns every decode iteration into a
draft-propose / batched-verify / accept-rollback step: k draft tokens per
sequence are scored in ONE k+1-wide target pass against the paged cache,
accepted prefixes advance ``seq_lens`` in place, rejected suffixes roll
back (un-queueing any page-freeze bids past the accepted watermark). The
emitted trace is greedy-token-identical to non-speculative decoding by
construction; acceptance counters land in the metrics summary.

``prefill_chunk=C`` (colocated engine) splits every admitted prompt into
C-token chunks and advances ONE chunk per engine iteration, interleaved
with decode steps for the live batch — a long prompt no longer stalls
decode for its whole prefill, which is what bounds ``itl_max`` under a
long-prompt burst. The chunk's slot and worst-case pages are reserved at
admission (``scheduler.stage``) and the sequence joins the decode batch
only once its whole prompt is in cache; with ``attn_impl="fused"`` each
chunk reads earlier frozen pages as packed codes + codebooks through the
same double-buffered kernel path as decode. The chunk sequence is
logit-identical to a single-shot prefill (bitwise on the gather path), so
``--verify`` replays hold.

Weights flow through ``repro.quant.serve.qmatmul`` untouched: dense params
hit the plain matmul path, PTQ'd QuantizedTensor leaves hit the fused
dequant kernel — the engines are agnostic; each run's summary reports
``qmatmul_dequant_fallback``, the count of traced dense-materialization
fallbacks (0 certifies zero per-call weight dequants).
"""
from __future__ import annotations

import time
from collections import deque

import jax

from repro.core import registry as quant_registry
from repro.obs.trace import NULL_TRACER
from repro.quant.serve import fallback_count

from .kv_cache import resolve_kv_spec
from .metrics import MetricsCollector
from .overload import OverloadManager, SLOAdmission
from .scheduler import DisaggRouter, Request, make_requests
from .workers import DecodeWorker, PrefillWorker


def _make_overload(metrics, *, offload_pages, preempt, admission, itl_slo_s,
                   router=None):
    """Overload machinery shared by both engine compositions: None when
    every overload feature is off (the pre-PR fast path), else an
    ``OverloadManager`` with an SLO policy iff admission == "slo"."""
    assert admission in ("fcfs", "slo"), admission
    if not (offload_pages or preempt or admission == "slo"):
        return None
    policy = (SLOAdmission(metrics, itl_slo_s=itl_slo_s)
              if admission == "slo" else None)
    return OverloadManager(offload_pages=offload_pages, policy=policy,
                           router=router)


def _resolve_attn_impl(attn_impl: str) -> str:
    assert attn_impl in ("auto", "fused", "gather"), attn_impl
    if attn_impl == "auto":
        return "fused" if jax.default_backend() == "tpu" else "gather"
    return attn_impl


class ContinuousBatchingEngine:
    """Colocated serving: decode worker + pool-borrowing prefill worker."""

    def __init__(self, params, cfg, *, max_slots: int = 8,
                 block_size: int = 16, max_seq_len: int = 256,
                 num_blocks: int | None = None, kv_quant: str | None = None,
                 kv_num_values: int | None = None, max_queue: int = 256,
                 eos_id: int | None = None, record_logits: bool = False,
                 attn_impl: str = "auto", freeze_async: bool = True,
                 freeze_page_budget: int = 4, speculate: int = 0,
                 draft: tuple | None = None, prefill_chunk: int | None = None,
                 tracer=None, exporter=None,
                 offload_pages: bool = False, preempt: bool = False,
                 admission: str = "fcfs", itl_slo_s: float | None = None,
                 prefix_cache: bool = False):
        assert cfg.family == "lm", "paged serving drives decoder-only LMs"
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.exporter = exporter
        self.attn_impl = _resolve_attn_impl(attn_impl)
        # fail fast at construction: resolve_kv_spec validates the spec
        # against the solver registry and raises naming the device-capable
        # methods when the configuration can't freeze pages
        self.kv_spec = (None if kv_quant is None else
                        resolve_kv_spec(kv_quant, num_values=kv_num_values))
        self.params, self.cfg = params, cfg
        self.kv_quant = None if self.kv_spec is None else self.kv_spec.method
        self.kv_num_values = (16 if self.kv_spec is None
                              else self.kv_spec.num_values)
        self.record_logits = record_logits
        self.speculate = speculate
        self.metrics = MetricsCollector()
        self.outputs: dict[int, list[int]] = {}
        self.request_logits: dict[int, object] = {}
        self.worker = DecodeWorker(
            params, cfg, max_slots=max_slots, block_size=block_size,
            max_seq_len=max_seq_len, num_blocks=num_blocks,
            kv_spec=self.kv_spec, attn_impl=self.attn_impl,
            freeze_async=freeze_async, freeze_page_budget=freeze_page_budget,
            max_queue=max_queue, eos_id=eos_id, record_logits=record_logits,
            speculate=speculate, draft=draft,
            metrics=self.metrics, outputs=self.outputs,
            request_logits=self.request_logits, tracer=self.tracer,
            roofline_gauges=exporter is not None,
            prefix_cache=prefix_cache)
        # prefill worker inlined into the decode worker's pool: the handoff
        # payload is a no-op "splice" of already-resident block ids
        self.prefill = PrefillWorker(
            params, cfg, block_size=block_size, max_seq_len=max_seq_len,
            kv_spec=self.kv_spec, pool=self.worker,
            record_logits=record_logits, metrics=self.metrics,
            prefill_chunk=prefill_chunk, tracer=self.tracer)
        self.prefill_chunk = prefill_chunk
        # admitted sequences whose prompts are mid-chunk: staged out of the
        # decode batch (slot + pages reserved), one chunk advances per
        # engine iteration, interleaved with decode steps
        self._chunking: deque = deque()
        # fallback watermark: this engine's runs report only their own
        # traced dense-materialization fallbacks, not the process total
        self._fallbacks0 = fallback_count()
        self.block_size = block_size
        self.max_seq_len = self.worker.max_seq_len
        self.freeze_async = self.worker.freeze_async
        self.eos_id = eos_id
        self.preempt = preempt
        self.overload = _make_overload(
            self.metrics, offload_pages=offload_pages, preempt=preempt,
            admission=admission, itl_slo_s=itl_slo_s)

    # ------------------------------------------- legacy attribute surface

    @property
    def tree(self):
        return self.worker.tree

    @tree.setter
    def tree(self, t):
        self.worker.tree = t

    @property
    def alloc(self):
        return self.worker.alloc

    @property
    def sched(self):
        return self.worker.sched

    @property
    def counters(self):
        return self.worker.counters

    @property
    def slots(self):
        return self.worker.slots

    @property
    def num_blocks(self):
        return self.worker.num_blocks

    @property
    def max_blocks(self):
        return self.worker.max_blocks

    @property
    def _pb(self):
        return self.worker._pb

    @property
    def _pending_freezes(self):
        return self.worker._pending_freezes

    # ------------------------------------------------------------ intake

    def submit(self, req: Request, now: float) -> bool:
        if self.speculate and req.temperature > 0.0:
            raise ValueError(
                "speculative decoding serves the greedy (temperature=0) "
                "verification path; submit sampled requests to a "
                "non-speculative engine")
        w = self.worker
        om = self.overload
        if om is not None and om.policy is not None and w.fits(req):
            # SLO door, after the hard never-fits door (a request no pool
            # could hold is a rejection, not a shed): consult windowed
            # itl_p99 + live occupancy, touch only best_effort requests
            occ = 1.0 - w.alloc.num_free / (w.num_blocks - 1)
            verdict = om.policy.decide(req, occupancy=occ)
            if verdict == "shed":
                w.sched.rejected.append(req.id)
                self.metrics.admission("shed_slo")
                self.tracer.instant("router", "reject", rid=req.id,
                                    reason="shed_slo")
                return False
            if verdict == "defer":
                om.deferred.append(req)
                self.metrics.arrival(req.id, now, req.prompt_len)
                self.metrics.admission("deferred")
                self.tracer.instant("router", "defer", rid=req.id,
                                    deferred=len(om.deferred))
                return True
        ok = w.submit(req, now)
        # no router here — the colocated scheduler's admission decision IS
        # the routing decision, so it lands on the same "router" track
        self.tracer.instant("router", "admit" if ok else "reject",
                            rid=req.id)
        return ok

    # ------------------------------------------------------------ run loop

    def run(self, requests: list[Request], *, poll_s: float = 0.002) -> dict:
        """Serve a trace of requests (arrival_time = seconds from start).

        Wall-clock driven: a request becomes visible when the loop's clock
        passes its arrival_time; the loop sleeps only when fully idle.
        """
        w = self.worker
        om = self.overload
        pending = deque(sorted(requests, key=lambda r: (r.arrival_time, r.id)))
        t0 = time.perf_counter()
        now_fn = lambda: time.perf_counter() - t0
        om_work = lambda: om is not None and om.has_work
        while pending or w.sched.has_work or self._chunking or om_work():
            now = now_fn()
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.popleft(), now)
            if not (w.sched.has_work or self._chunking or om_work()):
                if not pending:     # everything left was rejected at submit
                    break
                nxt = pending[0].arrival_time
                time.sleep(min(max(nxt - now, 0.0), poll_s) or poll_s)
                continue
            if om is not None:
                # restore-ahead BEFORE admission: an offloaded sequence
                # re-enters (pages re-installed while its first decode step
                # is still an iteration away) ahead of every queued arrival
                om.retry_deferred(w)
                om.try_restore(w, now_fn)
            # with the prefix cache on, admission charges each request its
            # worst case minus the prompt pages already shareable — the
            # capacity side of sharing (attach splices those pages instead
            # of allocating them, so the discounted need is what prefill
            # actually draws from the pool)
            disc = w.prefix_probe if w.prefix is not None else None
            for st in w.sched.schedule(w.alloc.num_free, discount=disc):
                if self.prefill_chunk:
                    # chunked path: pages allocated now, prompt advances
                    # one chunk per iteration below; the slot stays out of
                    # the decode batch until the whole prompt is in cache
                    self._chunking.append(
                        (st, self.prefill.start_chunked(st.req, now_fn)))
                    w.sched.stage(st)
                else:
                    # inline prefill straight into the decode worker's
                    # pool, then the no-op splice attaches the sequence
                    fin = self.prefill.run_inline(st.req, now_fn)
                    w.attach(st, fin, now_fn())
            if self._chunking:
                # one chunk per iteration (FCFS head), so decode steps for
                # live sequences interleave between chunks of a long prompt
                st, state = self._chunking[0]
                fin = self.prefill.advance_chunk(state, now_fn)
                if fin is not None:
                    self._chunking.popleft()
                    w.sched.activate(st)
                    w.attach(st, fin, now_fn())
            if om is not None and self.preempt:
                om.maybe_preempt(w, now_fn)
            # one batched (budgeted) solve for the pages the prefills (and
            # the previous iteration's decode) just filled, then this
            # iteration's decode step
            w.step(now_fn)
            if self.exporter is not None:
                self.exporter.maybe_emit(self.metrics)
        w.drain()
        if om is not None:
            # host-tier retirement backstop: an entry still demoted when
            # the run ends (its request finished/was cancelled while
            # offloaded, or restore never fired) is reclaimed here so both
            # residency tiers provably drain to empty; its output is
            # whatever it emitted before eviction
            for entry in om.store.entries():
                om.retire(entry.req.id)
                self.outputs.setdefault(entry.req.id, list(entry.out))
        if self.exporter is not None:
            self.exporter.maybe_emit(self.metrics, force=True)
        out = self.metrics.summary()
        # steady-state per-page ratio: what a fully frozen cache saves
        out["page_compression"] = w._pb["fp"] / w._pb["frozen"]
        out["rejected"] = len(w.sched.rejected)
        out["attn_impl"] = self.attn_impl
        out.update(w.counters)
        out["prefill_chunks"] = self.prefill.counters["prefill_chunks"]
        # 0 certifies zero per-call weight dequants this run: every PTQ'd
        # matmul (scanned stacked leaves included) hit a fused kernel
        fallbacks = fallback_count() - self._fallbacks0
        self._fallbacks0 = fallback_count()
        out["qmatmul_dequant_fallback"] = fallbacks
        self.metrics.stats.counter("qmatmul_dequant_fallback").inc(fallbacks)
        if out.get("offload_bytes"):
            # what the frozen-page host tier saved vs demoting at fp width
            out["offload_compression"] = (out["offload_fp_equiv_bytes"]
                                          / out["offload_bytes"])
        # decode-generated tokens per per-sequence decode step (batching
        # factored out): exactly 1.0 for plain decoding, > 1 when
        # speculative verify windows accept drafts
        if out.get("seq_decode_steps"):
            out["tokens_per_step"] = ((out.get("gen_tokens", 0)
                                       - out.get("completed", 0))
                                      / out["seq_decode_steps"])
        out["speculate"] = self.speculate
        return out

    def generate(self, prompts: list[list[int]], max_new_tokens: int,
                 *, temperature: float = 0.0, top_k: int = 0,
                 seed: int | None = None) -> dict:
        """Batch convenience: all requests arrive at t=0; returns outputs
        (None for requests rejected by admission control). Sampling knobs
        apply to every request (per-request seeds derive from ``seed``)."""
        self.run(make_requests(prompts, max_new_tokens,
                               temperature=temperature, top_k=top_k,
                               seed=seed))
        return {i: self.outputs.get(i) for i in range(len(prompts))}


class DisaggEngine:
    """Disaggregated serving: N prefill workers (own pools) feed M decode
    workers through a global router; pages migrate fp or frozen."""

    def __init__(self, params, cfg, *, prefill_workers: int = 1,
                 decode_workers: int = 1, migrate: str = "fp",
                 max_slots: int = 8, block_size: int = 16,
                 max_seq_len: int = 256, num_blocks: int | None = None,
                 prefill_blocks: int | None = None,
                 kv_quant: str | None = None, kv_num_values: int | None = None,
                 max_queue: int = 256, staging_depth: int | None = None,
                 eos_id: int | None = None,
                 record_logits: bool = False, attn_impl: str = "auto",
                 freeze_async: bool = True, freeze_page_budget: int = 4,
                 speculate: int = 0, draft: tuple | None = None,
                 tracer=None, exporter=None,
                 offload_pages: bool = False, preempt: bool = False,
                 admission: str = "fcfs", itl_slo_s: float | None = None):
        assert cfg.family == "lm", "paged serving drives decoder-only LMs"
        assert prefill_workers >= 1 and decode_workers >= 1
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.exporter = exporter
        if migrate not in ("fp", "frozen"):
            raise ValueError(f"migrate must be 'fp' or 'frozen', got "
                             f"{migrate!r}")
        self.attn_impl = _resolve_attn_impl(attn_impl)
        self.kv_spec = (None if kv_quant is None else
                        resolve_kv_spec(kv_quant, num_values=kv_num_values))
        if migrate == "frozen":
            if self.kv_spec is None:
                raise ValueError(
                    "migrate='frozen' ships pages as codes+codebooks and "
                    "needs a kv_quant spec (e.g. kmeans_ls@16)")
            if not self.kv_spec.device_capable:
                raise ValueError(
                    f"migrate='frozen' routes pages through the batched "
                    f"device freeze path; {self.kv_spec.method} has no "
                    f"device solver — use one of: "
                    f"{', '.join(quant_registry.device_methods())}")
        self.params, self.cfg = params, cfg
        self.migrate = migrate
        self.kv_quant = None if self.kv_spec is None else self.kv_spec.method
        self.kv_num_values = (16 if self.kv_spec is None
                              else self.kv_spec.num_values)
        self.record_logits = record_logits
        self.speculate = speculate
        self.metrics = MetricsCollector()
        self.outputs: dict[int, list[int]] = {}
        self.request_logits: dict[int, object] = {}
        self.decode = [DecodeWorker(
            params, cfg, worker_id=i, max_slots=max_slots,
            block_size=block_size, max_seq_len=max_seq_len,
            num_blocks=num_blocks, kv_spec=self.kv_spec,
            attn_impl=self.attn_impl, freeze_async=freeze_async,
            freeze_page_budget=freeze_page_budget, eos_id=eos_id,
            record_logits=record_logits, speculate=speculate, draft=draft,
            metrics=self.metrics,
            outputs=self.outputs, request_logits=self.request_logits,
            tracer=self.tracer, roofline_gauges=exporter is not None)
            for i in range(decode_workers)]
        self.prefills = [PrefillWorker(
            params, cfg, worker_id=i, block_size=block_size,
            max_seq_len=max_seq_len, kv_spec=self.kv_spec, migrate=migrate,
            num_blocks=prefill_blocks, record_logits=record_logits,
            metrics=self.metrics, tracer=self.tracer)
            for i in range(prefill_workers)]
        self.router = DisaggRouter(max_queue=max_queue,
                                   staging_depth=staging_depth,
                                   tracer=self.tracer)
        self._fallbacks0 = fallback_count()
        self.block_size = block_size
        self.max_seq_len = self.decode[0].max_seq_len
        self.freeze_async = self.decode[0].freeze_async
        self.eos_id = eos_id
        self.preempt = preempt
        self.overload = _make_overload(
            self.metrics, offload_pages=offload_pages, preempt=preempt,
            admission=admission, itl_slo_s=itl_slo_s, router=self.router)

    # ------------------------------------------------------------ intake

    def submit(self, req: Request, now: float) -> bool:
        if self.speculate and req.temperature > 0.0:
            raise ValueError(
                "speculative decoding serves the greedy (temperature=0) "
                "verification path; submit sampled requests to a "
                "non-speculative engine")
        d0, p0 = self.decode[0], self.prefills[0]
        if (req.prompt_len + req.max_new_tokens + self.speculate
                > self.max_seq_len
                or d0.sched.blocks_for(req) > d0.num_blocks - 1
                or -(-req.prompt_len // self.block_size)
                > p0.num_blocks - 1):
            # reject what no worker can ever hold — staging it would
            # head-of-line-block the router's queues forever
            self.router.rejected.append(req.id)
            self.metrics.admission("rejected_pool_full")
            self.tracer.instant("router", "reject", rid=req.id,
                                reason="never_fits")
            return False
        om = self.overload
        if om is not None and om.policy is not None:
            # the request may land on any decode worker, so gate on the
            # least-loaded one's occupancy
            occ = min(1.0 - d.alloc.num_free / (d.num_blocks - 1)
                      for d in self.decode)
            verdict = om.policy.decide(req, occupancy=occ)
            if verdict == "shed":
                self.router.rejected.append(req.id)
                self.metrics.admission("shed_slo")
                self.tracer.instant("router", "reject", rid=req.id,
                                    reason="shed_slo")
                return False
            if verdict == "defer":
                om.deferred.append(req)
                self.metrics.arrival(req.id, now, req.prompt_len)
                self.metrics.admission("deferred")
                self.tracer.instant("router", "defer", rid=req.id,
                                    deferred=len(om.deferred))
                return True
        ok = self.router.submit(req)
        if ok:
            self.metrics.arrival(req.id, now, req.prompt_len)
        else:
            self.metrics.admission("rejected_queue_full")
        return ok

    # ------------------------------------------------------------ run loop

    @property
    def _has_work(self) -> bool:
        return (self.router.has_work or any(p.busy for p in self.prefills)
                or any(d.sched.has_work or d.has_work for d in self.decode)
                or (self.overload is not None and self.overload.has_work))

    def run(self, requests: list[Request], *, poll_s: float = 0.002) -> dict:
        """Serve a trace of requests (arrival_time = seconds from start).

        One loop iteration: route waiting requests onto prefill workers,
        advance each prefill worker (async — dispatch or harvest), place
        finished prefills onto decode workers, then one decode step per
        decode worker with live sequences. Decode never waits on a prefill:
        a burst of long prompts costs each iteration at most the prefill
        workers' dispatch overhead, which is the TPOT-isolation property
        the worker split buys.
        """
        pending = deque(sorted(requests, key=lambda r: (r.arrival_time, r.id)))
        t0 = time.perf_counter()
        now_fn = lambda: time.perf_counter() - t0
        while pending or self._has_work:
            now = now_fn()
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.popleft(), now)
            if not self._has_work:
                if not pending:     # everything left was rejected at submit
                    break
                nxt = pending[0].arrival_time
                time.sleep(min(max(nxt - now, 0.0), poll_s) or poll_s)
                continue
            progressed = False
            om = self.overload
            if om is not None:
                # restore-ahead: offloaded sequences re-enter (onto any
                # decode worker with capacity — payloads are portable)
                # before staged prefills or queued arrivals take the space
                om.retry_deferred(max(self.decode,
                                      key=lambda d: d.alloc.num_free))
                for dw in self.decode:
                    progressed |= bool(om.try_restore(dw, now_fn))
            self.router.route_prefill(self.prefills)
            for pw in self.prefills:
                for fin in pw.step(now_fn):
                    self.router.stage(fin)
                    progressed = True
            def _place(dw, fin):
                st = dw.sched.admit_direct(fin.req)
                assert st is not None       # router checked can_accept
                dw.attach(st, fin, now_fn())
            progressed |= bool(self.router.route_decode(self.decode, _place))
            if om is not None and self.preempt:
                for dw in self.decode:
                    progressed |= om.maybe_preempt(dw, now_fn)
            for dw in self.decode:
                if dw.has_work:
                    dw.step(now_fn)
                    progressed = progressed or bool(dw.sched.active)
            if self.exporter is not None:
                self.exporter.maybe_emit(self.metrics)
            if not progressed:
                # only in-flight prefills to wait on: let the device work
                time.sleep(poll_s / 4)
        for pw in self.prefills:
            assert not pw.busy
        for dw in self.decode:
            dw.drain()
        if self.overload is not None:
            # same host-tier retirement backstop as the colocated engine
            for entry in self.overload.store.entries():
                self.overload.retire(entry.req.id)
                self.outputs.setdefault(entry.req.id, list(entry.out))
        if self.exporter is not None:
            self.exporter.maybe_emit(self.metrics, force=True)
        return self._summary()

    def _summary(self) -> dict:
        out = self.metrics.summary()
        agg = {}
        for dw in self.decode:
            for k, v in dw.counters.items():
                agg[k] = max(agg.get(k, 0), v) if k == "max_gather_blocks" \
                    else agg.get(k, 0) + v
        out.update(agg)
        out["prefills_done"] = sum(p.counters["prefills"]
                                   for p in self.prefills)
        fallbacks = fallback_count() - self._fallbacks0
        self._fallbacks0 = fallback_count()
        out["qmatmul_dequant_fallback"] = fallbacks
        self.metrics.stats.counter("qmatmul_dequant_fallback").inc(fallbacks)
        out["rejected"] = len(self.router.rejected)
        out["attn_impl"] = self.attn_impl
        out["migrate"] = self.migrate
        if agg.get("seq_decode_steps"):
            out["tokens_per_step"] = ((out.get("gen_tokens", 0)
                                       - out.get("completed", 0))
                                      / agg["seq_decode_steps"])
        out["speculate"] = self.speculate
        out["prefill_workers"] = len(self.prefills)
        out["decode_workers"] = len(self.decode)
        pb = self.decode[0]._pb
        out["page_compression"] = pb["fp"] / pb["frozen"]
        out["migrate_compression"] = (
            out["migrate_fp_equiv_bytes"] / out["migrate_bytes"]
            if out.get("migrate_bytes") else 1.0)
        if out.get("offload_bytes"):
            out["offload_compression"] = (out["offload_fp_equiv_bytes"]
                                          / out["offload_bytes"])
        return out

    def generate(self, prompts: list[list[int]], max_new_tokens: int,
                 *, temperature: float = 0.0, top_k: int = 0,
                 seed: int | None = None) -> dict:
        """Batch convenience mirroring the colocated engine's."""
        self.run(make_requests(prompts, max_new_tokens,
                               temperature=temperature, top_k=top_k,
                               seed=seed))
        return {i: self.outputs.get(i) for i in range(len(prompts))}
