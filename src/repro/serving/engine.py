"""Continuous-batching serving engine over the paged (optionally
codebook-quantized) KV cache.

One engine iteration = admit new prefills (they join the in-flight batch),
one fused decode step over every active slot, freeze any page that just
filled (batched on-device sparse-LSQ quantization, dispatched async so
decode keeps running while it completes), evict finished sequences and
recycle their pages. The decode batch is a fixed (max_slots, 1) token
shape; the gathered KV window is clamped to the blocks the longest live
sequence needs (bounded retraces, one per distinct block count), so short
batches parked next to idle slots don't pay ``max_blocks`` bandwidth.
Idle slots write to the null page and their logits are ignored. Prefill
runs per-request at block-rounded lengths — the new sequence decodes
together with the rest of the batch in the same iteration, which is
iteration-level (continuous) batching.

``attn_impl`` picks the decode read path: "fused" routes every decode step
through the Pallas paged-attention kernel (frozen pages dequantized in
VMEM), "gather" expands pages to dense K/V in HBM first, "auto" fuses on
TPU and gathers elsewhere (the kernel only interprets off-TPU).

``kv_quant`` is a QuantSpec (object or compact string like "kmeans_ls@16"
or "iter_l1@16"; legacy bare method + ``kv_num_values`` still resolves) —
validated against the solver registry at construction, so an unfreezable
configuration fails here, naming the device-capable methods, rather than
mid-serve.

Weights flow through ``repro.quant.serve.qmatmul`` untouched: dense params
hit the plain matmul path, PTQ'd QuantizedTensor leaves would hit the fused
dequant kernel — the engine is agnostic.
"""
from __future__ import annotations

import functools
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from .kv_cache import (BlockAllocator, dispatch_freeze, freeze_blocks,
                       init_paged_cache, install_freeze, merge_pools,
                       page_bytes, resolve_kv_spec, thaw_blocks, with_tables)
from .metrics import MetricsCollector
from .scheduler import ContinuousBatchingScheduler, Request, SeqState


@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill_step(params, toks, tree, *, cfg):
    return models.prefill(params, cfg, {"tokens": toks}, tree)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _decode_step_fn(params, toks, tree, lens, *, cfg):
    return models.decode_step(params, cfg, toks, tree, lens)


class _Slot:
    """Engine-side per-slot state (token io + page bookkeeping)."""

    def __init__(self):
        self.rid = None
        self.blocks: list[int] = []
        self.frozen_upto = 0          # block-table slots already quantized
        self.last_token = 0
        self.out: list[int] = []
        self.logits: list[np.ndarray] = []


class ContinuousBatchingEngine:
    def __init__(self, params, cfg, *, max_slots: int = 8,
                 block_size: int = 16, max_seq_len: int = 256,
                 num_blocks: int | None = None, kv_quant: str | None = None,
                 kv_num_values: int | None = None, max_queue: int = 256,
                 eos_id: int | None = None, record_logits: bool = False,
                 attn_impl: str = "auto", freeze_async: bool = True):
        assert cfg.family == "lm", "paged serving drives decoder-only LMs"
        assert attn_impl in ("auto", "fused", "gather"), attn_impl
        if attn_impl == "auto":
            attn_impl = "fused" if jax.default_backend() == "tpu" else "gather"
        self.attn_impl = attn_impl
        # fail fast at construction: resolve_kv_spec validates the spec
        # against the solver registry and raises naming the device-capable
        # methods when the configuration can't freeze pages
        self.kv_spec = (None if kv_quant is None else
                        resolve_kv_spec(kv_quant, num_values=kv_num_values))
        self.params, self.cfg = params, cfg
        self.block_size = block_size
        self.max_blocks = -(-max_seq_len // block_size)
        self.max_seq_len = self.max_blocks * block_size
        self.num_blocks = (num_blocks if num_blocks is not None
                           else max_slots * self.max_blocks + 1)
        self.kv_quant = None if self.kv_spec is None else self.kv_spec.method
        self.kv_num_values = (16 if self.kv_spec is None
                              else self.kv_spec.num_values)
        # async freezing: dispatch the device solve, keep serving the exact
        # fp page until the result is ready, then install. Sync freezing
        # installs at dispatch (deterministic step at which codes take
        # over — what logit-replay verification wants).
        self.freeze_async = (freeze_async and self.kv_spec is not None
                             and self.kv_spec.device_capable)
        self.eos_id = eos_id
        self.record_logits = record_logits

        self.tree = init_paged_cache(
            cfg, num_blocks=self.num_blocks, block_size=block_size,
            batch=max_slots, max_blocks=self.max_blocks,
            quantized=self.kv_spec is not None,
            num_values=self.kv_num_values, fused=attn_impl == "fused")
        self.alloc = BlockAllocator(self.num_blocks)
        self.sched = ContinuousBatchingScheduler(
            max_slots=max_slots, block_size=block_size, max_queue=max_queue)
        self.metrics = MetricsCollector()
        self.table = np.zeros((max_slots, self.max_blocks), np.int32)
        self.lens = np.zeros((max_slots,), np.int32)
        self.slots = [_Slot() for _ in range(max_slots)]
        self.outputs: dict[int, list[int]] = {}
        self.request_logits: dict[int, np.ndarray] = {}
        self._pb = page_bytes(cfg, block_size,
                              quantized=self.kv_spec is not None,
                              num_values=self.kv_num_values)
        # freeze/decode overlap accounting: freezes dispatch async to the
        # device and install once ready (_poll_freezes); until then frozen
        # pages serve fp, so decode has no data dependency on the solve.
        # host_page_solves counts fallback per-page numpy solves (0 in the
        # kmeans_ls steady state).
        self.counters = {"freeze_dispatches": 0, "freeze_installs": 0,
                         "host_page_solves": 0, "decode_steps": 0,
                         "freeze_inflight_steps": 0, "freeze_overlap_steps": 0,
                         "freeze_pending_max": 0, "max_gather_blocks": 0}
        self._pending_freezes: list[tuple[int, object]] = []
        self._freeze_bids: list[int] = []   # queued for the next flush
        self._frozen_pages: set[int] = set()   # installed (codes serving)

        # module-level jits keyed on the (hashable) config: engines of the
        # same geometry share compiles instead of retracing per instance
        self._prefill_fn = functools.partial(_prefill_step, cfg=cfg)
        self._decode_fn = functools.partial(_decode_step_fn, cfg=cfg)

    # ------------------------------------------------------------ intake

    def submit(self, req: Request, now: float) -> bool:
        if (req.prompt_len + req.max_new_tokens > self.max_seq_len
                or self.sched.blocks_for(req) > self.num_blocks - 1):
            # reject what can never fit (seq budget or whole page pool) —
            # admitting it would head-of-line-block the queue forever
            self.sched.rejected.append(req.id)
            return False
        ok = self.sched.submit(req)
        if ok:
            self.metrics.arrival(req.id, now, req.prompt_len)
        return ok

    # ------------------------------------------------------------ steps

    def _do_prefill(self, st: SeqState, now_fn) -> None:
        req, slot = st.req, st.slot
        blocks = self.alloc.alloc(self.sched.blocks_for(req))
        s = self.slots[slot]
        s.rid, s.blocks, s.frozen_upto = req.id, blocks, 0
        s.out, s.logits = [], []
        self.table[slot] = 0
        self.table[slot, :len(blocks)] = blocks
        self.lens[slot] = 0

        P = req.prompt_len
        ppad = -(-P // self.block_size) * self.block_size
        toks = np.zeros((1, ppad), np.int32)
        toks[0, :P] = req.prompt
        # clamp the table to the blocks this prompt actually writes/reads
        tree1 = with_tables(self.tree,
                            self.table[slot:slot + 1, :ppad // self.block_size],
                            np.zeros((1,), np.int32))
        logits, new1 = self._prefill_fn(self.params, jnp.asarray(toks), tree1)
        self.tree = merge_pools(self.tree, new1)
        self.lens[slot] = P
        st.length, st.generated = P, 1
        last = np.asarray(logits[0, P - 1])     # materializes the prefill
        now = now_fn()                          # TTFT includes prefill time
        s.last_token = int(np.argmax(last))
        s.out.append(s.last_token)
        if self.record_logits:
            s.logits.append(last)
        self.metrics.first_token(req.id, now)
        self._freeze(slot)
        if st.done or s.last_token == self.eos_id:
            self._finish(st, now)

    def _decode_step(self, now_fn) -> None:
        active = self.sched.active_slots()
        if not active:
            return
        self.counters["decode_steps"] += 1
        self._poll_freezes()
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].last_token
        # gather only the blocks the longest live sequence needs this step
        # (idle slots sit at length 0); retraces are bounded by max_blocks
        need = int(self.lens.max()) + 1
        mb_used = max(1, -(-need // self.block_size))
        self.counters["max_gather_blocks"] = max(
            self.counters["max_gather_blocks"], mb_used)
        tree = with_tables(self.tree, self.table[:, :mb_used], self.lens)
        lens = jnp.asarray(self.lens)
        logits, new = self._decode_fn(self.params, jnp.asarray(toks), tree,
                                      lens)
        self.tree = merge_pools(self.tree, new)
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        full = np.asarray(logits[:, -1]) if self.record_logits else None
        now = now_fn()
        finished = []
        for i in active:
            st = self.sched.active[i]
            s = self.slots[i]
            self.lens[i] += 1
            st.length += 1
            st.generated += 1
            s.last_token = int(nxt[i])
            s.out.append(s.last_token)
            if full is not None:
                s.logits.append(full[i])
            self.metrics.token(st.req.id)
            self._freeze(i)
            if st.done or s.last_token == self.eos_id:
                finished.append(st)
        for st in finished:
            self._finish(st, now)

    def _poll_freezes(self, drain: bool = False) -> None:
        """Install completed freezes; count the ones still overlapping this
        decode step. drain=True blocks on the remainder (end of run)."""
        still = []
        for step0, pending in self._pending_freezes:
            if drain and not pending.is_ready():
                jax.block_until_ready(pending.markers())
            if pending.is_ready():
                self.tree = install_freeze(self.tree, pending)
                self._frozen_pages.update(
                    int(b) for b in pending.bids[pending.keep])
                self.counters["freeze_installs"] += 1
                self.counters["freeze_overlap_steps"] += (
                    self.counters["decode_steps"] - step0)
            else:
                self.counters["freeze_inflight_steps"] += 1
                still.append((step0, pending))
        self._pending_freezes = still

    def _freeze(self, slot: int) -> None:
        """Queue this sequence's just-filled pages for quantization; the
        engine iteration flushes the whole batch as ONE device dispatch
        (_flush_freezes), so slots whose pages fill at the same step share
        a solve."""
        if self.kv_quant is None:
            return
        s = self.slots[slot]
        full = int(self.lens[slot]) // self.block_size
        if full > s.frozen_upto:
            self._freeze_bids.extend(int(self.table[slot, j])
                                     for j in range(s.frozen_upto, full))
            s.frozen_upto = full

    def _flush_freezes(self) -> None:
        """One batched solve for every page queued this iteration.

        kmeans_ls/kmeans solve on device; with freeze_async the dispatch
        returns as soon as the work is enqueued and the pages keep serving
        fp until _poll_freezes installs the codes — decode steps in between
        carry no data dependency on the solve."""
        if not self._freeze_bids:
            return
        # cap pages per flush: a prefill burst's worth of pages solved as
        # one chunk would run long enough to delay the next decode steps;
        # the remainder flushes next iteration (pages serve exact fp until
        # then, so correctness is unaffected)
        take = min(len(self._freeze_bids), 4)
        bids, self._freeze_bids = (self._freeze_bids[:take],
                                   self._freeze_bids[take:])
        if self.kv_spec.device_capable:
            # pad to a power-of-two page count (repeating one page is a
            # no-op at install) so the jitted solver compiles a handful of
            # shapes instead of one per distinct flush size; the host
            # fallback solves per page, where a duplicate is pure waste
            bucket = 1 << (len(bids) - 1).bit_length()
            bids = bids + [bids[-1]] * (bucket - len(bids))
        if self.freeze_async:
            pending = dispatch_freeze(self.tree, bids, self.kv_spec)
            self._pending_freezes.append(
                (self.counters["decode_steps"], pending))
            self.counters["freeze_pending_max"] = max(
                self.counters["freeze_pending_max"],
                len(self._pending_freezes))
        else:
            self.tree = freeze_blocks(self.tree, bids, self.kv_spec,
                                      stats=self.counters)
            self._frozen_pages.update(bids)
            self.counters["freeze_installs"] += 1
        self.counters["freeze_dispatches"] += 1

    def _finish(self, st: SeqState, now: float) -> None:
        slot, s = st.slot, self.slots[st.slot]
        self.outputs[st.req.id] = list(s.out)
        if self.record_logits and s.logits:
            self.request_logits[st.req.id] = np.stack(s.logits)
        self.metrics.finish(st.req.id, now)
        # freed pages may be reallocated before an in-flight solve lands —
        # forget them (queued or dispatched) so a stale install can't mark
        # a reused page frozen
        freed = set(s.blocks)
        self._freeze_bids = [b for b in self._freeze_bids if b not in freed]
        self._frozen_pages -= freed
        for _, pending in self._pending_freezes:
            pending.drop(s.blocks)
        self.tree = thaw_blocks(self.tree, s.blocks)
        self.alloc.free(s.blocks)
        self.table[slot] = 0
        self.lens[slot] = 0
        s.rid, s.blocks, s.frozen_upto, s.out = None, [], 0, []
        self.sched.release(st)

    def _sample_cache(self) -> None:
        allocated = (self.num_blocks - 1) - self.alloc.num_free
        # count *installed* pages: queued/in-flight solves still serve fp
        # at full width, so they must not book frozen-page bytes yet
        frozen = len(self._frozen_pages)
        actual = (frozen * self._pb["frozen"]
                  + (allocated - frozen) * self._pb["fp"])
        self.metrics.sample_cache(allocated / (self.num_blocks - 1),
                                  actual, allocated * self._pb["fp"])

    # ------------------------------------------------------------ run loop

    def run(self, requests: list[Request], *, poll_s: float = 0.002) -> dict:
        """Serve a trace of requests (arrival_time = seconds from start).

        Wall-clock driven: a request becomes visible when the loop's clock
        passes its arrival_time; the loop sleeps only when fully idle.
        """
        pending = deque(sorted(requests, key=lambda r: (r.arrival_time, r.id)))
        t0 = time.perf_counter()
        now_fn = lambda: time.perf_counter() - t0
        while pending or self.sched.has_work:
            now = now_fn()
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.popleft(), now)
            if not self.sched.has_work:
                if not pending:     # everything left was rejected at submit
                    break
                nxt = pending[0].arrival_time
                time.sleep(min(max(nxt - now, 0.0), poll_s) or poll_s)
                continue
            for st in self.sched.schedule(self.alloc.num_free):
                self._do_prefill(st, now_fn)
            # one batched solve for the pages the prefills (and the
            # previous iteration's decode) just filled, before this
            # iteration's decode reads them
            self._flush_freezes()
            self._decode_step(now_fn)
            self._sample_cache()
        self._flush_freezes()
        self._poll_freezes(drain=True)      # land any still-computing solves
        out = self.metrics.summary()
        # steady-state per-page ratio: what a fully frozen cache saves
        out["page_compression"] = self._pb["fp"] / self._pb["frozen"]
        out["rejected"] = len(self.sched.rejected)
        out["attn_impl"] = self.attn_impl
        out.update(self.counters)
        return out

    def generate(self, prompts: list[list[int]], max_new_tokens: int) -> dict:
        """Batch convenience: all requests arrive at t=0; returns outputs
        (None for requests rejected by admission control)."""
        reqs = [Request(id=i, prompt=tuple(p), max_new_tokens=max_new_tokens)
                for i, p in enumerate(prompts)]
        self.run(reqs)
        return {i: self.outputs.get(i) for i in range(len(prompts))}
