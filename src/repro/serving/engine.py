"""Continuous-batching serving engine over the paged (optionally
codebook-quantized) KV cache.

One engine iteration = admit new prefills (they join the in-flight batch),
one fused decode step over every active slot, freeze any page that just
filled (host-side sparse-LSQ quantization), evict finished sequences and
recycle their pages. The decode batch is a fixed (max_slots, 1) shape so
the jitted step compiles once; idle slots write to the null page and their
logits are ignored. Prefill runs per-request at block-rounded lengths
(bounded retraces) — the new sequence decodes together with the rest of
the batch in the same iteration, which is iteration-level (continuous)
batching.

Weights flow through ``repro.quant.serve.qmatmul`` untouched: dense params
hit the plain matmul path, PTQ'd QuantizedTensor leaves would hit the fused
dequant kernel — the engine is agnostic.
"""
from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from .kv_cache import (BlockAllocator, freeze_blocks, init_paged_cache,
                       merge_pools, page_bytes, thaw_blocks, with_tables)
from .metrics import MetricsCollector
from .scheduler import ContinuousBatchingScheduler, Request, SeqState


class _Slot:
    """Engine-side per-slot state (token io + page bookkeeping)."""

    def __init__(self):
        self.rid = None
        self.blocks: list[int] = []
        self.frozen_upto = 0          # block-table slots already quantized
        self.last_token = 0
        self.out: list[int] = []
        self.logits: list[np.ndarray] = []


class ContinuousBatchingEngine:
    def __init__(self, params, cfg, *, max_slots: int = 8,
                 block_size: int = 16, max_seq_len: int = 256,
                 num_blocks: int | None = None, kv_quant: str | None = None,
                 kv_num_values: int = 16, max_queue: int = 256,
                 eos_id: int | None = None, record_logits: bool = False):
        assert cfg.family == "lm", "paged serving drives decoder-only LMs"
        if kv_quant is not None:
            from repro.core import COUNT_METHODS

            allowed = set(COUNT_METHODS) | {"tv"}
            if kv_quant not in allowed:
                raise ValueError(f"kv_quant {kv_quant!r}: need a "
                                 f"count-parameterised method, one of "
                                 f"{sorted(allowed)}")
        self.params, self.cfg = params, cfg
        self.block_size = block_size
        self.max_blocks = -(-max_seq_len // block_size)
        self.max_seq_len = self.max_blocks * block_size
        self.num_blocks = (num_blocks if num_blocks is not None
                           else max_slots * self.max_blocks + 1)
        self.kv_quant = kv_quant
        self.kv_num_values = kv_num_values
        self.eos_id = eos_id
        self.record_logits = record_logits

        self.tree = init_paged_cache(
            cfg, num_blocks=self.num_blocks, block_size=block_size,
            batch=max_slots, max_blocks=self.max_blocks,
            quantized=kv_quant is not None, num_values=kv_num_values)
        self.alloc = BlockAllocator(self.num_blocks)
        self.sched = ContinuousBatchingScheduler(
            max_slots=max_slots, block_size=block_size, max_queue=max_queue)
        self.metrics = MetricsCollector()
        self.table = np.zeros((max_slots, self.max_blocks), np.int32)
        self.lens = np.zeros((max_slots,), np.int32)
        self.slots = [_Slot() for _ in range(max_slots)]
        self.outputs: dict[int, list[int]] = {}
        self.request_logits: dict[int, np.ndarray] = {}
        self._pb = page_bytes(cfg, block_size, quantized=kv_quant is not None,
                              num_values=kv_num_values)

        self._prefill_fn = jax.jit(
            lambda p, toks, tree: models.prefill(p, cfg, {"tokens": toks},
                                                 tree))
        self._decode_fn = jax.jit(
            lambda p, toks, tree, lens: models.decode_step(p, cfg, toks,
                                                           tree, lens))

    # ------------------------------------------------------------ intake

    def submit(self, req: Request, now: float) -> bool:
        if (req.prompt_len + req.max_new_tokens > self.max_seq_len
                or self.sched.blocks_for(req) > self.num_blocks - 1):
            # reject what can never fit (seq budget or whole page pool) —
            # admitting it would head-of-line-block the queue forever
            self.sched.rejected.append(req.id)
            return False
        ok = self.sched.submit(req)
        if ok:
            self.metrics.arrival(req.id, now, req.prompt_len)
        return ok

    # ------------------------------------------------------------ steps

    def _do_prefill(self, st: SeqState, now_fn) -> None:
        req, slot = st.req, st.slot
        blocks = self.alloc.alloc(self.sched.blocks_for(req))
        s = self.slots[slot]
        s.rid, s.blocks, s.frozen_upto = req.id, blocks, 0
        s.out, s.logits = [], []
        self.table[slot] = 0
        self.table[slot, :len(blocks)] = blocks
        self.lens[slot] = 0

        P = req.prompt_len
        ppad = -(-P // self.block_size) * self.block_size
        toks = np.zeros((1, ppad), np.int32)
        toks[0, :P] = req.prompt
        tree1 = with_tables(self.tree, self.table[slot:slot + 1],
                            np.zeros((1,), np.int32))
        logits, new1 = self._prefill_fn(self.params, jnp.asarray(toks), tree1)
        self.tree = merge_pools(self.tree, new1)
        self.lens[slot] = P
        st.length, st.generated = P, 1
        last = np.asarray(logits[0, P - 1])     # materializes the prefill
        now = now_fn()                          # TTFT includes prefill time
        s.last_token = int(np.argmax(last))
        s.out.append(s.last_token)
        if self.record_logits:
            s.logits.append(last)
        self.metrics.first_token(req.id, now)
        self._freeze(slot)
        if st.done or s.last_token == self.eos_id:
            self._finish(st, now)

    def _decode_step(self, now_fn) -> None:
        active = self.sched.active_slots()
        if not active:
            return
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].last_token
        tree = with_tables(self.tree, self.table, self.lens)
        lens = jnp.asarray(self.lens)
        logits, new = self._decode_fn(self.params, jnp.asarray(toks), tree,
                                      lens)
        self.tree = merge_pools(self.tree, new)
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        full = np.asarray(logits[:, -1]) if self.record_logits else None
        now = now_fn()
        finished = []
        for i in active:
            st = self.sched.active[i]
            s = self.slots[i]
            self.lens[i] += 1
            st.length += 1
            st.generated += 1
            s.last_token = int(nxt[i])
            s.out.append(s.last_token)
            if full is not None:
                s.logits.append(full[i])
            self.metrics.token(st.req.id)
            self._freeze(i)
            if st.done or s.last_token == self.eos_id:
                finished.append(st)
        for st in finished:
            self._finish(st, now)

    def _freeze(self, slot: int) -> None:
        """Quantize pages of this sequence that just became full."""
        if self.kv_quant is None:
            return
        s = self.slots[slot]
        full = int(self.lens[slot]) // self.block_size
        if full > s.frozen_upto:
            bids = [int(self.table[slot, j])
                    for j in range(s.frozen_upto, full)]
            self.tree = freeze_blocks(self.tree, bids, method=self.kv_quant,
                                      num_values=self.kv_num_values)
            s.frozen_upto = full

    def _finish(self, st: SeqState, now: float) -> None:
        slot, s = st.slot, self.slots[st.slot]
        self.outputs[st.req.id] = list(s.out)
        if self.record_logits and s.logits:
            self.request_logits[st.req.id] = np.stack(s.logits)
        self.metrics.finish(st.req.id, now)
        self.tree = thaw_blocks(self.tree, s.blocks)
        self.alloc.free(s.blocks)
        self.table[slot] = 0
        self.lens[slot] = 0
        s.rid, s.blocks, s.frozen_upto, s.out = None, [], 0, []
        self.sched.release(st)

    def _sample_cache(self) -> None:
        allocated = (self.num_blocks - 1) - self.alloc.num_free
        frozen = sum(s.frozen_upto for s in self.slots)
        actual = (frozen * self._pb["frozen"]
                  + (allocated - frozen) * self._pb["fp"])
        self.metrics.sample_cache(allocated / (self.num_blocks - 1),
                                  actual, allocated * self._pb["fp"])

    # ------------------------------------------------------------ run loop

    def run(self, requests: list[Request], *, poll_s: float = 0.002) -> dict:
        """Serve a trace of requests (arrival_time = seconds from start).

        Wall-clock driven: a request becomes visible when the loop's clock
        passes its arrival_time; the loop sleeps only when fully idle.
        """
        pending = deque(sorted(requests, key=lambda r: (r.arrival_time, r.id)))
        t0 = time.perf_counter()
        now_fn = lambda: time.perf_counter() - t0
        while pending or self.sched.has_work:
            now = now_fn()
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.popleft(), now)
            if not self.sched.has_work:
                if not pending:     # everything left was rejected at submit
                    break
                nxt = pending[0].arrival_time
                time.sleep(min(max(nxt - now, 0.0), poll_s) or poll_s)
                continue
            for st in self.sched.schedule(self.alloc.num_free):
                self._do_prefill(st, now_fn)
            self._decode_step(now_fn)
            self._sample_cache()
        out = self.metrics.summary()
        # steady-state per-page ratio: what a fully frozen cache saves
        out["page_compression"] = self._pb["fp"] / self._pb["frozen"]
        out["rejected"] = len(self.sched.rejected)
        return out

    def generate(self, prompts: list[list[int]], max_new_tokens: int) -> dict:
        """Batch convenience: all requests arrive at t=0; returns outputs
        (None for requests rejected by admission control)."""
        reqs = [Request(id=i, prompt=tuple(p), max_new_tokens=max_new_tokens)
                for i, p in enumerate(prompts)]
        self.run(reqs)
        return {i: self.outputs.get(i) for i in range(len(prompts))}
