"""Overload survival: tiered frozen-page host offload, preempt-and-requeue,
and SLO-aware admission.

Three cooperating mechanisms let a full pool degrade gracefully instead of
hard-429ing at the admission door (ROADMAP item 4):

  tiered paging    Under pressure a whole victim sequence's pages demote
      to a ``HostPageStore`` as a "resident" payload
      (``transfer.extract_resident_pages``): installed-frozen pages cross
      as their existing packed 4-bit codes + codebooks (~7x fewer bytes
      than fp — the sparse-LSQ codebooks are what make survival cheap),
      the rest fp. Restore is dispatched at re-admission — BEFORE the
      decode window needs the pages — and the jit dataflow chains the
      first decode step behind the install, so a restored sequence is
      greedy-token-identical to one that never left.

  preempt-and-requeue    ``DecodeWorker.preempt`` evicts a victim at a
      step boundary (mirroring ``_finish``'s cleanup, so the rollback/
      freeze-watermark and pool-conservation invariants hold), and the
      scheduler re-admits preempted requests ahead of FCFS. The
      ``choose_resume`` cost model picks restore (move the payload bytes
      back — exact) vs recompute (re-prefill prompt + emitted tokens —
      cheaper when almost nothing was frozen, but only value-exact on
      unquantized greedy runs, so quantized/sampled runs always restore).

  SLO-aware admission    ``SLOAdmission`` consults the *windowed* itl_s
      p99 from the streaming registry (PR 6's log-histogram counts-delta
      mechanism) plus live pool occupancy to shed or defer best_effort
      requests while latency-tier requests are only ever bounced by the
      hard queue/pool doors. Deferred requests park in the
      ``OverloadManager`` and retry when occupancy recedes.

``OverloadManager`` is the engine-side composition of the three: both
engine run loops call ``try_restore`` (drain the resume queue into freed
capacity, ahead of any FCFS admission) and ``maybe_preempt`` (evict a
best_effort victim when a latency-tier head is capacity-blocked) once per
iteration. All decision logic is host-side and deterministic — a whole
overload scenario replays exactly in a unit test.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from .transfer import PagePayload


@dataclasses.dataclass
class ResumeEntry:
    """Everything needed to resume a preempted sequence exactly where it
    stopped: the request, its emitted tokens (``out``; the last one has no
    KV row yet — the next decode step writes it, same as at attach), and
    the demoted pages. ``n_tokens`` is the KV length at eviction, which at
    a step boundary is prompt_len + generated - 1."""

    req: object
    out: list
    generated: int
    n_tokens: int
    rng: object = None
    logits: list = dataclasses.field(default_factory=list)
    payload: PagePayload | None = None         # None = recompute path
    frozen_idx: list = dataclasses.field(default_factory=list)
    span_ids: dict = dataclasses.field(default_factory=dict)  # page pos -> span
    # leading pages the victim shared with other live tables at eviction:
    # they dropped a ref instead of demoting (the payload never captures
    # them), and restore re-attaches them from the prefix index — or
    # rebuilds them deterministically if their last reference died
    shared_pages: int = 0

    @property
    def restore_bytes(self) -> int:
        return self.payload.nbytes if self.payload is not None else 0


class HostPageStore:
    """Host-memory tier holding demoted sequences' page payloads, keyed by
    request id. Pure bookkeeping over staged numpy payloads — this is
    where a second HBM tier (or a remote host) would sit; ``nbytes`` is
    the measured footprint of everything currently demoted."""

    def __init__(self):
        self._entries: dict[int, ResumeEntry] = {}
        self.put_total = 0          # lifetime payloads stored
        self.bytes_total = 0        # lifetime bytes staged in

    def put(self, entry: ResumeEntry) -> None:
        rid = entry.req.id
        assert rid not in self._entries, f"rid {rid} already demoted"
        assert entry.payload is not None and entry.payload.staged
        self._entries[rid] = entry
        self.put_total += 1
        self.bytes_total += entry.payload.nbytes

    def pop(self, rid: int) -> ResumeEntry:
        return self._entries.pop(rid)

    def release(self, rid: int) -> ResumeEntry | None:
        """Reclaim a retired request's entry (finished or cancelled while
        offloaded) — unlike ``pop``, absent is fine. Without this, a
        demoted payload whose request never restores leaks in the host
        tier forever."""
        return self._entries.pop(rid, None)

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return sum(e.payload.nbytes for e in self._entries.values())

    @property
    def pages(self) -> int:
        return sum(e.payload.n_pages for e in self._entries.values())

    def entries(self) -> list[ResumeEntry]:
        return list(self._entries.values())


def choose_resume(*, frozen_pages: int, total_pages: int, restore_bytes: int,
                  fp_equiv_bytes: int, exact_required: bool) -> str:
    """Restore-vs-recompute cost model for a preemption victim.

    Restore moves ``restore_bytes`` back across the host tier; recompute
    re-prefills the whole context, rewriting ``fp_equiv_bytes`` of KV at
    full width plus the prefill FLOPs. On the modeled roofline both reduce
    to bytes moved, so restore wins whenever the payload is meaningfully
    compressed — i.e. when enough pages were frozen (codes are ~7x
    smaller). ``exact_required`` forces restore: recompute re-prefills
    through exact fp where the original decode served quantized
    reconstructions (and a sampled request's rng cannot be rewound), so
    only restore keeps those runs token-identical.
    """
    if exact_required:
        return "restore"
    if total_pages == 0:
        return "recompute"          # nothing demotable — nothing to move
    # payload compressed below ~60% of a full fp re-write: moving it back
    # beats paying the re-prefill (which also burns compute the overloaded
    # box doesn't have)
    if restore_bytes <= 0.6 * fp_equiv_bytes:
        return "restore"
    return "recompute"


class SLOAdmission:
    """Shed/defer policy over the streaming registry's live signals.

    Consumes the windowed itl_s p99 (log-histogram counts-delta between
    policy snapshots — O(1) memory, no sample lists) and the device pool
    occupancy. latency-tier requests always pass; best_effort requests are
    shed while the latency SLO is breached and deferred while the pool is
    nearly full. Hysteresis: deferred requests re-admit only once
    occupancy recedes below ``occ_resume`` (or the worker goes idle), so
    the door doesn't flap at the threshold.
    """

    def __init__(self, metrics, *, itl_slo_s: float | None = None,
                 occ_defer: float = 0.95, occ_resume: float = 0.80,
                 window: int = 128, min_samples: int = 16):
        assert 0.0 < occ_resume <= occ_defer <= 1.0
        self.metrics = metrics
        self.itl_slo_s = itl_slo_s
        self.occ_defer = occ_defer
        self.occ_resume = occ_resume
        self.window = window
        self.min_samples = min_samples
        self._snap = None            # (histogram state) at window start

    # ------------------------------------------------------------ signals

    def windowed_itl_p99(self) -> float | None:
        """p99 of inter-token latency over the current window, from bucket
        count deltas; None until ``min_samples`` gaps landed in-window."""
        if "itl_s" not in self.metrics.stats:
            return None
        h = self.metrics.stats.histogram("itl_s")
        if self._snap is None:
            # first window starts EMPTY, not at the current counts —
            # snapshotting late would swallow every gap observed before
            # the policy's first decision
            self._snap = {"counts": [0] * len(h.counts), "underflow": 0,
                          "overflow": 0, "n": 0}
        d = h.delta(self._snap)
        if d["n"] >= self.window:
            # roll the window forward; answer over the closing window
            p = h.percentile(99, **d)
            self._snap = h.state()
            self._last = p
            return p
        if d["n"] >= self.min_samples:
            return h.percentile(99, **d)
        return getattr(self, "_last", None)

    # ------------------------------------------------------------ decisions

    def decide(self, req, *, occupancy: float) -> str:
        """'admit' | 'shed' | 'defer' for an arriving request."""
        if getattr(req, "priority", "latency") != "best_effort":
            return "admit"
        if self.itl_slo_s is not None:
            p99 = self.windowed_itl_p99()
            if p99 is not None and p99 > self.itl_slo_s:
                return "shed"
        if occupancy >= self.occ_defer:
            return "defer"
        return "admit"

    def may_resume(self, *, occupancy: float, idle: bool) -> bool:
        """Gate for re-admitting deferred requests (hysteresis band)."""
        return idle or occupancy <= self.occ_resume


class OverloadManager:
    """Engine-side overload state: the host tier, the restore queue, the
    deferred queue, and the preemption trigger. One instance per engine;
    methods take the decode worker they act on, so the disaggregated
    engine shares one manager across its decode workers (payloads are
    portable — a sequence may restore onto a different worker than it was
    evicted from)."""

    def __init__(self, *, offload_pages: bool = True, policy=None,
                 router=None):
        self.offload_pages = offload_pages
        self.policy = policy
        # disaggregated composition: recompute-requeues and deferred
        # retries go through the global router's queues, not a worker's
        # local scheduler (which the disagg import path bypasses)
        self.router = router
        self.store = HostPageStore()
        self.resume: deque[ResumeEntry] = deque()
        self.deferred: deque = deque()

    @property
    def has_work(self) -> bool:
        return bool(self.resume or self.deferred or len(self.store))

    def _queues(self, worker):
        """The admission queues this composition drains: the router's when
        disaggregated, the worker's scheduler's when colocated."""
        return self.router if self.router is not None else worker.sched

    # ------------------------------------------------------------ restore

    def try_restore(self, worker, now_fn) -> int:
        """Drain the resume queue head-first into the worker's free
        capacity. Runs BEFORE the scheduler's FCFS admission each
        iteration, so a preempted sequence re-enters ahead of every queued
        arrival. Stops at the first entry that doesn't fit (strict order —
        a later, smaller entry must not starve the head)."""
        n = 0
        while self.resume:
            entry = self.resume[0]
            req = entry.req
            if (not worker.sched._free_slots
                    or worker.sched.blocks_for(req) > worker.alloc.num_free):
                break
            if getattr(req, "priority", "latency") == "best_effort":
                # a best_effort victim must not re-absorb the capacity its
                # own eviction freed for a starved latency head: it only
                # restores when slots+pages suffice for BOTH, else it stays
                # demoted until the head admits (or finishes)
                head = self._queue_head(worker)
                if (head is not None
                        and getattr(head, "priority", "latency") == "latency"
                        and (len(worker.sched._free_slots) < 2
                             or worker.alloc.num_free
                             < worker.sched.blocks_for(req)
                             + worker.sched.blocks_for(head))):
                    break
            self.resume.popleft()
            self.store.pop(req.id)
            st = worker.sched.admit_direct(req)
            worker.restore(st, entry, now_fn())
            n += 1
        return n

    def retry_deferred(self, worker) -> int:
        """Re-admit deferred best_effort requests once pressure recedes
        (hysteresis: the policy's ``occ_resume`` band, or an idle worker).
        They rejoin the ordinary waiting queue — deferral bought them a
        later place in line, not a priority upgrade. Appends directly
        (their arrival was already metered at defer time) and respects the
        queue-depth door."""
        if not self.deferred or self.policy is None:
            return 0
        occ = 1.0 - worker.alloc.num_free / (worker.num_blocks - 1)
        if not self.policy.may_resume(occupancy=occ,
                                      idle=not worker.sched.active):
            return 0
        q = self._queues(worker)
        n = 0
        while self.deferred and len(q.waiting) < q.max_queue:
            q.waiting.append(self.deferred.popleft())
            n += 1
        return n

    # ------------------------------------------------------------ retire

    def retire(self, rid: int) -> ResumeEntry | None:
        """Release a request's host-tier residency on retirement (it
        finished, was cancelled, or the run is over while it sat demoted):
        drop its store entry and resume-queue slot. Returns the released
        entry (its ``out`` is everything the request ever emitted) or None
        if the request was not demoted."""
        entry = self.store.release(rid)
        if entry is not None:
            self.resume = deque(e for e in self.resume if e.req.id != rid)
        return entry

    # ------------------------------------------------------------ preempt

    def _queue_head(self, worker):
        """The first request waiting in the admission queues: staged
        prefills (disagg) outrank recompute-requeues outrank FCFS."""
        if self.router is not None and self.router.staged:
            return self.router.staged[0].req
        q = self._queues(worker)
        if q.preempted:
            return q.preempted[0]
        if q.waiting:
            return q.waiting[0]
        return None

    def _head(self, worker):
        """The highest-priority request waiting on this worker's capacity:
        resume entries outrank everything in the admission queues."""
        if self.resume:
            return self.resume[0].req
        return self._queue_head(worker)

    def pick_victim(self, worker):
        """A best_effort victim worth evicting for a capacity-blocked
        latency-tier head, or None.

        Coldness rank: least-recently-attended first (LRU by decode step),
        then highest frozen fraction (cheapest to demote — frozen pages
        move at ~4 bits/value), then slot for determinism. Only preempts
        across tiers, and only when the evictable best_effort capacity
        could actually unblock the head."""
        head = self._head(worker)
        if head is None or getattr(head, "priority", "latency") != "latency":
            return None
        need = worker.sched.blocks_for(head)
        slot_blocked = not worker.sched._free_slots
        page_blocked = need > worker.alloc.num_free
        if not (slot_blocked or page_blocked):
            return None
        # the LRU signal is seeded at attach/restore time, so a sequence is
        # visible to preemption from the moment it holds pages — a
        # just-attached best_effort victim (coldest possible: zero steps
        # attended) must not hide from a capacity-blocked latency head
        # behind a missing last_attended entry
        victims = [st for st in worker.sched.active.values()
                   if getattr(st.req, "priority", "latency") == "best_effort"
                   and st.slot in worker.last_attended]
        if not victims:
            return None
        if page_blocked:
            reclaimable = sum(len(worker.slots[st.slot].blocks)
                              for st in victims)
            if worker.alloc.num_free + reclaimable < need:
                return None          # eviction can't unblock — don't thrash

        def rank(st):
            s = worker.slots[st.slot]
            frozen = sum(1 for b in s.blocks if b in worker._frozen_pages)
            frac = frozen / max(len(s.blocks), 1)
            return (worker.last_attended[st.slot], -frac, st.slot)

        return min(victims, key=rank)

    def maybe_preempt(self, worker, now_fn) -> bool:
        """Evict at most one victim per call (re-evaluated every iteration
        so pressure ramps rather than mass-evicting). The cost model picks
        offload-and-restore vs drop-and-recompute; with the host tier
        disabled, recompute is the only resume path."""
        st = self.pick_victim(worker)
        if st is None:
            return False
        s = worker.slots[st.slot]
        # shared prefix pages neither demote nor restore — they drop a ref
        # and re-attach from the index — so the cost model sees only the
        # exclusively-owned suffix on both sides of the tradeoff
        sh = worker.shared_prefix_pages(st.slot)
        full = int(worker.lens[st.slot]) // worker.block_size
        frozen = sum(1 for b in s.blocks[sh:full]
                     if b in worker._frozen_pages)
        n_pages = -(-int(worker.lens[st.slot]) // worker.block_size) - sh
        pb = worker._pb
        est = frozen * pb["frozen"] + (n_pages - frozen) * pb["fp"]
        exact = (worker.kv_spec is not None
                 or st.req.temperature > 0.0)
        mode = "recompute"
        if self.offload_pages:
            mode = choose_resume(
                frozen_pages=frozen, total_pages=n_pages, restore_bytes=est,
                fp_equiv_bytes=n_pages * pb["fp"], exact_required=exact)
        entry = worker.preempt(st, mode, now_fn())
        if mode == "restore":
            self.store.put(entry)
            self.resume.append(entry)
        else:
            # recompute: resume as a fresh request whose prompt is the
            # original plus everything emitted; the worker merges the
            # prefix back at finish. Re-admitted ahead of FCFS (through
            # the router's preempted queue when disaggregated — it must
            # re-prefill on a prefill worker first).
            self._queues(worker).preempted.append(entry.req)
        return True
