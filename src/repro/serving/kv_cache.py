"""Paged KV cache: fixed-size blocks, a free-list allocator, per-sequence
block tables, codebook-quantized pages, and a fused decode read path.

Layout (per attention layer, leading group axis added by the stacked model
cache exactly like ``transformer.init_lm_cache``):

  k_fp/v_fp     (nb, bs, Hkv, Dh)  fp pages — the write-hot pool; every
                token lands here first.
  k_codes/...   (nb, bs, Hkv, Dc)  uint8 codes for quantized pages
                (Dc = Dh/2 when two 4-bit codes pack per byte, split-half
                layout — see kernels.paged_attention.pack4).
  k_cb/v_cb     (nb, L) f32        per-block codebooks from the paper's
                solvers (kmeans_ls / tv via repro.core.quantize).
  blk_q         (nb,) bool         page i is frozen: codes are
                authoritative, fp holds their reconstruction.
  block_table   (B, mb) int32      per-sequence page ids (0 = null page).
  seq_lens      (B,) int32         per-sequence lengths (write positions).

Block 0 is reserved as the null page: idle batch slots point every table
entry at it, so their (masked) decode writes land in the trash instead of a
live page.

Writes always go to the fp pool inside the jitted step. Freezing a full
page takes a ``QuantSpec`` (see ``resolve_kv_spec``) and is split into
``dispatch_freeze`` — every (page, group, k/v) row of the event batched
through the spec's registry device solver (kmeans_ls/kmeans via the exact
DP sketch, iter_l1 via batched FISTA + per-row lambda bisection) in one
async dispatch per layer — and ``install_freeze``, which scatters the
finished codes/codebooks and flips ``blk_q``. Between the two, the pages
keep serving from the exact fp pool, so decode steps carry no data
dependency on the solve and truly overlap it; no host numpy runs in the
steady state (count methods without a device entry keep the per-page host
fallback).

Reads have two paths:

  fused (TPU decode hot path)   ``fused_decode`` hands the query plus the
      raw pools/table to ``kernels.paged_decode_attention``, which walks
      the block table on-core, DMAs frozen pages as packed codes +
      codebooks, dequantizes in VMEM, and runs online-softmax attention.
      Frozen pages cross the wire at ~4 bits/value.

  gather (CPU / prefill / fallback)   ``update`` expands every table page
      to full width from the fp pool and returns dense K/V for the
      caller's sdpa. Installing a freeze *materializes* ``cb[codes]`` into
      the frozen pages' fp rows, so this path serves exactly the quantized
      values with a decode graph identical to the unquantized one — it is
      the reference the fused kernel is validated against, paying fp
      bandwidth where the kernel pays ~4 bits/value.

``PagedKVCache`` implements the adapter protocol of ``repro.models.cache``
(plus its optional fused-decode extension); model code never learns about
pages.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantSpec
from repro.core import registry as quant_registry
from repro.kernels import (default_interpret, pack4, paged_decode_attention,
                           paged_prefill_attention, unpack4)

# ------------------------------------------------------------- allocator


class PoolExhausted(MemoryError):
    """Typed allocator failure carrying the shortfall, so overload-control
    code (preemption, admission deferral) can catch-and-react instead of
    pattern-matching a bare MemoryError message. Subclasses MemoryError for
    callers that only care that allocation failed."""

    def __init__(self, requested: int, free: int):
        self.requested = requested
        self.free = free
        super().__init__(f"asked {requested} blocks, {free} free")


class DoubleFree(ValueError):
    """Typed allocator failure for freeing a block that is already on the
    free list (or was never allocated). Subclasses ValueError so legacy
    callers that caught the old bare-ValueError message keep working; the
    offending id rides along for return-path audits."""

    def __init__(self, block: int):
        self.block = block
        super().__init__(f"double free / foreign block {block}")


class BlockAllocator:
    """Host-side free-list page allocator with per-page refcounts. Block 0
    is never handed out.

    Refcount protocol (prefix sharing): ``alloc`` hands out pages at rc 1;
    ``retain`` bumps rc for every table that splices an already-live page;
    ``free`` drops rc and releases a page to the free list only when its
    last reference goes away. ``free`` returns the ids actually released so
    callers can scope teardown side effects (thawing, span drops, frozen-set
    removal) to pages no other sequence still serves from."""

    def __init__(self, num_blocks: int):
        assert num_blocks >= 2, "need at least one allocatable block"
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> low ids first
        self._used: set[int] = set()
        self._rc: dict[int, int] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    def refcount(self, b: int) -> int:
        return self._rc.get(int(b), 0)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise PoolExhausted(n, len(self._free))
        out = [self._free.pop() for _ in range(n)]
        self._used.update(out)
        for b in out:
            self._rc[b] = 1
        return out

    def retain(self, ids) -> None:
        """Add one reference per id for a table sharing already-live pages."""
        for b in ids:
            b = int(b)
            if b not in self._used:
                raise ValueError(f"retain of non-live block {b}")
            self._rc[b] += 1

    def free(self, ids) -> list[int]:
        """Drop one reference per id; release pages whose rc hits 0.

        Returns the ids actually released (rc reached zero) in drop order.
        Freeing an id that is not live raises ``DoubleFree``.
        """
        released: list[int] = []
        for b in ids:
            b = int(b)
            if b not in self._used:
                raise DoubleFree(b)
            self._rc[b] -= 1
            if self._rc[b] == 0:
                del self._rc[b]
                self._used.remove(b)
                self._free.append(b)
                released.append(b)
        return released


# ------------------------------------------------------------- prefix index


class PrefixIndex:
    """Rolling token-hash index over installed-frozen full pages.

    Each published page is keyed by ``(chain_hash, page_tokens)`` where
    ``chain_hash`` rolls over every preceding page of the same prompt
    (``h_0 = 0``, ``h_{i+1} = hash((h_i, page_i_tokens))``), so a lookup
    walks the longest run of full pages whose *entire prefix* matches a
    published chain — a radix trie keyed one page per edge. Only immutable
    pages publish: installed-frozen codebook reconstructions on quantized
    pools, full prompt pages on unquantized pools (prompt rows never
    rewrite once written) — safe for any number of tables to reference.
    Entries die with their page: the worker calls ``invalidate`` with the
    ids ``BlockAllocator.free`` actually released.
    """

    def __init__(self, block_size: int):
        self.block_size = block_size
        self._map: dict[tuple, int] = {}          # (chain_hash, page) -> bid
        self._keys: dict[int, list] = {}          # bid -> keys published

    def __len__(self) -> int:
        return len(self._map)

    @staticmethod
    def _link(parent: int, page: tuple) -> int:
        # int/tuple hashing is unsalted in CPython, so chains are stable
        # across processes (tests may compare index sizes run-to-run)
        return hash((parent, page))

    def publish(self, tokens, blocks, frozen) -> int:
        """Register the full pages of ``tokens`` served by ``blocks`` whose
        ids are in ``frozen``, stopping at the first non-frozen page (a
        chain must be contiguous from the root). ``frozen=None`` marks every
        full page eligible — the unquantized-pool case, where full prompt
        pages are immutable exact-fp rows the moment prefill wrote them.
        Idempotent; first publisher of a (chain, page) key wins. Returns
        new entries added."""
        bs = self.block_size
        h, added = 0, 0
        for i in range(min(len(tokens) // bs, len(blocks))):
            bid = int(blocks[i])
            if frozen is not None and bid not in frozen:
                break
            page = tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
            key = (h, page)
            if key not in self._map:
                self._map[key] = bid
                self._keys.setdefault(bid, []).append(key)
                added += 1
            h = self._link(h, page)
        return added

    def lookup(self, tokens, max_pages: int) -> list[int]:
        """Longest run of published pages matching ``tokens`` from position
        0, capped at ``max_pages``; returns their block ids in order."""
        bs = self.block_size
        h, out = 0, []
        limit = min(len(tokens) // bs, max_pages)
        for i in range(limit):
            page = tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
            bid = self._map.get((h, page))
            if bid is None:
                break
            out.append(bid)
            h = self._link(h, page)
        return out

    def invalidate(self, released_ids) -> None:
        """Forget every entry served by a page whose last reference was
        just released (the id may be reallocated with different content)."""
        for bid in released_ids:
            for key in self._keys.pop(int(bid), ()):
                if self._map.get(key) == int(bid):
                    del self._map[key]


# ------------------------------------------------------------- paged cache


def _pack4(codes: np.ndarray) -> np.ndarray:
    """Host-side pack4 (same split-half layout as kernels.pack4)."""
    D = codes.shape[-1]
    lo, hi = codes[..., : D // 2], codes[..., D // 2:]
    return (lo | (hi << 4)).astype(np.uint8)


def _unpack4(packed: jax.Array) -> jax.Array:
    return unpack4(packed)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """One attention layer's paged KV pools + this batch's table view."""

    k_fp: jax.Array
    v_fp: jax.Array
    k_codes: jax.Array
    v_codes: jax.Array
    k_cb: jax.Array
    v_cb: jax.Array
    blk_q: jax.Array
    block_table: jax.Array
    seq_lens: jax.Array
    # static
    block_size: int
    quantized: bool
    packed: bool
    fused: bool = False       # decode reads go through the Pallas kernel
    fused_window: int = 1     # max fused query window (speculative verify)
    prefill_fused: bool = False   # prefill chunks read through the kernel

    _LEAVES = ("k_fp", "v_fp", "k_codes", "v_codes", "k_cb", "v_cb",
               "blk_q", "block_table", "seq_lens")
    _POOL_LEAVES = ("k_fp", "v_fp", "k_codes", "v_codes", "k_cb", "v_cb",
                    "blk_q")

    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in self._LEAVES),
                (self.block_size, self.quantized, self.packed, self.fused,
                 self.fused_window, self.prefill_fused))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # ---------------------------------------------- adapter protocol

    def _write(self, k, v):
        """Scatter k/v (B, S, Hkv, Dh) into the fp pool at per-sequence
        positions (block 0 absorbs idle slots' masked writes)."""
        B, S, Hkv, Dh = k.shape
        bs = self.block_size
        pos = self.seq_lens[:, None] + jnp.arange(S)[None]          # (B,S)
        blk = jnp.take_along_axis(self.block_table, pos // bs, axis=1)
        off = pos % bs
        return dataclasses.replace(
            self,
            k_fp=self.k_fp.at[blk.reshape(-1), off.reshape(-1)].set(
                k.reshape(B * S, Hkv, Dh).astype(self.k_fp.dtype)),
            v_fp=self.v_fp.at[blk.reshape(-1), off.reshape(-1)].set(
                v.reshape(B * S, Hkv, Dh).astype(self.v_fp.dtype)),
        )

    def update(self, k, v, cache_index):
        """Write k/v (B,S,Hkv,Dh) at per-sequence positions; gather pages.

        cache_index (the ring-cache scalar) is ignored: this cache carries
        its own per-sequence lengths.
        """
        del cache_index
        S = k.shape[1]
        new = self._write(k, v)
        k_all = new._gather(new.k_fp, new.k_codes, new.k_cb)
        v_all = new._gather(new.v_fp, new.v_codes, new.v_cb)
        return new, k_all, v_all, self.seq_lens, self.seq_lens + S

    @property
    def use_fused_decode(self) -> bool:
        """Fused-adapter extension flag (see repro.models.cache)."""
        return self.fused

    def fused_decode(self, q, k, v, *, softcap=None):
        """Decode write + fused paged attention over a 1..fused_window
        query window.

        Returns (new_cache, out (B, S, Hq, Dh)); frozen pages are read as
        packed codes and dequantized inside the kernel. S > 1 is the
        speculative verify window: query w attends causally through
        position ``seq_lens + w``.
        """
        B, S, Hq, Dh = q.shape
        assert S <= max(self.fused_window, 1), (
            f"fused_decode window {S} exceeds fused_window "
            f"{self.fused_window}")
        new = self._write(k, v)
        out = paged_decode_attention(
            q if S > 1 else q[:, 0], new.k_fp, new.v_fp, new.k_codes,
            new.v_codes, new.k_cb, new.v_cb, new.blk_q, new.block_table,
            new.seq_lens + S, softcap=softcap, quantized=new.quantized,
            packed=new.packed, interpret=default_interpret())
        return new, (out if S > 1 else out[:, None]).astype(q.dtype)

    @property
    def use_fused_prefill(self) -> bool:
        """Fused chunked-prefill extension flag (see repro.models.cache)."""
        return self.prefill_fused

    def fused_prefill(self, q, k, v, *, softcap=None):
        """Prefill-chunk write + fused paged attention.

        The chunk's C queries sit at absolute positions
        ``seq_lens .. seq_lens + C - 1`` — exactly the last C positions of
        the post-write valid length, so this is ``fused_decode`` with
        W = C and the causal chunk mask falls out of the existing windowed
        mask (``pos <= q_offset + w``). Earlier frozen pages are read as
        packed codes + codebooks through the same double-buffered DMA path
        as decode; splitting a prompt into chunks is bitwise identical to
        one whole-prompt call (the PR 5 verify-window discipline applied
        to prefill).
        """
        new = self._write(k, v)
        out = paged_prefill_attention(
            q, new.k_fp, new.v_fp, new.k_codes, new.v_codes, new.k_cb,
            new.v_cb, new.blk_q, new.block_table, self.seq_lens,
            softcap=softcap, quantized=new.quantized, packed=new.packed,
            interpret=default_interpret())
        return new, out.astype(q.dtype)

    def _gather(self, fp, codes=None, cb=None):
        """Pages for this batch: (B, mb*bs, Hkv, Dh).

        No read-time dequantization: installing a freeze materializes the
        reconstruction ``cb[codes]`` into the frozen pages' fp rows (see
        ``_install_leaf``), so this path reads plain fp yet returns
        quantized values for frozen pages — the decode graph is identical
        to the unquantized one. ``codes``/``cb`` are accepted for call-site
        symmetry; the packed form is read only by the fused kernel, which
        is where the ~4-bit HBM crossing actually pays."""
        del codes, cb
        t = self.block_table                                # (B, mb)
        B, mb = t.shape
        pages = fp[t]                                       # (B,mb,bs,H,D)
        nb, bs, H, D = fp.shape
        return pages.reshape(B, mb * bs, H, D)


def init_paged_layer(cfg, *, num_blocks, block_size, batch, max_blocks,
                     quantized, num_values, dtype,
                     fused=False, fused_window=1) -> PagedKVCache:
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    packed = quantized and num_values <= 16
    assert Dh % 2 == 0 or not packed
    Dc = Dh // 2 if packed else Dh
    cshape = (num_blocks, block_size, Hkv, Dc) if quantized else (1, 1, 1, 1)
    cbshape = (num_blocks, num_values) if quantized else (1, 1)
    return PagedKVCache(
        k_fp=jnp.zeros((num_blocks, block_size, Hkv, Dh), dtype),
        v_fp=jnp.zeros((num_blocks, block_size, Hkv, Dh), dtype),
        k_codes=jnp.zeros(cshape, jnp.uint8),
        v_codes=jnp.zeros(cshape, jnp.uint8),
        k_cb=jnp.zeros(cbshape, jnp.float32),
        v_cb=jnp.zeros(cbshape, jnp.float32),
        blk_q=jnp.zeros((num_blocks if quantized else 1,), bool),
        block_table=jnp.zeros((batch, max_blocks), jnp.int32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
        block_size=block_size, quantized=quantized, packed=packed,
        fused=fused, fused_window=fused_window,
    )


def init_paged_cache(cfg, *, num_blocks, block_size, batch, max_blocks,
                     quantized=False, num_values=16, fused=False,
                     fused_window=1):
    """Model-shaped cache tree mirroring ``transformer.init_lm_cache`` with
    PagedKVCache leaves (leading group axis on scanned groups)."""
    for spec in tuple(cfg.group) + tuple(cfg.head_layers):
        assert spec.mixer == "attn", (
            f"paged serving supports attention mixers only, got {spec.mixer}")
    dtype = cfg.dtype("compute")
    kw = dict(num_blocks=num_blocks, block_size=block_size, batch=batch,
              max_blocks=max_blocks, quantized=quantized,
              num_values=num_values, dtype=dtype, fused=fused,
              fused_window=fused_window)

    def stack(_spec):
        one = init_paged_layer(cfg, **kw)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape).copy(),
            one)

    cache = {"groups": {f"l{i}": stack(s) for i, s in enumerate(cfg.group)}}
    for i, spec in enumerate(cfg.head_layers):
        cache[f"head_{i}"] = init_paged_layer(cfg, **kw)
    return cache


# ----------------------------------------------- tree-surgery helpers


def _is_leaf(x):
    return isinstance(x, PagedKVCache)


def map_layers(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_leaf)


def with_tables(tree, block_table: np.ndarray, seq_lens: np.ndarray):
    """Install host-managed table/lens into every layer leaf (broadcast over
    the stacked group axis when present). The table may be narrower than
    ``max_blocks``: the engine clamps it to the blocks the longest live
    sequence actually needs, so short batches don't pay full-window reads."""
    bt = jnp.asarray(block_table, jnp.int32)
    sl = jnp.asarray(seq_lens, jnp.int32)

    def per(leaf: PagedKVCache):
        g = leaf.k_fp.ndim == 5            # stacked group axis present
        G = leaf.k_fp.shape[0] if g else None
        b = jnp.broadcast_to(bt, (G,) + bt.shape).copy() if g else bt
        s = jnp.broadcast_to(sl, (G,) + sl.shape).copy() if g else sl
        return dataclasses.replace(leaf, block_table=b, seq_lens=s)

    return map_layers(per, tree)


def with_prefill_fused(tree):
    """Flag every layer leaf so ``models.prefill`` routes chunk attention
    through the fused kernel (``fused_prefill``). Applied only to the
    chunked-prefill view of the tree — the default-False flag keeps every
    other jit cache key and golden trace unchanged."""
    return map_layers(
        lambda leaf: dataclasses.replace(leaf, prefill_fused=True), tree)


def merge_pools(held, returned):
    """Adopt jit-updated fp pools; keep host-managed quantization state and
    tables from ``held``."""
    return jax.tree_util.tree_map(
        lambda h, r: dataclasses.replace(h, k_fp=r.k_fp, v_fp=r.v_fp),
        held, returned, is_leaf=_is_leaf)


def freeze_markers(tree) -> list[jax.Array]:
    """One device array per layer whose readiness implies that layer's last
    freeze dispatch has completed (used by the engine's overlap counters)."""
    out = []
    map_layers(lambda leaf: out.append(leaf.k_cb), tree)
    return out


# ----------------------------------------------- spec resolution


def resolve_kv_spec(spec=None, *, method=None, num_values=None) -> QuantSpec:
    """Coerce the engine/freeze ``kv_quant`` argument to a validated
    QuantSpec.

    Accepts a QuantSpec, a compact spec string ("kmeans_ls@16",
    "iter_l1@16:seed=3"), or the legacy (method, num_values) pair —
    including the old "tv" alias, which maps to the exact-count ``tv_iter``
    (tv itself is lam-parameterised; freezing needs a count budget).
    Page freezing requires a count-parameterised method: anything else
    raises at construction, naming the registry's device-capable methods.
    """
    device = quant_registry.device_methods()
    host_only = sorted(set(quant_registry.count_methods()) - set(device))
    capable = (f"device-batched methods: {', '.join(device)}; count methods "
               f"with a per-page host fallback: {', '.join(host_only)}")
    try:
        if isinstance(spec, QuantSpec) or (
                isinstance(spec, str) and ("@" in spec or ":" in spec)):
            if num_values is not None or method is not None:
                raise TypeError(
                    f"got both a kv_quant spec ({spec!s}) and loose "
                    f"method=/num_values= arguments; fold them into the "
                    f"spec, e.g. 'kmeans_ls@{num_values or 16}'")
            out = QuantSpec.parse(spec)
        else:
            m = spec if isinstance(spec, str) else method
            if m is None:
                m = "kmeans_ls"
            m = {"tv": "tv_iter"}.get(m, m)
            out = QuantSpec(m, num_values=16 if num_values is None
                            else num_values)
    except ValueError as e:
        raise ValueError(f"bad kv_quant spec: {e}\npage freezing needs a "
                         f"count-parameterised method — {capable}") from None
    if out.param_kind != "count":
        raise ValueError(
            f"kv_quant spec {str(out)!r} is lam-parameterised; page "
            f"freezing needs a count budget (method@num_values) — {capable}")
    return out


# ----------------------------------------------- host-side quantization


def quantize_page(data: np.ndarray, spec, num_values: int | None = None):
    """Run the paper's solver on one page; returns (codes u8, codebook f32).

    Host fallback for methods without a batched device solver. ``spec`` is
    anything ``resolve_kv_spec`` accepts (legacy ``(method, num_values)``
    included). Pages always solve multiplicity-weighted: the page *is* the
    full vector being served.
    """
    from repro.core import quantize

    spec = resolve_kv_spec(spec, num_values=num_values)
    qt, _ = quantize(data.astype(np.float32), spec.replace(weighted=True))
    cb = np.asarray(qt.codebook, np.float32)
    codes = np.asarray(qt.indices, np.uint8).reshape(data.shape)
    if cb.shape[0] < spec.num_values:               # pad to the static width
        cb = np.concatenate([cb, np.full(spec.num_values - cb.shape[0],
                                         cb[-1], np.float32)])
    return codes, cb


#: count methods with a batched on-device solver (no host numpy per page);
#: declared per-method in core.registry
DEVICE_FREEZE_METHODS = quant_registry.device_methods()


def freeze_blocks(tree, block_ids, spec=None, *, method=None,
                  num_values=None, stats=None):
    """Quantize full pages ``block_ids`` in every attention layer and
    scatter codes/codebooks/flags back.

    ``spec`` is a QuantSpec / spec string (legacy ``method=``/
    ``num_values=`` kwargs still map). Methods with a registry
    ``device_batch`` entry (kmeans_ls, kmeans, iter_l1) batch every
    (page, group, k/v) row of the event through one async device dispatch
    per layer — the engine keeps decoding while it runs. Other count
    methods fall back to per-page host solves (``stats["host_page_solves"]``
    counts them, so serving tests can assert the steady state performs
    none).
    """
    if not len(block_ids):
        return tree
    spec = resolve_kv_spec(spec, method=method, num_values=num_values)
    bids = np.asarray(sorted(block_ids), np.int32)
    if spec.device_capable:
        return _freeze_blocks_device(tree, bids, spec)
    return _freeze_blocks_host(tree, bids, spec, stats=stats)


@functools.partial(jax.jit, static_argnames=("spec",))
def _solve_leaf_pages(leaf: PagedKVCache, jb, *, spec: QuantSpec):
    """Gather pages ``jb`` from one layer leaf and solve their codebooks as
    a single jitted computation (one async dispatch per layer), keyed on
    the hashable spec. Returns (codes (2, G?, P, bs, Hkv, Dc),
    cb (2, G?, P, L)) — k stacked over v on the leading axis — without
    touching the leaf."""
    solve = quant_registry.device_batch_solve(spec.method)
    stacked = leaf.k_fp.ndim == 5
    axis = 1 if stacked else 0
    kf = jnp.take(leaf.k_fp, jb, axis=axis)
    vf = jnp.take(leaf.v_fp, jb, axis=axis)
    both = jnp.stack([kf, vf])              # (2, G?, P, bs, Hkv, Dh)
    page_shape = both.shape[-3:]
    rows = both.reshape(-1, int(np.prod(page_shape)))
    codes, cb = solve(rows, spec)
    codes = codes.reshape(both.shape)
    cb = cb.reshape(both.shape[:-3] + (spec.num_values,))
    if leaf.packed:
        codes = pack4(codes)
    return codes, cb


@jax.jit
def _install_leaf(leaf: PagedKVCache, jb, keep, codes, cb):
    """Scatter one solve's outputs into a leaf, masked by ``keep`` (P,):
    dropped pages rewrite their current values and stay thawed. Installing
    also *materializes the reconstruction into the fp pool*, so the gather
    read path serves quantized values at plain-fp cost; the packed codes
    stay the source of truth for the fused kernel's ~4-bit HBM reads. One
    jit dispatch — eager scatter chains on still-computing operands can
    block the host."""
    stacked = leaf.k_fp.ndim == 5
    sel = (slice(None), jb) if stacked else (jb,)
    # align keep to the (G?, P, ...) result layout of _solve_leaf_pages
    kpage = keep[None, :, None, None, None] if stacked \
        else keep[:, None, None, None]
    kcb_m = keep[None, :, None] if stacked else keep[:, None]
    kc = jnp.where(kpage, codes[0], leaf.k_codes[sel])
    vc = jnp.where(kpage, codes[1], leaf.v_codes[sel])
    kcb = jnp.where(kcb_m, cb[0], leaf.k_cb[sel])
    vcb = jnp.where(kcb_m, cb[1], leaf.v_cb[sel])

    def recon(codes1, cb1, cur):
        idx = _unpack4(codes1) if leaf.packed else codes1.astype(jnp.int32)
        L = cb1.shape[-1]
        cbb = jnp.broadcast_to(cb1[..., None, None, :],
                               idx.shape[:-1] + (L,))    # (G?, P, bs, H, L)
        deq = jnp.take_along_axis(cbb, idx, axis=-1).astype(leaf.k_fp.dtype)
        return jnp.where(kpage, deq, cur)

    kf = recon(codes[0], cb[0], leaf.k_fp[sel])
    vf = recon(codes[1], cb[1], leaf.v_fp[sel])
    return dataclasses.replace(
        leaf,
        k_fp=leaf.k_fp.at[sel].set(kf),
        v_fp=leaf.v_fp.at[sel].set(vf),
        k_codes=leaf.k_codes.at[sel].set(kc),
        v_codes=leaf.v_codes.at[sel].set(vc),
        k_cb=leaf.k_cb.at[sel].set(kcb),
        v_cb=leaf.v_cb.at[sel].set(vcb),
        blk_q=leaf.blk_q.at[..., jb].max(keep))


class PendingFreeze:
    """Handle for an in-flight device freeze.

    Holds the solver outputs (one (codes, cb) pair per layer leaf, still
    computing on device) plus the page ids they target. Until ``install``
    scatters them into the cache, those pages keep serving from the exact
    fp pool — so decode steps issued between dispatch and install have NO
    data dependency on the solve and genuinely overlap it. ``drop`` forgets
    pages whose sequence finished (freed pages must not be installed later
    over a reallocated page); it only flips a host-side mask, so it is free
    to call while the solve is still in flight.
    """

    def __init__(self, bids: np.ndarray, results: list):
        self.bids = np.asarray(bids, np.int32)
        self.keep = np.ones(self.bids.shape, bool)
        self.results = results

    def is_ready(self) -> bool:
        return all(cb.is_ready() for _, cb in self.results)

    def markers(self) -> list:
        return [cb for _, cb in self.results]

    def drop(self, freed_ids) -> None:
        self.keep &= ~np.isin(self.bids,
                              np.asarray(list(freed_ids), np.int32))

    def kept_pages(self) -> list[int]:
        """Distinct page ids an install will mark frozen — padding
        duplicates collapsed, dropped pages excluded. Sorted so callers
        (frozen-set updates, tracer span ends) iterate deterministically."""
        return sorted({int(b) for b in self.bids[self.keep]})


def dispatch_freeze(tree, block_ids, spec=None, *, num_values=None,
                    refit=True) -> PendingFreeze:
    """Start the batched device solve for ``block_ids`` in every layer;
    returns immediately with a PendingFreeze (the cache is unmodified).

    ``spec`` must name a device-capable method (legacy ``num_values=`` +
    ``refit=`` kwargs map to kmeans_ls / kmeans)."""
    if spec is None:
        spec = resolve_kv_spec(method="kmeans_ls" if refit else "kmeans",
                               num_values=num_values)
    else:
        spec = resolve_kv_spec(spec, num_values=num_values)
    # device solvers are deterministic — canonicalize the meaningless seed
    # so specs differing only there share one jit entry
    spec = spec.replace(seed=0)
    bids = np.asarray(sorted(block_ids), np.int32)
    jb = jnp.asarray(bids)
    results = []

    def per(leaf: PagedKVCache):
        assert leaf.quantized
        results.append(_solve_leaf_pages(leaf, jb, spec=spec))
        return leaf

    map_layers(per, tree)
    return PendingFreeze(bids, results)


def install_freeze(tree, pending: PendingFreeze):
    """Scatter a completed (or still-computing) freeze into the cache and
    flip ``blk_q``; from the next step the kept pages serve from codes.
    Stacked leaves broadcast ``keep``/``codes`` over the group axis inside
    ``_install_leaf`` via the (2, G, P, ...) result layout."""
    if not pending.keep.any():
        return tree
    jb = jnp.asarray(pending.bids)
    keep = jnp.asarray(pending.keep)
    it = iter(pending.results)

    def per(leaf: PagedKVCache):
        codes, cb = next(it)
        return _install_leaf(leaf, jb, keep, codes, cb)

    return map_layers(per, tree)


def _freeze_blocks_device(tree, bids, spec: QuantSpec):
    # synchronous-semantics convenience: dispatch and install in one call
    # (jax's dataflow still runs the solve async behind later dispatches)
    return install_freeze(tree, dispatch_freeze(tree, bids, spec))


def _freeze_blocks_host(tree, bids, spec: QuantSpec, *, stats=None):
    def per(leaf: PagedKVCache):
        assert leaf.quantized
        stacked = leaf.k_fp.ndim == 5
        groups = range(leaf.k_fp.shape[0]) if stacked else (None,)
        axis = 1 if stacked else 0
        # pull only the pages being frozen to host, not the whole pool
        jb = jnp.asarray(bids)
        kf = np.asarray(jnp.take(leaf.k_fp, jb, axis=axis))
        vf = np.asarray(jnp.take(leaf.v_fp, jb, axis=axis))
        kc, vc = leaf.k_codes, leaf.v_codes
        kcb, vcb = leaf.k_cb, leaf.v_cb
        kfp, vfp = leaf.k_fp, leaf.v_fp
        for g in groups:
            sel = () if g is None else (g,)
            for pool, tag in ((kf, "k"), (vf, "v")):
                new_codes, new_cbs, new_recon = [], [], []
                for bi in range(len(bids)):
                    codes, cb = quantize_page(pool[sel + (bi,)], spec)
                    if stats is not None:
                        stats["host_page_solves"] = (
                            stats.get("host_page_solves", 0) + 1)
                    new_recon.append(cb[codes])
                    if leaf.packed:
                        codes = _pack4(codes)
                    new_codes.append(codes)
                    new_cbs.append(cb)
                nc = jnp.asarray(np.stack(new_codes))
                ncb = jnp.asarray(np.stack(new_cbs))
                # materialize the reconstruction into the fp rows so the
                # gather read path serves quantized values at plain-fp cost
                nr = jnp.asarray(np.stack(new_recon), leaf.k_fp.dtype)
                if tag == "k":
                    kc = kc.at[sel + (bids,)].set(nc)
                    kcb = kcb.at[sel + (bids,)].set(ncb)
                    kfp = kfp.at[sel + (bids,)].set(nr)
                else:
                    vc = vc.at[sel + (bids,)].set(nc)
                    vcb = vcb.at[sel + (bids,)].set(ncb)
                    vfp = vfp.at[sel + (bids,)].set(nr)
        blk_q = leaf.blk_q.at[..., bids].set(True)
        return dataclasses.replace(leaf, k_fp=kfp, v_fp=vfp, k_codes=kc,
                                   v_codes=vc, k_cb=kcb, v_cb=vcb,
                                   blk_q=blk_q)

    return map_layers(per, tree)


def thaw_blocks(tree, block_ids):
    """Clear the quantized flag for freed pages (reallocation starts fp)."""
    if not len(block_ids):
        return tree
    bids = np.asarray(sorted(block_ids), np.int32)

    def per(leaf: PagedKVCache):
        if not leaf.quantized:
            return leaf
        return dataclasses.replace(leaf,
                                   blk_q=leaf.blk_q.at[..., bids].set(False))

    return map_layers(per, tree)


# ----------------------------------------------- footprint accounting


def page_bytes(cfg, block_size: int, *, quantized: bool, num_values: int,
               n_layers_attn: int | None = None) -> dict:
    """Bytes one page costs across all attention layers, fp vs frozen."""
    n_attn = (n_layers_attn if n_layers_attn is not None
              else sum(1 for s in (tuple(cfg.head_layers)
                                   + tuple(cfg.group) * cfg.n_groups)
                       if s.mixer == "attn"))
    elems = block_size * cfg.n_kv_heads * cfg.head_dim
    fp = 2 * elems * cfg.dtype("compute").itemsize          # k and v
    if not quantized:
        return {"fp": n_attn * fp, "frozen": n_attn * fp, "n_attn": n_attn}
    bits = 4 if num_values <= 16 else 8
    frozen = 2 * ((elems * bits + 7) // 8 + num_values * 4)
    return {"fp": n_attn * fp, "frozen": n_attn * frozen, "n_attn": n_attn}
