"""Paged KV cache: fixed-size blocks, a free-list allocator, per-sequence
block tables, and optional codebook-quantized pages.

Layout (per attention layer, leading group axis added by the stacked model
cache exactly like ``transformer.init_lm_cache``):

  k_fp/v_fp     (nb, bs, Hkv, Dh)  fp pages — the write-hot pool; every
                token lands here first.
  k_codes/...   (nb, bs, Hkv, Dc)  uint8 codes for quantized pages
                (Dc = Dh/2 when two 4-bit codes pack per byte).
  k_cb/v_cb     (nb, L) f32        per-block codebooks from the paper's
                solvers (kmeans_ls / tv via repro.core.quantize).
  blk_q         (nb,) bool         page i is served from codes, not fp.
  block_table   (B, mb) int32      per-sequence page ids (0 = null page).
  seq_lens      (B,) int32         per-sequence lengths (write positions).

Block 0 is reserved as the null page: idle batch slots point every table
entry at it, so their (masked) decode writes land in the trash instead of a
live page.

Writes always go to the fp pool inside the jitted step; the engine freezes
a page once it is full by running the paper's quantizer on the host and
scattering codes + codebook back (``quantize_page`` / ``freeze_blocks``).
Reads overlay: pages flagged in ``blk_q`` dequantize ``cb[codes]``, the
rest gather fp — so the hot (partial) page stays exact while cold context
crosses HBM at ~4 bits/value.

``PagedKVCache.update`` implements the adapter protocol of
``repro.models.cache``; model code never learns about pages.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------------- allocator


class BlockAllocator:
    """Host-side free-list page allocator. Block 0 is never handed out."""

    def __init__(self, num_blocks: int):
        assert num_blocks >= 2, "need at least one allocatable block"
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> low ids first
        self._used: set[int] = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise MemoryError(f"asked {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._used.update(out)
        return out

    def free(self, ids) -> None:
        for b in ids:
            if b not in self._used:
                raise ValueError(f"double free / foreign block {b}")
            self._used.remove(b)
            self._free.append(b)


# ------------------------------------------------------------- paged cache


def _pack4(codes: np.ndarray) -> np.ndarray:
    """Two 4-bit codes per byte along the last dim (must be even)."""
    lo, hi = codes[..., 0::2], codes[..., 1::2]
    return (lo | (hi << 4)).astype(np.uint8)


def _unpack4(packed: jax.Array) -> jax.Array:
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1],
                                                packed.shape[-1] * 2)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """One attention layer's paged KV pools + this batch's table view."""

    k_fp: jax.Array
    v_fp: jax.Array
    k_codes: jax.Array
    v_codes: jax.Array
    k_cb: jax.Array
    v_cb: jax.Array
    blk_q: jax.Array
    block_table: jax.Array
    seq_lens: jax.Array
    # static
    block_size: int
    quantized: bool
    packed: bool

    _LEAVES = ("k_fp", "v_fp", "k_codes", "v_codes", "k_cb", "v_cb",
               "blk_q", "block_table", "seq_lens")
    _POOL_LEAVES = ("k_fp", "v_fp", "k_codes", "v_codes", "k_cb", "v_cb",
                    "blk_q")

    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in self._LEAVES),
                (self.block_size, self.quantized, self.packed))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # ---------------------------------------------- adapter protocol

    def update(self, k, v, cache_index):
        """Write k/v (B,S,Hkv,Dh) at per-sequence positions; gather pages.

        cache_index (the ring-cache scalar) is ignored: this cache carries
        its own per-sequence lengths.
        """
        del cache_index
        B, S, Hkv, Dh = k.shape
        bs = self.block_size
        pos = self.seq_lens[:, None] + jnp.arange(S)[None]          # (B,S)
        blk = jnp.take_along_axis(self.block_table, pos // bs, axis=1)
        off = pos % bs
        new = dataclasses.replace(
            self,
            k_fp=self.k_fp.at[blk.reshape(-1), off.reshape(-1)].set(
                k.reshape(B * S, Hkv, Dh).astype(self.k_fp.dtype)),
            v_fp=self.v_fp.at[blk.reshape(-1), off.reshape(-1)].set(
                v.reshape(B * S, Hkv, Dh).astype(self.v_fp.dtype)),
        )
        k_all = new._gather(new.k_fp, new.k_codes, new.k_cb)
        v_all = new._gather(new.v_fp, new.v_codes, new.v_cb)
        return new, k_all, v_all, self.seq_lens, self.seq_lens + S

    def _gather(self, fp, codes, cb):
        """Pages for this batch: (B, mb*bs, Hkv, Dh), dequantizing frozen
        pages from their per-block codebooks."""
        t = self.block_table                                # (B, mb)
        B, mb = t.shape
        pages = fp[t]                                       # (B,mb,bs,H,D)
        if self.quantized:
            c = codes[t]                                    # (B,mb,bs,H,Dc)
            if self.packed:
                c = _unpack4(c)
            c = c.astype(jnp.int32)
            deq = jnp.take_along_axis(
                cb[t], c.reshape(B, mb, -1), axis=-1).reshape(c.shape)
            frozen = self.blk_q[t][:, :, None, None, None]
            pages = jnp.where(frozen, deq.astype(pages.dtype), pages)
        nb, bs, H, D = fp.shape
        return pages.reshape(B, mb * bs, H, D)


def init_paged_layer(cfg, *, num_blocks, block_size, batch, max_blocks,
                     quantized, num_values, dtype) -> PagedKVCache:
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    packed = quantized and num_values <= 16
    assert Dh % 2 == 0 or not packed
    Dc = Dh // 2 if packed else Dh
    cshape = (num_blocks, block_size, Hkv, Dc) if quantized else (1, 1, 1, 1)
    cbshape = (num_blocks, num_values) if quantized else (1, 1)
    return PagedKVCache(
        k_fp=jnp.zeros((num_blocks, block_size, Hkv, Dh), dtype),
        v_fp=jnp.zeros((num_blocks, block_size, Hkv, Dh), dtype),
        k_codes=jnp.zeros(cshape, jnp.uint8),
        v_codes=jnp.zeros(cshape, jnp.uint8),
        k_cb=jnp.zeros(cbshape, jnp.float32),
        v_cb=jnp.zeros(cbshape, jnp.float32),
        blk_q=jnp.zeros((num_blocks if quantized else 1,), bool),
        block_table=jnp.zeros((batch, max_blocks), jnp.int32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
        block_size=block_size, quantized=quantized, packed=packed,
    )


def init_paged_cache(cfg, *, num_blocks, block_size, batch, max_blocks,
                     quantized=False, num_values=16):
    """Model-shaped cache tree mirroring ``transformer.init_lm_cache`` with
    PagedKVCache leaves (leading group axis on scanned groups)."""
    for spec in tuple(cfg.group) + tuple(cfg.head_layers):
        assert spec.mixer == "attn", (
            f"paged serving supports attention mixers only, got {spec.mixer}")
    dtype = cfg.dtype("compute")
    kw = dict(num_blocks=num_blocks, block_size=block_size, batch=batch,
              max_blocks=max_blocks, quantized=quantized,
              num_values=num_values, dtype=dtype)

    def stack(_spec):
        one = init_paged_layer(cfg, **kw)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape).copy(),
            one)

    cache = {"groups": {f"l{i}": stack(s) for i, s in enumerate(cfg.group)}}
    for i, spec in enumerate(cfg.head_layers):
        cache[f"head_{i}"] = init_paged_layer(cfg, **kw)
    return cache


# ----------------------------------------------- tree-surgery helpers


def _is_leaf(x):
    return isinstance(x, PagedKVCache)


def map_layers(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_leaf)


def with_tables(tree, block_table: np.ndarray, seq_lens: np.ndarray):
    """Install host-managed table/lens into every layer leaf (broadcast over
    the stacked group axis when present)."""
    bt = jnp.asarray(block_table, jnp.int32)
    sl = jnp.asarray(seq_lens, jnp.int32)

    def per(leaf: PagedKVCache):
        g = leaf.k_fp.ndim == 5            # stacked group axis present
        G = leaf.k_fp.shape[0] if g else None
        b = jnp.broadcast_to(bt, (G,) + bt.shape).copy() if g else bt
        s = jnp.broadcast_to(sl, (G,) + sl.shape).copy() if g else sl
        return dataclasses.replace(leaf, block_table=b, seq_lens=s)

    return map_layers(per, tree)


def merge_pools(held, returned):
    """Adopt jit-updated fp pools; keep host-managed quantization state and
    tables from ``held``."""
    return jax.tree_util.tree_map(
        lambda h, r: dataclasses.replace(h, k_fp=r.k_fp, v_fp=r.v_fp),
        held, returned, is_leaf=_is_leaf)


# ----------------------------------------------- host-side quantization


def quantize_page(data: np.ndarray, method: str, num_values: int):
    """Run the paper's solver on one page; returns (codes u8, codebook f32).

    method "tv" maps to the exact-count tv_iter (tv itself is
    lam-parameterised).
    """
    from repro.core import quantize

    m = {"tv": "tv_iter"}.get(method, method)
    qt, _ = quantize(data.astype(np.float32), method=m,
                     num_values=num_values, weighted=True)
    cb = np.asarray(qt.codebook, np.float32)
    codes = np.asarray(qt.indices, np.uint8).reshape(data.shape)
    if cb.shape[0] < num_values:                    # pad to the static width
        cb = np.concatenate([cb, np.full(num_values - cb.shape[0], cb[-1],
                                         np.float32)])
    return codes, cb


def freeze_blocks(tree, block_ids, *, method="kmeans_ls", num_values=16):
    """Quantize full pages ``block_ids`` in every attention layer (host side,
    between engine steps) and scatter codes/codebooks/flags back."""
    if not block_ids:
        return tree
    bids = np.asarray(sorted(block_ids), np.int32)

    def per(leaf: PagedKVCache):
        assert leaf.quantized
        stacked = leaf.k_fp.ndim == 5
        groups = range(leaf.k_fp.shape[0]) if stacked else (None,)
        axis = 1 if stacked else 0
        # pull only the pages being frozen to host, not the whole pool
        jb = jnp.asarray(bids)
        kf = np.asarray(jnp.take(leaf.k_fp, jb, axis=axis))
        vf = np.asarray(jnp.take(leaf.v_fp, jb, axis=axis))
        kc, vc = leaf.k_codes, leaf.v_codes
        kcb, vcb = leaf.k_cb, leaf.v_cb
        for g in groups:
            sel = () if g is None else (g,)
            for pool, tag in ((kf, "k"), (vf, "v")):
                new_codes, new_cbs = [], []
                for bi in range(len(bids)):
                    codes, cb = quantize_page(pool[sel + (bi,)], method,
                                              num_values)
                    if leaf.packed:
                        codes = _pack4(codes)
                    new_codes.append(codes)
                    new_cbs.append(cb)
                nc = jnp.asarray(np.stack(new_codes))
                ncb = jnp.asarray(np.stack(new_cbs))
                if tag == "k":
                    kc = kc.at[sel + (bids,)].set(nc)
                    kcb = kcb.at[sel + (bids,)].set(ncb)
                else:
                    vc = vc.at[sel + (bids,)].set(nc)
                    vcb = vcb.at[sel + (bids,)].set(ncb)
        blk_q = leaf.blk_q.at[..., bids].set(True)
        return dataclasses.replace(leaf, k_codes=kc, v_codes=vc,
                                   k_cb=kcb, v_cb=vcb, blk_q=blk_q)

    return map_layers(per, tree)


def thaw_blocks(tree, block_ids):
    """Clear the quantized flag for freed pages (reallocation starts fp)."""
    if not block_ids:
        return tree
    bids = np.asarray(sorted(block_ids), np.int32)

    def per(leaf: PagedKVCache):
        if not leaf.quantized:
            return leaf
        return dataclasses.replace(leaf,
                                   blk_q=leaf.blk_q.at[..., bids].set(False))

    return map_layers(per, tree)


# ----------------------------------------------- footprint accounting


def page_bytes(cfg, block_size: int, *, quantized: bool, num_values: int,
               n_layers_attn: int | None = None) -> dict:
    """Bytes one page costs across all attention layers, fp vs frozen."""
    n_attn = (n_layers_attn if n_layers_attn is not None
              else sum(1 for s in (tuple(cfg.head_layers)
                                   + tuple(cfg.group) * cfg.n_groups)
                       if s.mixer == "attn"))
    elems = block_size * cfg.n_kv_heads * cfg.head_dim
    fp = 2 * elems * cfg.dtype("compute").itemsize          # k and v
    if not quantized:
        return {"fp": n_attn * fp, "frozen": n_attn * fp, "n_attn": n_attn}
    bits = 4 if num_values <= 16 else 8
    frozen = 2 * ((elems * bits + 7) // 8 + num_values * 4)
    return {"fp": n_attn * fp, "frozen": n_attn * frozen, "n_attn": n_attn}
