"""Role-based serving workers: model execution split into composable
prefill and decode roles.

``DecodeWorker`` owns a paged KV pool and the decode hot loop — iteration
batching over its slots, async page freezing (batched sparse-LSQ device
solves, rate-limited per decode step), eviction/recycling — behind a narrow
``step()`` / ``attach()`` interface. Sequences enter it only as finished
prefills (``transfer.FinishedPrefill``): pages are spliced into its pool
and decoding continues from the already-sampled first token.

``PrefillWorker`` turns queued prompts into finished prefills. It runs in
one of two compositions:

  owned pool (disaggregated)   The worker prefills into its *own* paged
      pool, then extracts the pages as a migration payload — fp rows, or
      codes + codebooks when migrating frozen — and frees its blocks. The
      dispatch is async: ``step()`` launches the prefill (and, for frozen
      migration, the freeze solve chained behind it) and only harvests once
      the device finished, so a long prompt never blocks the caller's loop.

  borrowed pool (colocated)    Constructed with ``pool=<DecodeWorker>``,
      the worker prefills straight into the decode worker's pool using
      blocks from its allocator; the handoff payload is a no-op "splice"
      carrying just the block ids. This is exactly the old monolithic
      engine's inline prefill, now expressed as the degenerate worker
      composition.

Both engines (`engine.ContinuousBatchingEngine`, `engine.DisaggEngine`)
are thin run loops over these two roles plus a scheduler/router.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.obs.export import (modeled_decode_hbm_bytes,
                              modeled_prefill_hbm_bytes)
from repro.obs.trace import NULL_TRACER

from .kv_cache import (BlockAllocator, PrefixIndex, dispatch_freeze,
                       freeze_blocks, init_paged_cache, install_freeze,
                       merge_pools, page_bytes, thaw_blocks,
                       with_prefill_fused, with_tables)
from .scheduler import ContinuousBatchingScheduler, Request, SeqState
from .speculative import DraftWorker, window_step
from .overload import ResumeEntry
from .transfer import (FinishedPrefill, PagePayload, extract_pages,
                       extract_resident_pages, splice_payload)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill_step(params, toks, tree, *, cfg):
    return models.prefill(params, cfg, {"tokens": toks}, tree)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill_chunk_step(params, toks, pos, tree, *, cfg):
    # positions are explicit (off + arange(C), same 2-D form lm_prefill
    # derives itself) so a chunk at token offset ``off`` ropes/masks exactly
    # as the matching slice of a single whole-prompt prefill
    return models.prefill(params, cfg, {"tokens": toks, "positions": pos},
                          tree)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _decode_step_fn(params, toks, tree, lens, *, cfg):
    return models.decode_step(params, cfg, toks, tree, lens)


def sample_token(row: np.ndarray, *, temperature: float = 0.0,
                 top_k: int = 0, rng=None) -> int:
    """Engine-level sampling over one vocab row of logits.

    temperature <= 0 is greedy argmax (the default and the path every
    logit-replay verification runs); otherwise softmax at ``temperature``
    over the ``top_k`` largest logits (0 = no truncation), drawn from the
    request's own Generator so traces replay deterministically per seed.
    """
    if temperature <= 0.0 or rng is None:
        return int(np.argmax(row))
    logits = np.asarray(row, np.float64) / temperature
    if 0 < top_k < logits.size:
        kth = np.partition(logits, -top_k)[-top_k]
        logits = np.where(logits >= kth, logits, -np.inf)
    p = np.exp(logits - logits.max())
    return int(rng.choice(logits.size, p=p / p.sum()))


class _Slot:
    """Decode-worker per-slot state (token io + page bookkeeping)."""

    def __init__(self):
        self.rid = None
        self.blocks: list[int] = []
        self.frozen_upto = 0          # block-table slots already quantized
        self.last_token = 0
        self.out: list[int] = []
        self.logits: list[np.ndarray] = []
        self.rng = None
        self.temperature = 0.0
        self.top_k = 0


class DecodeWorker:
    """The decode role: paged pool + iteration-batched decode loop + async
    freeze machinery, fed through ``attach(seq_state, finished_prefill)``.
    """

    def __init__(self, params, cfg, *, worker_id: int = 0, max_slots: int = 8,
                 block_size: int = 16, max_seq_len: int = 256,
                 num_blocks: int | None = None, kv_spec=None,
                 attn_impl: str = "gather", freeze_async: bool = True,
                 freeze_page_budget: int = 4, max_queue: int = 256,
                 eos_id: int | None = None, record_logits: bool = False,
                 speculate: int = 0, draft: tuple | None = None,
                 metrics=None, outputs=None, request_logits=None,
                 tracer=None, roofline_gauges: bool = False,
                 prefix_cache: bool = False):
        from .metrics import MetricsCollector

        self.worker_id = worker_id
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # compute per-step modeled HBM gauges even when tracing is off
        # (a metrics exporter wants them); pure-NullTracer runs skip the
        # host walk entirely
        self.roofline_gauges = roofline_gauges
        self._trk_decode = f"decode/w{worker_id}"
        self._trk_freeze = f"freeze/w{worker_id}"
        self._trk_spec = f"spec/w{worker_id}"
        self.params, self.cfg = params, cfg
        self.kv_spec = kv_spec
        self.attn_impl = attn_impl
        self.block_size = block_size
        self.max_blocks = -(-max_seq_len // block_size)
        self.max_seq_len = self.max_blocks * block_size
        self.num_blocks = (num_blocks if num_blocks is not None
                           else max_slots * self.max_blocks + 1)
        self.freeze_async = (freeze_async and kv_spec is not None
                             and kv_spec.device_capable)
        assert freeze_page_budget >= 1, "freeze budget must cover >= 1 page"
        self.freeze_page_budget = freeze_page_budget
        self.eos_id = eos_id
        self.record_logits = record_logits
        assert speculate >= 0
        if speculate:
            if draft is None:
                raise ValueError("speculate=k needs draft=(params, cfg) — "
                                 "see serving.speculative.derive_draft")
            draft_params, draft_cfg = draft
            if draft_cfg.vocab != cfg.vocab:
                raise ValueError(
                    f"draft vocab {draft_cfg.vocab} != target {cfg.vocab}; "
                    f"speculative verify compares token ids directly")
            if attn_impl == "fused" and speculate + 1 > block_size:
                raise ValueError(
                    f"speculate={speculate} verify window exceeds block "
                    f"size {block_size}; the fused-window gate would also "
                    f"catch prefill steps")
        self.speculate = speculate

        self.tree = init_paged_cache(
            cfg, num_blocks=self.num_blocks, block_size=block_size,
            batch=max_slots, max_blocks=self.max_blocks,
            quantized=kv_spec is not None,
            num_values=16 if kv_spec is None else kv_spec.num_values,
            fused=attn_impl == "fused", fused_window=speculate + 1)
        self.alloc = BlockAllocator(self.num_blocks)
        # `lookahead` reserves the verify window's optimistic write rows
        # past max_new_tokens in worst-case page accounting
        self.sched = ContinuousBatchingScheduler(
            max_slots=max_slots, block_size=block_size, max_queue=max_queue,
            lookahead=speculate)
        self.draft = None if not speculate else DraftWorker(
            draft[0], draft[1], max_slots=max_slots, block_size=block_size,
            max_blocks=self.max_blocks, worker_id=worker_id,
            tracer=self.tracer)
        self.metrics = metrics if metrics is not None else MetricsCollector()
        self.table = np.zeros((max_slots, self.max_blocks), np.int32)
        self.lens = np.zeros((max_slots,), np.int32)
        self.slots = [_Slot() for _ in range(max_slots)]
        self.outputs = outputs if outputs is not None else {}
        self.request_logits = (request_logits if request_logits is not None
                               else {})
        self._pb = page_bytes(cfg, block_size, quantized=kv_spec is not None,
                              num_values=16 if kv_spec is None
                              else kv_spec.num_values)
        # freeze/decode overlap + migration accounting; host_page_solves
        # counts fallback per-page numpy solves (0 in the device-solver
        # steady state), freeze_deferred_pages counts pages pushed past
        # their iteration by the per-step freeze budget.
        self.counters = {"freeze_dispatches": 0, "freeze_installs": 0,
                         "host_page_solves": 0, "decode_steps": 0,
                         "seq_decode_steps": 0,
                         "freeze_inflight_steps": 0, "freeze_overlap_steps": 0,
                         "freeze_pending_max": 0, "freeze_deferred_pages": 0,
                         "max_gather_blocks": 0, "migrated_seqs": 0,
                         "migrated_pages": 0, "migrate_bytes": 0,
                         "migrate_fp_equiv_bytes": 0,
                         # overload survival: whole-sequence evictions and
                         # the host-tier traffic they caused
                         "preemptions": 0, "preempt_offloads": 0,
                         "preempt_recomputes": 0, "offloaded_pages": 0,
                         "offload_bytes": 0, "offload_fp_equiv_bytes": 0,
                         "restored_seqs": 0, "restored_pages": 0,
                         "restore_bytes": 0,
                         # prefix sharing: attaches that matched a published
                         # prefix run, the pages they spliced instead of
                         # prefilling, and write-hot tail pages materialized
                         # privately instead of shared (copy-on-write)
                         "prefix_hits": 0, "prefix_shared_pages": 0,
                         "cow_copies": 0}
        # radix/hash prefix index over installed-frozen (or, unquantized,
        # sequence-passed) full prompt pages; sequences attach published
        # pages at rc > 1 instead of re-prefilling them
        self.prefix = PrefixIndex(block_size) if prefix_cache else None
        self._pending_freezes: list[tuple[int, object]] = []
        self._freeze_bids: list[int] = []   # queued for the next flush
        self._deferred_seen = 0    # queue suffix already counted deferred
        self._frozen_pages: set[int] = set()   # installed (codes serving)
        # freeze-lifecycle async spans: page id -> open span id. A span
        # opens when a bid is queued and MUST end in exactly one terminal
        # state — installed / dropped (seq finished first) / rolled_back
        # (speculative suffix rejected) — which the obs property test
        # checks against the dispatch/install counters.
        self._page_spans: dict[int, int] = {}
        self._span_seq = 0
        # overload machinery: per-slot LRU signal (decode step the slot
        # last attended) and, for recompute-path preemptions, the tokens
        # already emitted under the request's first life — merged back
        # into ``outputs`` when the resumed request finishes
        self.last_attended: dict[int, int] = {}
        self._resume_prefix: dict[int, tuple[list, list]] = {}

        # module-level jit keyed on the (hashable) config: workers of the
        # same geometry share compiles instead of retracing per instance
        self._decode_fn = functools.partial(_decode_step_fn, cfg=cfg)
        self._verify_fn = functools.partial(window_step, cfg=cfg)

    # ------------------------------------------------------------ intake

    def fits(self, req: Request) -> bool:
        """Whether this worker could EVER hold the request (sequence
        budget and whole page pool) — the never-admit door. Admitting a
        request that fails this would head-of-line-block the queue
        forever."""
        return not (req.prompt_len + req.max_new_tokens + self.speculate
                    > self.max_seq_len
                    or self.sched.blocks_for(req) > self.num_blocks - 1)

    def submit(self, req: Request, now: float) -> bool:
        """Colocated front door: admission control + queueing + arrival
        metric (the disaggregated router does this globally instead)."""
        if not self.fits(req):
            self.sched.rejected.append(req.id)
            self.metrics.admission("rejected_pool_full")
            return False
        ok = self.sched.submit(req)
        if ok:
            self.metrics.arrival(req.id, now, req.prompt_len)
        else:
            self.metrics.admission("rejected_queue_full")
        return ok

    def can_accept(self, req: Request) -> bool:
        """Router probe: a free slot and the request's worst-case pages."""
        return (bool(self.sched._free_slots)
                and self.sched.blocks_for(req) <= self.alloc.num_free)

    @property
    def free_slots(self) -> int:
        return len(self.sched._free_slots)

    @property
    def has_work(self) -> bool:
        return bool(self.sched.active or self._pending_freezes
                    or self._freeze_bids)

    # ------------------------------------------------------------ import

    def attach(self, st: SeqState, fin: FinishedPrefill, now: float) -> None:
        """Splice a finished prefill's pages into this worker's pool and
        start decoding it at slot ``st.slot``.

        "splice" payloads (colocated) carry block ids already living in
        this pool; migration payloads allocate the request's worst-case
        blocks here, land the prompt pages in the first of them (frozen
        pages through ``install_freeze``, directly servable by the fused
        kernel), and the rest fill during decode.
        """
        req, s = st.req, self.slots[st.slot]
        payload = fin.payload
        if payload.mode == "splice":
            blocks = list(payload.blocks)
        else:
            blocks = self.alloc.alloc(self.sched.blocks_for(req))
            self.tree = splice_payload(self.tree, payload, blocks,
                                       tracer=self.tracer)
            self.counters["migrated_seqs"] += 1
            self.counters["migrated_pages"] += payload.n_pages
            self.counters["migrate_bytes"] += payload.nbytes
            self.counters["migrate_fp_equiv_bytes"] += payload.fp_equiv_bytes
        P = req.prompt_len
        s.rid, s.blocks = req.id, blocks
        s.out, s.logits = [fin.first_token], []
        s.last_token = fin.first_token
        s.rng, s.temperature, s.top_k = fin.rng, req.temperature, req.top_k
        if self.record_logits and fin.last_logits is not None:
            s.logits.append(fin.last_logits)
        self.table[st.slot] = 0
        self.table[st.slot, :len(blocks)] = blocks
        self.lens[st.slot] = P
        st.length, st.generated = P, 1
        # a fresh attach is the coldest possible preemption candidate at
        # the current step — seed the LRU signal so pick_victim can see it
        # before its first decode step
        self.last_attended[st.slot] = self.counters["decode_steps"]
        if payload.mode == "frozen" and payload.n_full:
            # pages landed as codes+codebooks: already frozen, never queue
            # them for a second solve
            s.frozen_upto = payload.n_full
            self._frozen_pages.update(int(b)
                                      for b in blocks[:payload.n_full])
        else:
            # a shared prefix splices installed-frozen pages: they start
            # the frozen watermark, so they are never queued for a second
            # solve (unquantized pools share exact-fp pages; the watermark
            # stays 0 because nothing ever freezes)
            s.frozen_upto = (payload.shared_pages
                             if self.kv_spec is not None else 0)
            self._queue_freeze(st.slot)
        if self.draft is not None:
            # the draft prefills the same prompt on its own pool (cheap:
            # the draft config is the reduced one) and mirrors this slot
            self.draft.attach(st.slot, req.prompt, len(blocks))
        self._publish_prefixes()
        if st.done or fin.first_token == self.eos_id:
            self._finish(st, now)

    # ------------------------------------------------------ prefix sharing

    def _publish_prefixes(self) -> None:
        """(Re)publish every active slot's eligible full prompt pages into
        the prefix index. Quantized pools publish only installed-frozen
        pages (immutable reconstructions); unquantized pools publish every
        full prompt page — prompt rows never rewrite once the sequence's
        length passes them, so sharing them is bitwise-exact. Idempotent
        (the index dedupes on chain key), so calling after every attach /
        install keeps the index current without per-page bookkeeping."""
        if self.prefix is None:
            return
        frozen = self._frozen_pages if self.kv_spec is not None else None
        for i in self.sched.active_slots():
            st = self.sched.active[i]
            self.prefix.publish(st.req.prompt, self.slots[i].blocks, frozen)

    def shared_prefix_pages(self, slot: int) -> int:
        """Length of the slot's leading page run other sequences also
        reference (rc > 1). Sharing only ever splices *prefix* runs of
        published chains, so refcounts are monotone non-increasing along
        the table — the first rc == 1 page ends the run. Used by preemption
        to scope a victim's payload to pages it exclusively owns."""
        if self.prefix is None:
            return 0
        n = 0
        for b in self.slots[slot].blocks:
            if self.alloc.refcount(int(b)) <= 1:
                break
            n += 1
        return n

    def prefix_probe(self, req: Request) -> int:
        """Scheduler admission discount: pages of ``req``'s prompt already
        published (lookup only — no retain). Admission can charge the
        request worst-case-minus-shareable pages because its prefill will
        splice exactly these pages instead of allocating fresh ones."""
        if self.prefix is None:
            return 0
        return len(self.prefix.lookup(req.prompt,
                                      (req.prompt_len - 1) // self.block_size))

    # ------------------------------------------------------------ steps

    def step(self, now_fn) -> None:
        """One engine iteration over this worker: flush queued freezes
        (budgeted), one batched decode step, occupancy sample.

        With no live sequences the decode step is skipped but pending
        freezes are still polled — an async solve outliving its sequences
        must land (or be dropped) here, or a run loop keyed on
        ``has_work`` would wait on it forever."""
        self._flush_freezes()
        if self.sched.active_slots():
            if self.speculate:
                self._spec_decode_step(now_fn)
            else:
                self._decode_step(now_fn)
        else:
            self._poll_freezes()
        self._sample_cache()

    def _decode_step(self, now_fn) -> None:
        active = self.sched.active_slots()
        if not active:
            return
        tr = self.tracer
        t_step = tr.now()
        self.counters["decode_steps"] += 1
        self.counters["seq_decode_steps"] += len(active)
        self._poll_freezes()
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].last_token
        # gather only the blocks the longest live sequence needs this step
        # (idle slots sit at length 0); retraces are bounded by max_blocks
        need = int(self.lens.max()) + 1
        mb_used = max(1, -(-need // self.block_size))
        self.counters["max_gather_blocks"] = max(
            self.counters["max_gather_blocks"], mb_used)
        t0 = tr.now()
        tree = with_tables(self.tree, self.table[:, :mb_used], self.lens)
        lens = jnp.asarray(self.lens)
        logits, new = self._decode_fn(self.params, jnp.asarray(toks), tree,
                                      lens)
        self.tree = merge_pools(self.tree, new)
        tr.complete(self._trk_decode, "dispatch", t0, blocks=mb_used)
        t0 = tr.now()
        # lint: sync(intentional step-end token sync for the scheduler)
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        sampling = any(self.slots[i].temperature > 0.0 for i in active)
        # lint: sync(host sampling/record path needs this step's logit row)
        rows = (np.asarray(logits[:, -1])
                if self.record_logits or sampling else None)
        tr.complete(self._trk_decode, "sync", t0)
        t0 = tr.now()
        now = now_fn()
        finished = []
        for i in active:
            st = self.sched.active[i]
            s = self.slots[i]
            self.last_attended[i] = self.counters["decode_steps"]
            self.lens[i] += 1
            st.length += 1
            st.generated += 1
            s.last_token = (sample_token(rows[i], temperature=s.temperature,
                                         top_k=s.top_k, rng=s.rng)
                            if s.temperature > 0.0 else int(nxt[i]))
            s.out.append(s.last_token)
            if self.record_logits:
                s.logits.append(rows[i])
            self.metrics.token(st.req.id, now)
            self._queue_freeze(i)
            if st.done or s.last_token == self.eos_id:
                finished.append(st)
        for st in finished:
            self._finish(st, now)
        tr.complete(self._trk_decode, "commit", t0, finished=len(finished))
        tr.complete(self._trk_decode, "decode_step", t_step,
                    step=self.counters["decode_steps"], active=len(active))

    # ------------------------------------------------------- speculative

    def _spec_decode_step(self, now_fn) -> None:
        """One speculative iteration: k draft proposals per active slot,
        ONE batched verify window on the target over all k+1 positions,
        then per-slot accept/rollback.

        The verify pass writes all k+1 KV rows and this method advances
        ``lens`` (and queues page-freeze bids) *optimistically* before
        acceptance is known; ``_rollback_slot`` then shrinks every slot
        back to its accepted watermark, un-queueing bids for rolled-back
        pages. Bids flush at the *start* of the next ``step()``, so a bid
        queued here can never dispatch before its rollback — the invariant
        "no frozen page past the accepted seq_lens" holds at every step
        boundary. Every emitted token is the target's greedy argmax for
        its exact accepted context, so the trace is token-identical to
        non-speculative decoding by construction.
        """
        active = self.sched.active_slots()
        if not active:
            return
        tr = self.tracer
        t_step = tr.now()
        k = self.speculate
        W = k + 1
        self.counters["decode_steps"] += 1
        self.counters["seq_decode_steps"] += len(active)
        self._poll_freezes()
        t0 = tr.now()
        proposals = self.draft.propose(active, self.slots, k)
        tr.complete(self._trk_spec, "propose", t0, k=k, active=len(active))
        toks = np.zeros((len(self.slots), W), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].last_token
            toks[i, 1:] = proposals[i]
        # gather only the blocks the longest live sequence's window needs
        need = int(self.lens.max()) + W
        mb_used = max(1, -(-need // self.block_size))
        self.counters["max_gather_blocks"] = max(
            self.counters["max_gather_blocks"], mb_used)
        t0 = tr.now()
        tree = with_tables(self.tree, self.table[:, :mb_used], self.lens)
        logits, new = self._verify_fn(self.params, jnp.asarray(toks), tree,
                                      jnp.asarray(self.lens))
        self.tree = merge_pools(self.tree, new)
        # lint: sync(step-end verify sync: acceptance logic runs on host)
        preds = np.asarray(jnp.argmax(logits, -1))            # (B, W)
        tr.complete(self._trk_spec, "verify", t0, window=W,
                    active=len(active), blocks=mb_used)
        sampling = any(self.slots[i].temperature > 0.0 for i in active)
        assert not sampling, (
            "speculative decoding serves the greedy verification path; "
            "sampled requests need the non-speculative engine")
        # lint: sync(verification-only logit capture, off in production)
        rows = np.asarray(logits) if self.record_logits else None
        now = now_fn()
        finished = []
        for i in active:
            st = self.sched.active[i]
            s = self.slots[i]
            self.last_attended[i] = self.counters["decode_steps"]
            L = int(self.lens[i])
            # optimistic: all W rows written; advance + queue freezes as if
            # every draft were accepted, then roll back to the watermark
            self.lens[i] = L + W
            self._queue_freeze(i)
            n_acc = 0
            while n_acc < k and proposals[i][n_acc] == int(preds[i, n_acc]):
                n_acc += 1
            # row j of the verify logits is the target's next-token
            # distribution after [ctx, last, d1..dj]: accepted drafts are
            # emitted verbatim, and row n_acc supplies the correction (or
            # the bonus token when every draft survived) — uniformly its
            # argmax
            emitted = [int(t) for t in proposals[i][:n_acc]]
            emitted.append(int(preds[i, n_acc]))
            emitted = emitted[:st.req.max_new_tokens - st.generated]
            if self.eos_id is not None and self.eos_id in emitted:
                emitted = emitted[:emitted.index(self.eos_id) + 1]
            a = len(emitted)
            self.metrics.spec_step(k, min(n_acc, a), a < W)
            tr.instant(self._trk_spec, "accept", slot=i, rid=st.req.id,
                       proposed=k, accepted=min(n_acc, a), emitted=a)
            if a < W:
                tr.instant(self._trk_spec, "rollback", slot=i,
                           rid=st.req.id, to_len=L + a)
            self._rollback_slot(i, L + a)
            st.length = L + a
            st.generated += a
            for j, t in enumerate(emitted):
                s.out.append(t)
                if self.record_logits:
                    s.logits.append(rows[i, j])
                self.metrics.token(st.req.id, now)
            s.last_token = emitted[-1]
            self.draft.sync(i, L + a)
            if st.done or s.last_token == self.eos_id:
                finished.append(st)
        for st in finished:
            self._finish(st, now)
        tr.complete(self._trk_decode, "decode_step", t_step,
                    step=self.counters["decode_steps"], active=len(active),
                    window=W)

    def _rollback_slot(self, slot: int, new_len: int) -> None:
        """Shrink a slot to its accepted watermark ``new_len``: un-queue
        freeze bids for pages past it and drop them from any in-flight
        solve, so a rejected suffix can never leave a frozen page beyond
        the accepted ``seq_lens``. Rolled-back rows hold rejected drafts'
        KV — invisible to attention (masked past ``lens``) and rewritten
        in place by the next verify window before ``lens`` covers them."""
        s = self.slots[slot]
        full = int(new_len) // self.block_size
        if s.frozen_upto > full:
            stale = {int(self.table[slot, j])
                     for j in range(full, s.frozen_upto)}
            tr = self.tracer
            if tr.enabled:
                for b in sorted(stale):
                    sid = self._page_spans.pop(b, None)
                    if sid is not None:
                        tr.async_end(self._trk_freeze, "page_freeze", sid,
                                     state="rolled_back", page=b)
            self._freeze_bids = [b for b in self._freeze_bids
                                 if b not in stale]
            self._deferred_seen = min(self._deferred_seen,
                                      len(self._freeze_bids))
            for _, pending in self._pending_freezes:
                pending.drop(stale)
            s.frozen_upto = full
        self.lens[slot] = new_len

    # ------------------------------------------------------------ freezing

    def _poll_freezes(self, drain: bool = False) -> None:
        """Install completed freezes; count the ones still overlapping this
        decode step. drain=True blocks on the remainder (end of run)."""
        still = []
        installed_any = False
        for step0, pending in self._pending_freezes:
            if drain and not pending.is_ready():
                # lint: sync(drain-only: end-of-run flush blocks by design)
                jax.block_until_ready(pending.markers())
            if pending.is_ready():
                self.tree = install_freeze(self.tree, pending)
                kept = pending.kept_pages()
                self._frozen_pages.update(kept)
                installed_any = True
                self.counters["freeze_installs"] += 1
                self.counters["freeze_overlap_steps"] += (
                    self.counters["decode_steps"] - step0)
                tr = self.tracer
                if tr.enabled:
                    tr.instant(self._trk_freeze, "install", pages=len(kept),
                               wait_steps=self.counters["decode_steps"]
                               - step0)
                    for b in kept:
                        sid = self._page_spans.pop(b, None)
                        if sid is not None:
                            tr.async_end(self._trk_freeze, "page_freeze",
                                         sid, state="installed", page=b)
            else:
                self.counters["freeze_inflight_steps"] += 1
                still.append((step0, pending))
        self._pending_freezes = still
        if installed_any:
            # freshly installed pages just became shareable
            self._publish_prefixes()

    def _queue_freeze(self, slot: int) -> None:
        """Queue this sequence's just-filled pages for quantization; the
        worker iteration flushes the whole batch as ONE device dispatch
        (_flush_freezes), so slots whose pages fill at the same step share
        a solve."""
        if self.kv_spec is None:
            return
        s = self.slots[slot]
        full = int(self.lens[slot]) // self.block_size
        if full > s.frozen_upto:
            tr = self.tracer
            for j in range(s.frozen_upto, full):
                b = int(self.table[slot, j])
                # a shared page is already installed (or already bid by the
                # sequence that owns the solve) — never re-freeze: bids
                # dedupe on block id
                if b in self._frozen_pages or b in self._freeze_bids:
                    continue
                self._freeze_bids.append(b)
                if tr.enabled:
                    self._span_seq += 1
                    self._page_spans[b] = self._span_seq
                    tr.async_begin(self._trk_freeze, "page_freeze",
                                   self._span_seq, page=b, slot=slot)
            s.frozen_upto = full

    def _flush_freezes(self) -> None:
        """One batched solve for pages queued this iteration, rate-limited
        to ``freeze_page_budget`` pages per decode step.

        The budget is the backpressure valve: a prefill burst can queue a
        whole prompt's worth of full pages at once, and solving them as one
        chunk would run long enough to delay the next decode steps — the
        remainder flushes on later iterations (deferred pages keep serving
        exact fp until then, so correctness is unaffected) and
        ``freeze_deferred_pages`` counts how often the valve engaged."""
        if not self._freeze_bids:
            return
        tr = self.tracer
        t0 = tr.now()
        take = min(len(self._freeze_bids), self.freeze_page_budget)
        bids, self._freeze_bids = (self._freeze_bids[:take],
                                   self._freeze_bids[take:])
        if tr.enabled:
            for b in bids:
                sid = self._page_spans.get(b)
                if sid is not None:
                    tr.async_instant(self._trk_freeze, "page_freeze", sid,
                                     state="dispatched")
        # count each page's deferral once: the flush consumed ``take``
        # pages off the queue front (the oldest, hence any already-counted
        # ones first), so shrink the counted watermark by that before
        # counting what now remains beyond it as newly deferred
        self._deferred_seen = max(self._deferred_seen - take, 0)
        newly = len(self._freeze_bids) - self._deferred_seen
        if newly > 0:
            self.counters["freeze_deferred_pages"] += newly
        self._deferred_seen = len(self._freeze_bids)
        if self.kv_spec.device_capable:
            # pad to a power-of-two page count (repeating one page is a
            # no-op at install) so the jitted solver compiles a handful of
            # shapes instead of one per distinct flush size; the host
            # fallback solves per page, where a duplicate is pure waste
            bucket = 1 << (len(bids) - 1).bit_length()
            bids = bids + [bids[-1]] * (bucket - len(bids))
        if self.freeze_async:
            pending = dispatch_freeze(self.tree, bids, self.kv_spec)
            self._pending_freezes.append(
                (self.counters["decode_steps"], pending))
            self.counters["freeze_pending_max"] = max(
                self.counters["freeze_pending_max"],
                len(self._pending_freezes))
        else:
            self.tree = freeze_blocks(self.tree, bids, self.kv_spec,
                                      stats=self.counters)
            self._frozen_pages.update(bids)
            self._publish_prefixes()    # synchronous install: shareable now
            self.counters["freeze_installs"] += 1
            if tr.enabled:
                # synchronous install: the lifecycle terminates here
                for b in sorted(set(bids)):
                    sid = self._page_spans.pop(b, None)
                    if sid is not None:
                        tr.async_end(self._trk_freeze, "page_freeze", sid,
                                     state="installed", page=b)
        self.counters["freeze_dispatches"] += 1
        tr.complete(self._trk_freeze, "flush", t0, pages=take,
                    mode="async" if self.freeze_async else "sync")

    # ------------------------------------------------------------ teardown

    def _finish(self, st: SeqState, now: float) -> None:
        slot, s = st.slot, self.slots[st.slot]
        # a recompute-path resumption carries its first life's tokens as
        # prompt; stitch them back so the caller sees one output stream
        pre_out, pre_logits = self._resume_prefix.pop(st.req.id, ([], []))
        self.outputs[st.req.id] = pre_out + list(s.out)
        if self.record_logits and (pre_logits or s.logits):
            self.request_logits[st.req.id] = np.stack(pre_logits + s.logits)
        self.metrics.finish(st.req.id, now)
        # drop one reference per page; teardown side effects (span drops,
        # bid/frozen forgetting, thawing, index invalidation) scope to the
        # pages actually RELEASED — a shared prefix page another live table
        # still references keeps serving its frozen reconstruction
        released = set(self.alloc.free(s.blocks))
        if self.prefix is not None:
            self.prefix.invalidate(released)
        tr = self.tracer
        if tr.enabled:
            tr.instant(self._trk_decode, "finish", rid=st.req.id,
                       tokens=len(s.out))
            for b in sorted(released):
                sid = self._page_spans.pop(b, None)
                if sid is not None:
                    tr.async_end(self._trk_freeze, "page_freeze", sid,
                                 state="dropped", page=b)
        self._freeze_bids = [b for b in self._freeze_bids
                             if b not in released]
        self._deferred_seen = min(self._deferred_seen, len(self._freeze_bids))
        self._frozen_pages -= released
        for _, pending in self._pending_freezes:
            pending.drop(released)
        self.tree = thaw_blocks(self.tree, released)
        if self.draft is not None:
            self.draft.release(slot)
        self.table[slot] = 0
        self.lens[slot] = 0
        s.rid, s.blocks, s.frozen_upto, s.out = None, [], 0, []
        s.rng, s.temperature, s.top_k = None, 0.0, 0
        self.last_attended.pop(slot, None)
        self.sched.release(st)
        # the finisher may have been a chain's first publisher: invalidate
        # dropped its keys even though an identical live copy (a survivor's
        # own pages, same chain) may still be resident — re-publish so the
        # NEXT lookup (prefill dispatch precedes any attach) still matches
        self._publish_prefixes()

    # ------------------------------------------------------------ overload

    def preempt(self, st: SeqState, mode: str, now: float) -> ResumeEntry:
        """Evict a live sequence at a step boundary (overload pressure).

        mode "restore": demote its pages to a host payload via the
        "resident" extraction — installed-frozen pages cross as their
        existing packed codes + codebooks (bit-exact on re-install), the
        rest fp — for exact resumption later. mode "recompute": drop the
        pages and return a requeue request whose prompt is the original
        plus everything emitted; the re-prefill re-derives the KV (only
        chosen for unquantized greedy runs, where it is value-exact).

        The teardown mirrors ``_finish`` minus the output/latency events —
        the request stays live, only its residency changes — so every pool
        invariant (freeze watermark, conservation, pending-solve staleness)
        holds exactly as for a finished sequence. Open ``page_freeze``
        spans terminate ``offloaded`` / ``dropped`` per mode.
        """
        assert mode in ("restore", "recompute"), mode
        slot, s, req = st.slot, self.slots[st.slot], st.req
        assert not st.done and s.out, "preempt targets a live sequence"
        n_tok = int(self.lens[slot])
        tr = self.tracer
        self.counters["preemptions"] += 1
        if mode == "restore":
            # pages other live tables still reference are NOT demoted —
            # they stay resident serving those tables and this victim just
            # drops its ref below; the payload captures only the
            # exclusively-owned page suffix (frozen_idx relative to it)
            sh = self.shared_prefix_pages(slot)
            full = n_tok // self.block_size
            frozen_idx = [j - sh for j in range(sh, full)
                          if int(self.table[slot, j]) in self._frozen_pages]
            payload = extract_resident_pages(
                self.tree, s.blocks[sh:], n_tok - sh * self.block_size,
                frozen_idx, block_size=self.block_size, tracer=tr)
            t_host = tr.now()
            payload.to_host()
            tr.complete("transfer", "to_host", t_host, rid=req.id,
                        mode=payload.mode, bytes=payload.nbytes,
                        fp_equiv_bytes=payload.fp_equiv_bytes,
                        pages=payload.n_pages)
            entry = ResumeEntry(req=req, out=list(s.out),
                                generated=st.generated, n_tokens=n_tok,
                                rng=s.rng, logits=list(s.logits),
                                payload=payload, frozen_idx=frozen_idx,
                                shared_pages=sh)
            self.counters["preempt_offloads"] += 1
            self.counters["offloaded_pages"] += payload.n_pages
            self.counters["offload_bytes"] += payload.nbytes
            self.counters["offload_fp_equiv_bytes"] += payload.fp_equiv_bytes
            if tr.enabled:
                for j in range(payload.n_pages):
                    self._span_seq += 1
                    entry.span_ids[j] = self._span_seq
                    tr.async_begin(self._trk_freeze, "page_offload",
                                   self._span_seq, rid=req.id, page_pos=j)
        else:
            rem = req.max_new_tokens - st.generated
            resume = dataclasses.replace(
                req, prompt=tuple(req.prompt) + tuple(s.out),
                max_new_tokens=rem)
            pre_out, pre_logits = self._resume_prefix.get(req.id, ([], []))
            self._resume_prefix[req.id] = (pre_out + list(s.out),
                                           pre_logits + list(s.logits))
            entry = ResumeEntry(req=resume, out=list(s.out),
                                generated=st.generated, n_tokens=n_tok)
            self.counters["preempt_recomputes"] += 1
        tr.instant(self._trk_decode, "preempt", rid=req.id, slot=slot,
                   mode=mode, tokens=n_tok, pages=len(s.blocks))
        # ref-drop every page; only the RELEASED ones (last reference was
        # this victim's) tear down — a still-shared prefix page keeps its
        # frozen install and index entries for the sequences serving it
        released = set(self.alloc.free(s.blocks))
        if self.prefix is not None:
            self.prefix.invalidate(released)
        if tr.enabled:
            # literal per-branch states keep the page_freeze lifecycle
            # statically checkable (repro.analysis span pass)
            for b in sorted(released):
                sid = self._page_spans.pop(b, None)
                if sid is None:
                    continue
                if mode == "restore":
                    tr.async_end(self._trk_freeze, "page_freeze", sid,
                                 state="offloaded", page=b)
                else:
                    tr.async_end(self._trk_freeze, "page_freeze", sid,
                                 state="dropped", page=b)
        self._freeze_bids = [b for b in self._freeze_bids
                             if b not in released]
        self._deferred_seen = min(self._deferred_seen, len(self._freeze_bids))
        self._frozen_pages -= released
        for _, pending in self._pending_freezes:
            pending.drop(released)
        self.tree = thaw_blocks(self.tree, released)
        if self.draft is not None:
            self.draft.release(slot)
        self.table[slot] = 0
        self.lens[slot] = 0
        s.rid, s.blocks, s.frozen_upto, s.out, s.logits = None, [], 0, [], []
        s.rng, s.temperature, s.top_k = None, 0.0, 0
        self.last_attended.pop(slot, None)
        self.sched.release(st)
        # mirror _finish: re-register surviving duplicate chains whose keys
        # the invalidate above may have dropped with the victim's pages
        self._publish_prefixes()
        return entry

    def restore(self, st: SeqState, entry: ResumeEntry, now: float) -> None:
        """Re-install an offloaded sequence at slot ``st.slot`` and resume
        decoding exactly where it stopped.

        Restore-ahead: this runs at re-admission — before any decode
        window needs the pages — and the jit dataflow chains the next
        decode step behind the splice/install, so the resumed sequence is
        greedy-token-identical to one that never left. Frozen pages land
        through ``install_freeze`` (bit-exact codes), fp pages scatter
        verbatim; the stall the sequence suffered shows up honestly in its
        next inter-token gap."""
        req, s = st.req, self.slots[st.slot]
        tr = self.tracer
        m = entry.shared_pages
        shared: list[int] = []
        if m:
            t0 = tr.now()
            hit = (self.prefix.lookup(req.prompt, m)
                   if self.prefix is not None else [])
            if len(hit) == m:
                # the shared prefix survived the offload window: splice it
                # back at rc+1, exactly the pages this sequence decoded
                # against before eviction
                shared = [int(b) for b in hit]
                self.alloc.retain(shared)
                self.counters["prefix_hits"] += 1
                self.counters["prefix_shared_pages"] += m
                tr.complete(self._trk_decode, "prefix_match", t0,
                            rid=req.id, pages=m, cow=False)
            else:
                # its last referencer retired while this victim was
                # offloaded — rebuild privately (deterministic prefill +
                # deterministic freeze solver reproduce values identical to
                # the dead shared pages, so the resume stays token-exact)
                shared = self._rebuild_prefix(req, m)
        blocks = shared + self.alloc.alloc(self.sched.blocks_for(req) - m)
        self.tree = splice_payload(self.tree, entry.payload, blocks[m:],
                                   tracer=tr)
        s.rid, s.blocks = req.id, blocks
        s.out, s.logits = list(entry.out), list(entry.logits)
        s.last_token = entry.out[-1]
        s.rng, s.temperature, s.top_k = entry.rng, req.temperature, req.top_k
        self.table[st.slot] = 0
        self.table[st.slot, :len(blocks)] = blocks
        self.lens[st.slot] = entry.n_tokens
        st.length, st.generated = entry.n_tokens, entry.generated
        self.last_attended[st.slot] = self.counters["decode_steps"]
        self._frozen_pages.update(int(blocks[m + j])
                                  for j in entry.frozen_idx)
        # frozen_upto is the maximal frozen PREFIX; installs land in queue
        # order so the frozen set is a prefix in practice. If it ever
        # weren't, _queue_freeze would re-solve an already-frozen page —
        # value-exact (kmeans_ls on a 16-distinct-value reconstruction
        # reproduces it), so at most a redundant solve, never divergence.
        # A quantized shared prefix is installed-frozen by construction, so
        # it extends the watermark from page 0.
        fset = {m + j for j in entry.frozen_idx}
        if m and self.kv_spec is not None:
            fset |= set(range(m))
        upto = 0
        while upto in fset:
            upto += 1
        s.frozen_upto = upto
        self._queue_freeze(st.slot)
        self.counters["restored_seqs"] += 1
        self.counters["restored_pages"] += entry.payload.n_pages
        self.counters["restore_bytes"] += entry.payload.nbytes
        tr.instant(self._trk_decode, "restore", rid=req.id, slot=st.slot,
                   pages=entry.payload.n_pages, tokens=entry.n_tokens)
        if tr.enabled:
            for j, sid in sorted(entry.span_ids.items()):
                tr.async_end(self._trk_freeze, "page_offload", sid,
                             state="restored", rid=req.id, page_pos=j)
        if self.draft is not None:
            # the draft re-prefills the full accepted context (out[-1] has
            # no KV row yet, same as at attach); plen pins back to the
            # ORIGINAL prompt length because propose slices pending tokens
            # as out[lens - plen:]
            self.draft.attach(st.slot,
                              tuple(req.prompt) + tuple(entry.out[:-1]),
                              len(blocks))
            self.draft.plen[st.slot] = req.prompt_len
        self._publish_prefixes()

    def _rebuild_prefix(self, req: Request, m: int) -> list[int]:
        """Re-materialize the first ``m`` prompt pages of a restoring
        sequence whose shared prefix was released while it sat offloaded.

        Prefill is deterministic and the freeze solver is deterministic
        (canonical seed, sorted bids — see ``dispatch_freeze``), so the
        rebuilt pages carry values identical to the dead shared pages the
        sequence decoded against: the resumed trace stays token-exact. The
        chunk-prefill path used here is logit-identical to the slice of a
        single-shot prefill (tests/test_properties.py)."""
        bs = self.block_size
        blocks = self.alloc.alloc(m)
        toks = np.zeros((1, m * bs), np.int32)
        toks[0] = req.prompt[:m * bs]
        pos = jnp.asarray(np.arange(m * bs, dtype=np.int32)[None])
        table = np.asarray([blocks], np.int32)
        tree1 = with_tables(self.tree, table, np.zeros((1,), np.int32))
        if self.attn_impl == "fused":
            tree1 = with_prefill_fused(tree1)
        _, new = _prefill_chunk_step(self.params, jnp.asarray(toks), pos,
                                     tree1, cfg=self.cfg)
        self.tree = merge_pools(self.tree, new)
        if self.kv_spec is not None:
            # synchronous freeze: the restored watermark counts these pages
            # frozen from page 0, so they must be installed before decoding
            self.tree = freeze_blocks(self.tree, blocks, self.kv_spec,
                                      stats=self.counters)
            self._frozen_pages.update(blocks)
        return blocks

    def drain(self) -> None:
        """Flush every still-queued freeze and land in-flight solves (end
        of run — live sequences are gone, so latency no longer matters)."""
        while self._freeze_bids:
            self._flush_freezes()
        self._poll_freezes(drain=True)

    def _sample_cache(self) -> None:
        allocated = (self.num_blocks - 1) - self.alloc.num_free
        # count *installed* pages: queued/in-flight solves still serve fp
        # at full width, so they must not book frozen-page bytes yet
        frozen = len(self._frozen_pages)
        actual = (frozen * self._pb["frozen"]
                  + (allocated - frozen) * self._pb["fp"])
        occ = allocated / (self.num_blocks - 1)
        self.metrics.sample_cache(occ, actual, allocated * self._pb["fp"])
        tr = self.tracer
        if tr.enabled or self.roofline_gauges:
            extra = {}
            if self.prefix is not None:
                # physical pages saved by sharing right now: each extra
                # table reference on a page is a page NOT allocated
                extra["shared_saved_pages"] = sum(
                    rc - 1 for rc in self.alloc._rc.values() if rc > 1)
            tr.counter(self._trk_decode, "cache", occupancy=round(occ, 6),
                       frozen_pages=frozen, **extra)
            m = modeled_decode_hbm_bytes(self)
            if m is not None:
                self.metrics.stats.gauge("hbm_bytes_per_token").set(
                    m["hbm_bytes_per_token"])
                self.metrics.stats.gauge("t_memory_s").set(m["t_memory_s"])
                tr.counter(self._trk_decode, "roofline",
                           hbm_bytes_per_token=round(
                               m["hbm_bytes_per_token"], 3),
                           t_memory_us=round(m["t_memory_s"] * 1e6, 6))


@dataclasses.dataclass
class _ChunkedPrefill:
    """In-flight chunked prefill: one prompt advancing chunk-by-chunk so
    the engine can interleave decode steps between chunks."""

    req: Request
    blocks: list
    toks: np.ndarray          # (1, ppad) zero-padded prompt
    nblk: int
    off: int = 0              # tokens already in cache (shared prefix
    #                           pre-seeds this past the spliced pages)
    shared: int = 0           # leading pages spliced from the prefix index
    last_row: object = None   # device logits row at prompt position P-1

    @property
    def done(self) -> bool:
        return self.off >= self.toks.shape[1]


class PrefillWorker:
    """The prefill role: queued prompts -> finished-prefill artifacts.

    With ``pool=None`` the worker owns a small paged pool sized for
    in-flight prompts and emits migration payloads (mode fp/frozen); with
    ``pool=<DecodeWorker>`` it borrows the decode worker's pool and
    allocator (the colocated composition) and emits no-op "splice"
    payloads. ``step()`` is async in owned mode: it dispatches at most one
    prefill (plus, for frozen migration, the page-freeze solve chained
    behind it on device) and harvests on a later call once the device is
    done, so the caller's decode loop keeps running under a long prompt.
    """

    def __init__(self, params, cfg, *, worker_id: int = 0,
                 block_size: int = 16, max_seq_len: int = 256,
                 kv_spec=None, migrate: str = "fp",
                 num_blocks: int | None = None, pool: DecodeWorker | None = None,
                 record_logits: bool = False, metrics=None,
                 max_queue: int = 64, prefill_chunk: int | None = None,
                 tracer=None):
        from .metrics import MetricsCollector

        assert migrate in ("fp", "frozen"), migrate
        assert prefill_chunk is None or (prefill_chunk >= 1
                                         and pool is not None), (
            "chunked prefill interleaves with a colocated decode worker's "
            "pool — construct with pool=<DecodeWorker>")
        self.worker_id = worker_id
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._trk = f"prefill/w{worker_id}"
        self.params, self.cfg = params, cfg
        self.block_size = block_size
        self.kv_spec = kv_spec
        self.migrate = migrate
        self.pool = pool
        self.record_logits = record_logits
        self.max_queue = max_queue
        self.prefill_chunk = prefill_chunk
        self.metrics = metrics if metrics is not None else MetricsCollector()
        self.max_prompt_blocks = -(-max_seq_len // block_size)
        self.queue: deque[Request] = deque()
        self._inflight = None  # (req, blocks, logits device array, payload,
        #                         token offset the prefill started at)
        self.counters = {"prefills": 0, "queue_peak": 0, "prefill_chunks": 0}
        self._prefill_fn = functools.partial(_prefill_step, cfg=cfg)
        self._chunk_fn = functools.partial(_prefill_chunk_step, cfg=cfg)
        if pool is None:
            frozen = migrate == "frozen" and kv_spec is not None
            self.num_blocks = (num_blocks if num_blocks is not None
                               else 2 * self.max_prompt_blocks + 1)
            self.tree = init_paged_cache(
                cfg, num_blocks=self.num_blocks, block_size=block_size,
                batch=1, max_blocks=self.max_prompt_blocks, quantized=frozen,
                num_values=kv_spec.num_values if frozen else 16, fused=False)
            self.alloc = BlockAllocator(self.num_blocks)
        else:
            self.num_blocks = pool.num_blocks

    # ------------------------------------------------------------ routing

    @property
    def load(self) -> int:
        return len(self.queue) + (1 if self._inflight else 0)

    @property
    def busy(self) -> bool:
        return self.load > 0

    def can_accept(self) -> bool:
        return self.load < self.max_queue

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.counters["queue_peak"] = max(self.counters["queue_peak"],
                                          self.load)

    # ------------------------------------------------------ prefix sharing

    def _match_prefix(self, req: Request) -> list[int]:
        """Longest published-prefix match for a colocated prefill: retain
        the matched pages (rc+1 each) and return them for splicing into
        the new sequence's table — prefill then starts at the page-aligned
        offset past them instead of token 0.

        The match is capped one page short of the prompt's LAST token, so
        the page feeding the first-token logits row is always privately
        prefilled. A raw match past that cap is the copy-on-write event:
        the write-hot tail page exists in the index but is materialized
        privately (by prefilling it) instead of shared — ``cow_copies``
        counts these.
        """
        pool = self.pool
        if pool is None or pool.prefix is None:
            return []
        tr = self.tracer
        t0 = tr.now()
        cap = (req.prompt_len - 1) // self.block_size
        raw = pool.prefix.lookup(req.prompt, cap + 1)
        shared = [int(b) for b in raw[:cap]]
        if not shared:
            return []
        pool.alloc.retain(shared)
        pool.counters["prefix_hits"] += 1
        pool.counters["prefix_shared_pages"] += len(shared)
        cow = len(raw) > len(shared)
        if cow:
            pool.counters["cow_copies"] += 1
        tr.complete(self._trk, "prefix_match", t0, rid=req.id,
                    pages=len(shared), cow=cow)
        return shared

    # ------------------------------------------------------------ prefill

    def _dispatch(self, req: Request, now_fn) -> None:
        """Launch one prompt's prefill (and, when migrating frozen, the
        page-freeze solve chained behind it); returns without waiting."""
        tr = self.tracer
        t0 = tr.now()
        self.metrics.prefill_start(req.id, now_fn())
        P = req.prompt_len
        # async span across dispatch -> harvest: the device-side lifetime
        # of this prompt's prefill (and any chained freeze solve)
        tr.async_begin(self._trk, "prefill", req.id, rid=req.id,
                       prompt_len=P)
        ppad = -(-P // self.block_size) * self.block_size
        nblk = ppad // self.block_size
        off = 0
        if self.pool is not None:
            # borrowed pool: splice any published shared prefix, then
            # allocate the request's remaining worst-case pages where they
            # will be served; the handoff is a table splice
            shared = self._match_prefix(req)
            off = len(shared) * self.block_size
            blocks = shared + self.pool.alloc.alloc(
                self.pool.sched.blocks_for(req) - len(shared))
            tree = self.pool.tree
        else:
            blocks = self.alloc.alloc(nblk)
            tree = self.tree
        toks = np.zeros((1, ppad - off), np.int32)
        toks[0, :P - off] = req.prompt[off:]
        table = np.asarray([blocks[:nblk]], np.int32)
        tree1 = with_tables(tree, table, np.full((1,), off, np.int32))
        if off:
            # mid-sequence start past the shared pages: explicit positions
            # rope/mask this exactly like the matching slice of a
            # whole-prompt prefill (the chunked-prefill q_offset path)
            if self.pool.attn_impl == "fused":
                tree1 = with_prefill_fused(tree1)
            pos = jnp.asarray(np.arange(off, ppad, dtype=np.int32)[None])
            logits, new1 = self._chunk_fn(self.params, jnp.asarray(toks),
                                          pos, tree1)
        else:
            logits, new1 = self._prefill_fn(self.params, jnp.asarray(toks),
                                            tree1)
        merged = merge_pools(tree, new1)
        if self.pool is not None:
            self.pool.tree = merged
            payload = PagePayload(mode="splice",
                                  blocks=[int(b) for b in blocks],
                                  n_tokens=P, block_size=self.block_size,
                                  n_full=P // self.block_size,
                                  tail_rows=P % self.block_size,
                                  shared_pages=off // self.block_size)
        else:
            self.tree = merged
            payload = extract_pages(merged, blocks, P,
                                    block_size=self.block_size,
                                    mode=self.migrate, spec=self.kv_spec,
                                    tracer=tr)
        self._inflight = (req, blocks, logits, payload, off)
        tr.complete(self._trk, "dispatch", t0, rid=req.id, prompt_len=P,
                    pages=nblk, shared=off // self.block_size)

    def _harvest(self, now_fn) -> FinishedPrefill:
        """Materialize the finished prefill: sample the first token, stage
        the payload to host, release this worker's blocks."""
        tr = self.tracer
        t0 = tr.now()
        req, blocks, logits, payload, off = self._inflight
        self._inflight = None
        last = np.asarray(logits[0, req.prompt_len - 1 - off])
        now = now_fn()                        # TTFT includes prefill time
        rng = req.make_rng()
        tok = sample_token(last, temperature=req.temperature,
                           top_k=req.top_k, rng=rng)
        self.metrics.first_token(req.id, now)
        if payload.mode == "splice":
            payload.to_host()  # lint: sync(splice mode stages no arrays)
        else:
            t_host = tr.now()
            # lint: sync(handoff staging is the wire; gated on is_ready)
            payload.to_host()
            tr.complete("transfer", "to_host", t_host, rid=req.id,
                        mode=payload.mode, bytes=payload.nbytes,
                        fp_equiv_bytes=payload.fp_equiv_bytes,
                        pages=payload.n_pages)
        if self.pool is None:
            self.alloc.free(blocks)           # pages left as a host payload
        self.counters["prefills"] += 1
        tr.complete(self._trk, "harvest", t0, rid=req.id)
        tr.async_end(self._trk, "prefill", req.id, rid=req.id)
        return FinishedPrefill(
            req=req, first_token=tok, payload=payload, rng=rng,
            last_logits=last if self.record_logits else None,
            worker_id=self.worker_id)

    def step(self, now_fn, block: bool = False) -> list[FinishedPrefill]:
        """Advance this worker: dispatch the queue head if idle (and its
        prompt pages fit), harvest the in-flight prefill once the device
        finished (immediately when ``block``). Returns 0 or 1 artifacts."""
        if self._inflight is None and self.queue:
            req = self.queue[0]
            nblk = -(-req.prompt_len // self.block_size)
            if self.pool is not None or nblk <= self.alloc.num_free:
                self.queue.popleft()
                self._dispatch(req, now_fn)
        if self._inflight is not None:
            logits, payload = self._inflight[2], self._inflight[3]
            # harvest only once the prefill AND any chained freeze solve
            # landed: to_host() on an in-flight solve would block this
            # loop — the exact stall the worker split exists to avoid
            if block or (logits.is_ready() and payload.is_ready()):
                return [self._harvest(now_fn)]
        return []

    def run_inline(self, req: Request, now_fn) -> FinishedPrefill:
        """Synchronous prefill of one request (the colocated engine's
        inline path): dispatch + blocking harvest."""
        assert self._inflight is None and not self.queue
        self._dispatch(req, now_fn)
        return self._harvest(now_fn)

    # ---------------------------------------------------------- chunked

    def start_chunked(self, req: Request, now_fn) -> _ChunkedPrefill:
        """Open a chunked prefill: allocate the request's worst-case pages
        in the colocated pool and return the chunk cursor. The engine then
        calls ``advance_chunk`` once per iteration, interleaved with decode
        steps — a long prompt costs each iteration one chunk instead of
        the whole prompt, which is what bounds ``itl_max`` under a
        long-prompt burst."""
        assert self.prefill_chunk and self.pool is not None
        tr = self.tracer
        self.metrics.prefill_start(req.id, now_fn())
        P = req.prompt_len
        tr.async_begin(self._trk, "prefill", req.id, rid=req.id,
                       prompt_len=P)
        ppad = -(-P // self.block_size) * self.block_size
        shared = self._match_prefix(req)
        blocks = shared + self.pool.alloc.alloc(
            self.pool.sched.blocks_for(req) - len(shared))
        toks = np.zeros((1, ppad), np.int32)
        toks[0, :P] = req.prompt
        # a matched prefix pre-seeds the chunk cursor past the spliced
        # pages — those tokens are already in cache, so chunking starts
        # mid-sequence exactly like any later chunk would
        return _ChunkedPrefill(req=req, blocks=blocks, toks=toks,
                               nblk=ppad // self.block_size,
                               off=len(shared) * self.block_size,
                               shared=len(shared))

    def advance_chunk(self, state: _ChunkedPrefill,
                      now_fn) -> FinishedPrefill | None:
        """Run ONE chunk of an open chunked prefill; returns the finished
        artifact once the whole (padded) prompt is in cache, else None.

        Each chunk scores its C tokens against every earlier page through
        the same attention path decode uses — with the fused impl, frozen
        pages cross HBM as packed codes + codebooks (the modeled-bytes win
        on shared frozen context); positions/q_offset are explicit, so the
        chunk sequence is logit-identical to one single-shot prefill
        (bitwise on the gather path; see tests/test_properties.py).
        """
        tr = self.tracer
        t0 = tr.now()
        req, P = state.req, state.req.prompt_len
        ppad = state.toks.shape[1]
        off = state.off
        C = min(self.prefill_chunk, ppad - off)
        pool = self.pool
        toks = jnp.asarray(state.toks[:, off:off + C])
        pos = jnp.asarray(np.arange(off, off + C, dtype=np.int32)[None])
        table = np.zeros((1, state.nblk), np.int32)
        table[0] = state.blocks[:state.nblk]
        tree1 = with_tables(pool.tree, table, np.full((1,), off, np.int32))
        if pool.attn_impl == "fused":
            tree1 = with_prefill_fused(tree1)
        logits, new1 = self._chunk_fn(self.params, toks, pos, tree1)
        pool.tree = merge_pools(pool.tree, new1)
        if off <= P - 1 < off + C:
            state.last_row = logits[0, P - 1 - off]
        state.off = off + C
        self.counters["prefill_chunks"] += 1
        if tr.enabled or pool.roofline_gauges:
            m = modeled_prefill_hbm_bytes(
                pool._pb, state.blocks, pool._frozen_pages,
                block_size=self.block_size, off=off, chunk=C,
                fused=pool.attn_impl == "fused")
            self.metrics.stats.gauge("prefill_hbm_bytes_per_token").set(
                m["hbm_bytes_per_token"])
            tr.counter(self._trk, "roofline",
                       prefill_hbm_bytes_per_token=round(
                           m["hbm_bytes_per_token"], 3))
        tr.complete(self._trk, "prefill_chunk", t0, rid=req.id, off=off,
                    chunk=C)
        if not state.done:
            return None
        # -------- harvest: mirrors _harvest's splice branch
        last = np.asarray(state.last_row)   # first-token sampling sync
        now = now_fn()                        # TTFT includes all chunks
        rng = req.make_rng()
        tok = sample_token(last, temperature=req.temperature,
                           top_k=req.top_k, rng=rng)
        self.metrics.first_token(req.id, now)
        payload = PagePayload(mode="splice",
                              blocks=[int(b) for b in state.blocks],
                              n_tokens=P, block_size=self.block_size,
                              n_full=P // self.block_size,
                              tail_rows=P % self.block_size,
                              shared_pages=state.shared)
        payload.to_host()                   # splice mode stages no arrays
        self.counters["prefills"] += 1
        tr.async_end(self._trk, "prefill", req.id, rid=req.id)
        return FinishedPrefill(
            req=req, first_token=tok, payload=payload, rng=rng,
            last_logits=last if self.record_logits else None,
            worker_id=self.worker_id)
