"""Serving metrics: per-request latency (TTFT / TPOT), aggregate
throughput, and KV-cache occupancy counters.

TTFT = first token time - arrival, split into its two components so
disaggregation wins attribute correctly:

  queue_wait      = prefill start - arrival   (admission + routing delay)
  prefill_compute = first token - prefill start

TPOT = mean inter-token time over the remaining tokens.

Aggregate cache/ITL series are streaming (``obs.stats`` gauges + log
histograms) — O(1) memory however long the run — instead of the raw
per-step lists this collector used to keep. Per-request state
(``RequestTrace``, including its decode ``gaps``) stays exact: it is
bounded by max_new_tokens and benches consume it directly. ``summary()``
keys are unchanged; ``snapshot()`` is the live view the JSONL/Prometheus
exporters poll mid-run.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs.stats import Registry


def percentile(xs, p: float) -> float | None:
    """None (key omitted upstream) instead of NaN on empty input — NaN is
    not valid strict JSON and used to poison BENCH_*.json artifacts."""
    if not len(xs):
        return None
    return float(np.percentile(np.asarray(xs, np.float64), p))


@dataclasses.dataclass
class RequestTrace:
    arrival_t: float
    prompt_len: int
    prefill_start_t: float | None = None
    first_token_t: float | None = None
    finish_t: float | None = None
    tokens: int = 0
    # per-token decode gaps (when the engine timestamps token events):
    # the distribution whose tail a prefill stall inflates
    gaps: list = dataclasses.field(default_factory=list)
    _last_t: float | None = None

    @property
    def ttft(self) -> float:
        return self.first_token_t - self.arrival_t

    @property
    def queue_wait(self) -> float:
        """Admission/routing delay before prefill compute started (falls
        back to the whole TTFT when no prefill_start was recorded)."""
        if self.prefill_start_t is None:
            return self.ttft
        return self.prefill_start_t - self.arrival_t

    @property
    def prefill_compute(self) -> float:
        if self.prefill_start_t is None:
            return 0.0
        return self.first_token_t - self.prefill_start_t

    @property
    def tpot(self) -> float:
        if self.tokens <= 1:
            return 0.0
        return (self.finish_t - self.first_token_t) / (self.tokens - 1)


class MetricsCollector:
    def __init__(self):
        self.traces: dict[int, RequestTrace] = {}
        self.stats = Registry()
        self.steps = 0
        # speculative decoding: drafted-token fate, counted per SEQUENCE
        # slice of a batched verify pass (spec_step is called once per
        # active slot, so spec_proposed == k * spec_steps always)
        self.spec_steps = 0          # per-sequence verify slices
        self.spec_proposed = 0       # draft tokens offered for verification
        self.spec_accepted = 0       # draft tokens the target emitted
        self.spec_rollbacks = 0      # slices that rolled a suffix back
        # last cache sample where the pool held anything (fp-equiv > 0):
        # after the final eviction both sides are zero, so "final" keeps
        # meaning "steady state before teardown"
        self._cache_final: tuple[float, float] | None = None
        self._completed = 0
        self._completed_zero_token = 0
        self._gen_tokens_done = 0
        # admission outcomes, counted by reason: rejected_queue_full /
        # rejected_pool_full (hard doors), shed_slo / deferred (SLO-aware
        # policy). Keys surface in summary()/snapshot() only when nonzero
        # so the legacy key set is untouched on runs without overload.
        self._admission: dict[str, int] = {}

    # ----------------------------------------------------- request events

    def arrival(self, rid: int, t: float, prompt_len: int) -> None:
        self.traces[rid] = RequestTrace(arrival_t=t, prompt_len=prompt_len)
        self.stats.counter("requests_arrived").inc()

    def admission(self, reason: str) -> None:
        """Count one admission-control outcome by reason."""
        self._admission[reason] = self._admission.get(reason, 0) + 1

    def prefill_start(self, rid: int, t: float) -> None:
        tr = self.traces[rid]
        # a recompute-path resumption re-prefills mid-stream: keep the
        # FIRST life's queue-wait attribution, don't rewrite history
        if tr.prefill_start_t is None:
            tr.prefill_start_t = t

    def first_token(self, rid: int, t: float) -> None:
        tr = self.traces[rid]
        if tr.first_token_t is not None:
            # resumed after preemption: the re-prefill's sampled token is
            # just the next token of an already-started stream — one more
            # (stall-inflated, honestly counted) decode gap, not a second
            # TTFT, and not a reset of the token count
            tr.tokens += 1
            if tr._last_t is not None:
                gap = t - tr._last_t
                tr.gaps.append(gap)
                self.stats.histogram("itl_s").observe(gap)
            tr._last_t = t
            return
        tr.first_token_t = t
        tr.tokens = 1
        tr._last_t = t
        self.stats.histogram("ttft_s").observe(t - tr.arrival_t)

    def token(self, rid: int, t: float | None = None) -> None:
        tr = self.traces[rid]
        tr.tokens += 1
        self.stats.counter("tokens_generated").inc()
        if t is not None:
            if tr._last_t is not None:
                gap = t - tr._last_t
                tr.gaps.append(gap)
                self.stats.histogram("itl_s").observe(gap)
            tr._last_t = t

    def finish(self, rid: int, t: float) -> None:
        tr = self.traces[rid]
        tr.finish_t = t
        self._completed += 1
        self._gen_tokens_done += tr.tokens
        if tr.first_token_t is None:
            # finished without emitting anything (shed/rejected after
            # admission, or eos on first verify) — no latency to report
            self._completed_zero_token += 1

    def spec_step(self, proposed: int, accepted: int,
                  rolled_back: bool) -> None:
        """Account one sequence's slice of a speculative verify pass:
        ``proposed`` draft tokens went in, ``accepted`` of them were
        emitted; ``rolled_back`` marks a rejected suffix (seq_lens rolled
        back to the accepted watermark)."""
        self.spec_steps += 1
        self.spec_proposed += proposed
        self.spec_accepted += accepted
        if rolled_back:
            self.spec_rollbacks += 1

    # ----------------------------------------------------- cache sampling

    def sample_cache(self, occupancy: float, actual_bytes: float,
                     fp_bytes: float) -> None:
        self.steps += 1
        self.stats.gauge("cache_occupancy").set(occupancy)
        self.stats.gauge("cache_bytes").set(actual_bytes)
        self.stats.gauge("cache_bytes_fp").set(fp_bytes)
        if fp_bytes > 0:
            self.stats.gauge("cache_compression").set(fp_bytes / actual_bytes)
            self._cache_final = (actual_bytes, fp_bytes)

    # ----------------------------------------------------- aggregation

    def snapshot(self) -> dict:
        """Live mid-run view for the exporters: running totals + every
        streaming metric's snapshot. JSON-safe scalars only."""
        out = {"completed": self._completed,
               "completed_zero_token": self._completed_zero_token,
               "gen_tokens": self._gen_tokens_done,
               "steps": self.steps,
               "in_flight": len(self.traces) - self._completed}
        if self.spec_steps:
            out.update(spec_steps=self.spec_steps,
                       spec_proposed=self.spec_proposed,
                       spec_accepted=self.spec_accepted,
                       spec_rollbacks=self.spec_rollbacks)
        for k, v in self._admission.items():
            if v:
                out[k] = v
        out.update(self.stats.snapshot())
        return out

    def summary(self) -> dict:
        done = [t for t in self.traces.values() if t.finish_t is not None]
        # zero-token finishes have no first_token_t: excluding them from
        # the latency population (instead of raising on ttft's None
        # subtraction) keeps every key below well-defined
        zero = [t for t in done if t.first_token_t is None]
        done = [t for t in done if t.first_token_t is not None]
        if not done:
            out = {"completed": 0}
            if zero:
                out["completed_zero_token"] = len(zero)
            for k, v in self._admission.items():
                if v:
                    out[k] = v
            return out
        t0 = min(t.arrival_t for t in done)
        t1 = max(t.finish_t for t in done)
        gen = sum(t.tokens for t in done)
        ttfts = [t.ttft for t in done]
        tpots = [t.tpot for t in done if t.tokens > 1]
        out = {
            "completed": len(done),
            "gen_tokens": gen,
            "makespan_s": t1 - t0,
            "throughput_tok_s": gen / max(t1 - t0, 1e-9),
            "ttft_mean_s": float(np.mean(ttfts)),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p99_s": percentile(ttfts, 99),
        }
        if zero:
            out["completed_zero_token"] = len(zero)
        if tpots:
            out["tpot_p50_s"] = percentile(tpots, 50)
            out["tpot_p99_s"] = percentile(tpots, 99)
        # TTFT decomposition: queue_wait (admission + routing) vs
        # prefill_compute — the pair disaggregation trades against
        waits = [t.queue_wait for t in done]
        computes = [t.prefill_compute for t in done]
        out.update({
            "queue_wait_mean_s": float(np.mean(waits)),
            "queue_wait_p50_s": percentile(waits, 50),
            "queue_wait_p99_s": percentile(waits, 99),
            "prefill_compute_mean_s": float(np.mean(computes)),
            "prefill_compute_p50_s": percentile(computes, 50),
            "prefill_compute_p99_s": percentile(computes, 99),
        })
        # inter-token latency over every decode gap (engines that timestamp
        # token events): unlike the per-request tpot means above, a single
        # prefill stall lands in this distribution's tail undiluted
        gaps = [g for t in done for g in t.gaps]
        if gaps:
            out["itl_p50_s"] = percentile(gaps, 50)
            out["itl_p99_s"] = percentile(gaps, 99)
            out["itl_max_s"] = float(np.max(gaps))
        if self.spec_steps:
            out["spec_steps"] = self.spec_steps
            out["spec_proposed"] = self.spec_proposed
            out["spec_accepted"] = self.spec_accepted
            out["spec_rollbacks"] = self.spec_rollbacks
            out["spec_acceptance_rate"] = (
                self.spec_accepted / max(self.spec_proposed, 1))
        if "cache_occupancy" in self.stats:
            occ = self.stats.gauge("cache_occupancy")
            out["cache_occupancy_mean"] = occ.mean
            out["cache_occupancy_max"] = occ.vmax
        if self._cache_final is not None:
            act, fp = self._cache_final
            comp = self.stats.gauge("cache_compression")
            out["cache_bytes_final"] = float(act)
            out["cache_bytes_fp_final"] = float(fp)
            out["cache_compression_mean"] = comp.mean
            out["cache_compression_final"] = float(fp / act)
        # admission outcomes by reason, only when any occurred (keeps the
        # legacy summary key set byte-identical on unremarkable runs)
        for k, v in self._admission.items():
            if v:
                out[k] = v
        return out
