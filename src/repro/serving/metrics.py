"""Serving metrics: per-request latency (TTFT / TPOT), aggregate
throughput, and KV-cache occupancy counters.

TTFT = first token time - arrival, split into its two components so
disaggregation wins attribute correctly:

  queue_wait      = prefill start - arrival   (admission + routing delay)
  prefill_compute = first token - prefill start

TPOT = mean inter-token time over the remaining tokens.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def percentile(xs, p: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), p)) if len(xs) else float("nan")


@dataclasses.dataclass
class RequestTrace:
    arrival_t: float
    prompt_len: int
    prefill_start_t: float | None = None
    first_token_t: float | None = None
    finish_t: float | None = None
    tokens: int = 0
    # per-token decode gaps (when the engine timestamps token events):
    # the distribution whose tail a prefill stall inflates
    gaps: list = dataclasses.field(default_factory=list)
    _last_t: float | None = None

    @property
    def ttft(self) -> float:
        return self.first_token_t - self.arrival_t

    @property
    def queue_wait(self) -> float:
        """Admission/routing delay before prefill compute started (falls
        back to the whole TTFT when no prefill_start was recorded)."""
        if self.prefill_start_t is None:
            return self.ttft
        return self.prefill_start_t - self.arrival_t

    @property
    def prefill_compute(self) -> float:
        if self.prefill_start_t is None:
            return 0.0
        return self.first_token_t - self.prefill_start_t

    @property
    def tpot(self) -> float:
        if self.tokens <= 1:
            return 0.0
        return (self.finish_t - self.first_token_t) / (self.tokens - 1)


class MetricsCollector:
    def __init__(self):
        self.traces: dict[int, RequestTrace] = {}
        self.occupancy: list[float] = []        # allocated / total pages
        self.cache_bytes: list[tuple[float, float]] = []  # (actual, fp-equiv)
        self.steps = 0
        # speculative decoding: drafted-token fate, counted per SEQUENCE
        # slice of a batched verify pass (spec_step is called once per
        # active slot, so spec_proposed == k * spec_steps always)
        self.spec_steps = 0          # per-sequence verify slices
        self.spec_proposed = 0       # draft tokens offered for verification
        self.spec_accepted = 0       # draft tokens the target emitted
        self.spec_rollbacks = 0      # slices that rolled a suffix back

    # ----------------------------------------------------- request events

    def arrival(self, rid: int, t: float, prompt_len: int) -> None:
        self.traces[rid] = RequestTrace(arrival_t=t, prompt_len=prompt_len)

    def prefill_start(self, rid: int, t: float) -> None:
        self.traces[rid].prefill_start_t = t

    def first_token(self, rid: int, t: float) -> None:
        tr = self.traces[rid]
        tr.first_token_t = t
        tr.tokens = 1
        tr._last_t = t

    def token(self, rid: int, t: float | None = None) -> None:
        tr = self.traces[rid]
        tr.tokens += 1
        if t is not None:
            if tr._last_t is not None:
                tr.gaps.append(t - tr._last_t)
            tr._last_t = t

    def finish(self, rid: int, t: float) -> None:
        self.traces[rid].finish_t = t

    def spec_step(self, proposed: int, accepted: int,
                  rolled_back: bool) -> None:
        """Account one sequence's slice of a speculative verify pass:
        ``proposed`` draft tokens went in, ``accepted`` of them were
        emitted; ``rolled_back`` marks a rejected suffix (seq_lens rolled
        back to the accepted watermark)."""
        self.spec_steps += 1
        self.spec_proposed += proposed
        self.spec_accepted += accepted
        if rolled_back:
            self.spec_rollbacks += 1

    # ----------------------------------------------------- cache sampling

    def sample_cache(self, occupancy: float, actual_bytes: float,
                     fp_bytes: float) -> None:
        self.steps += 1
        self.occupancy.append(occupancy)
        self.cache_bytes.append((actual_bytes, fp_bytes))

    # ----------------------------------------------------- aggregation

    def summary(self) -> dict:
        done = [t for t in self.traces.values() if t.finish_t is not None]
        if not done:
            return {"completed": 0}
        t0 = min(t.arrival_t for t in done)
        t1 = max(t.finish_t for t in done)
        gen = sum(t.tokens for t in done)
        ttfts = [t.ttft for t in done]
        tpots = [t.tpot for t in done if t.tokens > 1]
        out = {
            "completed": len(done),
            "gen_tokens": gen,
            "makespan_s": t1 - t0,
            "throughput_tok_s": gen / max(t1 - t0, 1e-9),
            "ttft_mean_s": float(np.mean(ttfts)),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p99_s": percentile(ttfts, 99),
            "tpot_p50_s": percentile(tpots, 50),
            "tpot_p99_s": percentile(tpots, 99),
        }
        # TTFT decomposition: queue_wait (admission + routing) vs
        # prefill_compute — the pair disaggregation trades against
        waits = [t.queue_wait for t in done]
        computes = [t.prefill_compute for t in done]
        out.update({
            "queue_wait_mean_s": float(np.mean(waits)),
            "queue_wait_p50_s": percentile(waits, 50),
            "queue_wait_p99_s": percentile(waits, 99),
            "prefill_compute_mean_s": float(np.mean(computes)),
            "prefill_compute_p50_s": percentile(computes, 50),
            "prefill_compute_p99_s": percentile(computes, 99),
        })
        # inter-token latency over every decode gap (engines that timestamp
        # token events): unlike the per-request tpot means above, a single
        # prefill stall lands in this distribution's tail undiluted
        gaps = [g for t in done for g in t.gaps]
        if gaps:
            out["itl_p50_s"] = percentile(gaps, 50)
            out["itl_p99_s"] = percentile(gaps, 99)
            out["itl_max_s"] = float(np.max(gaps))
        if self.spec_steps:
            out["spec_steps"] = self.spec_steps
            out["spec_proposed"] = self.spec_proposed
            out["spec_accepted"] = self.spec_accepted
            out["spec_rollbacks"] = self.spec_rollbacks
            out["spec_acceptance_rate"] = (
                self.spec_accepted / max(self.spec_proposed, 1))
        if self.occupancy:
            out["cache_occupancy_mean"] = float(np.mean(self.occupancy))
            out["cache_occupancy_max"] = float(np.max(self.occupancy))
        if self.cache_bytes:
            act, fp = np.asarray(self.cache_bytes).T
            nz = np.flatnonzero(fp > 0)
            if nz.size:
                # "final" = last step the cache held anything (after the last
                # eviction both sides are zero)
                j = nz[-1]
                out["cache_bytes_final"] = float(act[j])
                out["cache_bytes_fp_final"] = float(fp[j])
                out["cache_compression_mean"] = float(np.mean(fp[nz] / act[nz]))
                out["cache_compression_final"] = float(fp[j] / act[j])
        return out
