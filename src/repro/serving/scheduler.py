"""Continuous-batching scheduler: iteration-level batching with admission
control.

Pure decision logic over a virtual "now" and a free-page count — no model,
no arrays — so a whole serving day can be simulated deterministically in a
unit test. The engine calls ``schedule()`` once per iteration; new prefills
join the in-flight decode batch whenever a slot and enough pages are free,
and finished sequences are evicted the same step they complete
(``release``), their pages immediately reusable.

Admission is conservative: a request is only scheduled when its *worst
case* page need — ceil((prompt + max_new) / block_size) — fits, so a
scheduled request can never deadlock the pool mid-decode (no preemption
needed). ``submit`` applies queue-depth admission control and is safe to
call from an async producer: it only appends to a deque, so an
``asyncio``/thread frontend can feed arrivals while the engine loop runs.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.obs.trace import NULL_TRACER


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.

    ``temperature``/``top_k``/``seed`` drive engine-level sampling:
    temperature 0 (the default) is greedy argmax — the deterministic path
    every verification harness replays — and any positive temperature
    samples from the (optionally top-k-truncated) softmax with a
    per-request numpy Generator seeded from ``seed`` (falling back to the
    request id), so a trace replays token-identically.

    ``priority`` is the SLO tier: "latency" requests are protected by
    admission control and may preempt; "best_effort" requests are the ones
    shed or deferred under overload (and the preemption victims).
    """

    id: int
    prompt: tuple          # token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    temperature: float = 0.0
    top_k: int = 0
    seed: int | None = None
    priority: str = "latency"

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    def make_rng(self) -> np.random.Generator:
        return np.random.default_rng(self.id if self.seed is None
                                     else self.seed)


@dataclasses.dataclass
class SeqState:
    """Scheduler-side state of an admitted sequence."""

    req: Request
    slot: int
    length: int            # tokens with KV in cache
    generated: int = 0

    @property
    def done(self) -> bool:
        return self.generated >= self.req.max_new_tokens


class ContinuousBatchingScheduler:
    def __init__(self, *, max_slots: int, block_size: int,
                 max_queue: int = 256, lookahead: int = 0):
        self.max_slots = max_slots
        self.block_size = block_size
        self.max_queue = max_queue
        # speculative decoding writes its verify window optimistically:
        # up to `lookahead` rows past the final accepted length need pages
        # (rolled-back rows are rewritten, never served), so worst-case
        # admission must reserve them
        self.lookahead = lookahead
        self.waiting: deque[Request] = deque()
        # requests evicted mid-decode by preemption, re-admitted ahead of
        # FCFS: they already waited their turn once, so they outrank every
        # queued arrival (appended in eviction order, drained FCFS)
        self.preempted: deque[Request] = deque()
        self.active: dict[int, SeqState] = {}       # slot -> state
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self.rejected: list[int] = []

    # ------------------------------------------------------------ intake

    def blocks_for(self, req: Request) -> int:
        total = req.prompt_len + req.max_new_tokens + self.lookahead
        return -(-total // self.block_size)

    def submit(self, req: Request) -> bool:
        """Admission control at the queue door; False = rejected (429)."""
        if len(self.waiting) >= self.max_queue:
            self.rejected.append(req.id)
            return False
        self.waiting.append(req)
        return True

    # ------------------------------------------------------------ per step

    def schedule(self, free_blocks: int, discount=None) -> list[SeqState]:
        """Admit FCFS from the queue into free slots while pages last.

        Returns newly admitted sequences (their prefill runs this
        iteration). Head-of-line blocking is intentional: FCFS keeps the
        schedule deterministic and starvation-free. Preempted requests
        drain first — they were already admitted once, so a queued arrival
        never overtakes them.

        ``discount(req)`` (optional) returns pages of the request's prompt
        already resident and shareable (prefix-cache probe): admission
        charges worst-case-minus-shareable, which is what turns page
        sharing into extra sequences per pool rather than just faster
        prefills.
        """
        admitted = []
        while (self.preempted or self.waiting) and self._free_slots:
            q = self.preempted if self.preempted else self.waiting
            need = self.blocks_for(q[0])
            if discount is not None:
                need = max(need - discount(q[0]), 0)
            if need > free_blocks:
                break
            req = q.popleft()
            slot = self._free_slots.pop()
            st = SeqState(req=req, slot=slot, length=0)
            self.active[slot] = st
            admitted.append(st)
            free_blocks -= need
        return admitted

    def admit_direct(self, req: Request) -> SeqState | None:
        """Bypass the waiting queue: bind ``req`` to a free slot right now.

        The disaggregated import path uses this — the request already went
        through global (router) queueing and its prefill already ran on a
        prefill worker, so re-queueing it behind this worker's FCFS door
        would deadlock against the router's own staging queue. Returns None
        when no slot is free (the router keeps the finished prefill staged).
        Page accounting stays with the caller, which checks the worker's
        free-block count before offering.
        """
        if not self._free_slots:
            return None
        slot = self._free_slots.pop()
        st = SeqState(req=req, slot=slot, length=0)
        self.active[slot] = st
        return st

    def step_decoded(self) -> list[SeqState]:
        """Account one decoded token per active sequence; return the ones
        that just finished (caller evicts them this same iteration)."""
        finished = []
        for st in self.active.values():
            st.length += 1
            st.generated += 1
            if st.done:
                finished.append(st)
        return finished

    def stage(self, st: SeqState) -> None:
        """Take an admitted sequence out of the decode batch while keeping
        its slot (and pages) reserved — the chunked-prefill engine parks a
        sequence here between prefill chunks so interleaved decode steps
        don't include its slot, then ``activate``s it once the whole prompt
        is in cache."""
        del self.active[st.slot]

    def activate(self, st: SeqState) -> None:
        """Re-enter a ``stage``d sequence into the decode batch."""
        assert st.slot not in self.active, f"slot {st.slot} already active"
        self.active[st.slot] = st

    def release(self, st: SeqState) -> None:
        del self.active[st.slot]
        self._free_slots.append(st.slot)
        self._free_slots.sort(reverse=True)   # deterministic reuse order

    # ------------------------------------------------------------ queries

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.preempted or self.active)

    def active_slots(self) -> list[int]:
        return sorted(self.active)


class DisaggRouter:
    """Global router for disaggregated serving: one queue in front of N
    prefill workers and M decode workers.

    Pure decision logic, like the scheduler above: workers are duck-typed
    (prefill workers expose ``load``/``can_accept()``, decode workers
    ``can_accept(req)``/``free_slots``), so routing policy is unit-testable
    with fakes and the same router drives any worker ratio. Requests flow

        submit -> waiting -> [prefill worker] -> stage -> [decode worker]

    ``route_prefill`` assigns FCFS to the least-loaded prefill worker (tie:
    lowest index, so the schedule is deterministic); ``route_decode`` places
    finished prefills FCFS onto the decode worker with the most free slots
    that can hold the request's worst-case pages. A staged head that fits
    nowhere *waits* (head-of-line, like the colocated scheduler): its pages
    are already computed and host-staged, so holding it costs no device
    memory, and FCFS keeps it starvation-free.

    ``staging_depth`` bounds the number of prefills in flight past the
    waiting queue (assigned to a prefill worker or already staged): when a
    decode-capacity stall stops ``route_decode`` from draining ``staged``,
    ``route_prefill`` stops feeding the prefill workers instead of growing
    the staged queue without bound — backpressure propagates to the global
    waiting queue, whose ``max_queue`` door 429s. None = unbounded (the
    pre-limit behavior).
    """

    def __init__(self, *, max_queue: int = 256,
                 staging_depth: int | None = None, tracer=None):
        assert staging_depth is None or staging_depth >= 1
        self.max_queue = max_queue
        self.staging_depth = staging_depth
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.waiting: deque[Request] = deque()
        # preempted requests re-prefilling (recompute path): drained ahead
        # of the FCFS waiting queue — they were already admitted once
        self.preempted: deque[Request] = deque()
        self.staged: deque = deque()           # FinishedPrefill artifacts
        self.rejected: list[int] = []

    def submit(self, req: Request) -> bool:
        """Queue-depth admission control at the global door (429 = False)."""
        if len(self.waiting) >= self.max_queue:
            self.rejected.append(req.id)
            self.tracer.instant("router", "reject", rid=req.id,
                                reason="queue_full")
            return False
        self.waiting.append(req)
        self.tracer.instant("router", "admit", rid=req.id,
                            queued=len(self.waiting))
        return True

    def route_prefill(self, workers) -> list:
        """Assign waiting requests to prefill workers; returns the
        (worker, request) assignments made this call.

        With a ``staging_depth``, assignments stop once the in-flight
        count (prefill-worker load + staged artifacts) reaches the limit —
        a stalled decode side backpressures prefill instead of piling
        finished pages into ``staged``."""
        out = []
        inflight = (sum(w.load for w in workers) + len(self.staged)
                    if self.staging_depth is not None else 0)
        while self.waiting or self.preempted:
            if (self.staging_depth is not None
                    and inflight >= self.staging_depth):
                break
            ranked = sorted((w for w in workers if w.can_accept()),
                            key=lambda w: (w.load, w.worker_id))
            if not ranked:
                break
            q = self.preempted if self.preempted else self.waiting
            req = q.popleft()
            ranked[0].submit(req)
            inflight += 1
            self.tracer.instant("router", "route_prefill", rid=req.id,
                                worker=ranked[0].worker_id,
                                load=ranked[0].load)
            out.append((ranked[0], req))
        return out

    def stage(self, finished) -> None:
        """Park a finished prefill until a decode worker can take it."""
        self.staged.append(finished)
        # getattr: the artifact is duck-typed (tests stage bare fakes)
        self.tracer.instant("router", "stage", rid=finished.req.id,
                            prefill_worker=getattr(finished, "worker_id",
                                                   None),
                            staged=len(self.staged))

    def route_decode(self, workers, place=None) -> list:
        """Offer staged prefills FCFS to decode workers.

        ``place(worker, finished)`` is invoked immediately per placement so
        worker capacity (slots, free pages) is re-evaluated live — two
        staged prefills must not both be routed against the capacity the
        first one is about to consume. Returns the placements made."""
        out = []
        while self.staged:
            req = self.staged[0].req
            ranked = sorted((w for w in workers if w.can_accept(req)),
                            key=lambda w: (-w.free_slots, w.worker_id))
            if not ranked:
                break
            fin = self.staged.popleft()
            self.tracer.instant("router", "route_decode", rid=fin.req.id,
                                worker=ranked[0].worker_id,
                                free_slots=ranked[0].free_slots)
            if place is not None:
                place(ranked[0], fin)
            out.append((ranked[0], fin))
        return out

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.preempted or self.staged)


def derive_seed(seed: int | None, i: int) -> int | None:
    """Per-request sampling seed from one trace-level seed — the single
    definition every trace builder and engine uses, so a trace replays
    token-identically whichever engine serves it."""
    return None if seed is None else seed * 100003 + i


def make_requests(prompts, max_new_tokens: int, *, temperature: float = 0.0,
                  top_k: int = 0, seed: int | None = None,
                  priority: str = "latency") -> list[Request]:
    """Requests for a batch of prompts, all arriving at t=0 (the engines'
    ``generate`` convenience); sampling knobs apply to every request."""
    return [Request(id=i, prompt=tuple(p), max_new_tokens=max_new_tokens,
                    temperature=temperature, top_k=top_k,
                    seed=derive_seed(seed, i), priority=priority)
            for i, p in enumerate(prompts)]


def poisson_trace(n: int, rate: float, *, vocab: int, prompt_len: int,
                  max_new_tokens: int, seed: int = 0, temperature: float = 0.0,
                  top_k: int = 0, best_effort_frac: float = 0.0,
                  shared_prefix_len: int = 0) -> list[Request]:
    """n requests with exp(1/rate) inter-arrival gaps (rate in req/s).
    Sampling knobs apply to every request; per-request sampling seeds
    derive from ``seed`` so a trace replays deterministically.
    ``best_effort_frac`` marks that (deterministic, seed-derived) fraction
    of requests "best_effort" — the tier SLO-aware admission sheds first.
    ``shared_prefix_len`` prepends one seed-derived common token run to
    every prompt (a shared system prompt / few-shot block): the workload
    shape the prefix cache deduplicates."""
    assert 0 <= shared_prefix_len <= prompt_len
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, n))
    tiers = rng.random(n) < best_effort_frac
    common = tuple(int(x) for x in
                   rng.integers(0, vocab, shared_prefix_len))
    uniq = prompt_len - shared_prefix_len
    return [Request(id=i,
                    prompt=common + tuple(int(x) for x in
                                          rng.integers(0, vocab, uniq)),
                    max_new_tokens=max_new_tokens,
                    arrival_time=float(t[i]),
                    temperature=temperature, top_k=top_k,
                    seed=derive_seed(seed, i),
                    priority="best_effort" if tiers[i] else "latency")
            for i in range(n)]
