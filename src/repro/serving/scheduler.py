"""Continuous-batching scheduler: iteration-level batching with admission
control.

Pure decision logic over a virtual "now" and a free-page count — no model,
no arrays — so a whole serving day can be simulated deterministically in a
unit test. The engine calls ``schedule()`` once per iteration; new prefills
join the in-flight decode batch whenever a slot and enough pages are free,
and finished sequences are evicted the same step they complete
(``release``), their pages immediately reusable.

Admission is conservative: a request is only scheduled when its *worst
case* page need — ceil((prompt + max_new) / block_size) — fits, so a
scheduled request can never deadlock the pool mid-decode (no preemption
needed). ``submit`` applies queue-depth admission control and is safe to
call from an async producer: it only appends to a deque, so an
``asyncio``/thread frontend can feed arrivals while the engine loop runs.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request."""

    id: int
    prompt: tuple          # token ids
    max_new_tokens: int
    arrival_time: float = 0.0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


@dataclasses.dataclass
class SeqState:
    """Scheduler-side state of an admitted sequence."""

    req: Request
    slot: int
    length: int            # tokens with KV in cache
    generated: int = 0

    @property
    def done(self) -> bool:
        return self.generated >= self.req.max_new_tokens


class ContinuousBatchingScheduler:
    def __init__(self, *, max_slots: int, block_size: int,
                 max_queue: int = 256):
        self.max_slots = max_slots
        self.block_size = block_size
        self.max_queue = max_queue
        self.waiting: deque[Request] = deque()
        self.active: dict[int, SeqState] = {}       # slot -> state
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self.rejected: list[int] = []

    # ------------------------------------------------------------ intake

    def blocks_for(self, req: Request) -> int:
        total = req.prompt_len + req.max_new_tokens
        return -(-total // self.block_size)

    def submit(self, req: Request) -> bool:
        """Admission control at the queue door; False = rejected (429)."""
        if len(self.waiting) >= self.max_queue:
            self.rejected.append(req.id)
            return False
        self.waiting.append(req)
        return True

    # ------------------------------------------------------------ per step

    def schedule(self, free_blocks: int) -> list[SeqState]:
        """Admit FCFS from the queue into free slots while pages last.

        Returns newly admitted sequences (their prefill runs this
        iteration). Head-of-line blocking is intentional: FCFS keeps the
        schedule deterministic and starvation-free.
        """
        admitted = []
        while self.waiting and self._free_slots:
            need = self.blocks_for(self.waiting[0])
            if need > free_blocks:
                break
            req = self.waiting.popleft()
            slot = self._free_slots.pop()
            st = SeqState(req=req, slot=slot, length=0)
            self.active[slot] = st
            admitted.append(st)
            free_blocks -= need
        return admitted

    def step_decoded(self) -> list[SeqState]:
        """Account one decoded token per active sequence; return the ones
        that just finished (caller evicts them this same iteration)."""
        finished = []
        for st in self.active.values():
            st.length += 1
            st.generated += 1
            if st.done:
                finished.append(st)
        return finished

    def release(self, st: SeqState) -> None:
        del self.active[st.slot]
        self._free_slots.append(st.slot)
        self._free_slots.sort(reverse=True)   # deterministic reuse order

    # ------------------------------------------------------------ queries

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    def active_slots(self) -> list[int]:
        return sorted(self.active)


def poisson_trace(n: int, rate: float, *, vocab: int, prompt_len: int,
                  max_new_tokens: int, seed: int = 0) -> list[Request]:
    """n requests with exp(1/rate) inter-arrival gaps (rate in req/s)."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(id=i,
                    prompt=tuple(int(x) for x in
                                 rng.integers(0, vocab, prompt_len)),
                    max_new_tokens=max_new_tokens,
                    arrival_time=float(t[i]))
            for i in range(n)]
