"""Paper fig. 1/2: quantize the MLP's last layer (64x10), sweep the number of
values, report post-quantization accuracy and solver runtime per method."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ALL_METHODS, quantize
from repro.models.mlp import mlp_accuracy

from .common import emit, timed_quant, train_paper_mlp

COUNT_METHODS = ["kmeans", "kmeans_ls", "mog", "dtc", "iter_l1", "dp", "l0",
                 "tv_iter"]
LAM_GRID = [3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2]
COUNTS = [2, 4, 8, 16, 32, 64]


def run() -> None:
    params, (xtr, ytr), (xte, yte), acc_tr, acc_te = train_paper_mlp()
    emit("nn_weights/baseline_acc", 0.0,
         f"train={acc_tr:.4f};test={acc_te:.4f}")
    w = np.asarray(params[-1]["w"])          # the 64x10 last layer
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

    def acc_with(wq):
        p2 = [dict(l) for l in params]
        p2[-1]["w"] = jnp.asarray(wq)
        return float(mlp_accuracy(p2, xte_j, yte_j))

    for method in COUNT_METHODS:
        for l in COUNTS:
            (qt, info), dt = timed_quant(w, method, num_values=l)
            a = acc_with(np.asarray(qt.to_dense()))
            emit(f"nn_weights/{method}/l{l}", dt * 1e6,
                 f"acc={a:.4f};n={info['n_values']};l2={info['l2_loss']:.5f}")

    for method in ("l1", "l1_ls", "l1l2", "tv"):
        for lam in LAM_GRID:
            (qt, info), dt = timed_quant(w, method, lam=lam)
            a = acc_with(np.asarray(qt.to_dense()))
            emit(f"nn_weights/{method}/lam{lam:g}", dt * 1e6,
                 f"acc={a:.4f};n={info['n_values']};l2={info['l2_loss']:.5f}")
