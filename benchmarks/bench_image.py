"""Paper fig. 5/6: image quantization with hard-sigmoid range clamping
([0,1]); l2 loss + runtime; includes the l0 method (fig. 6)."""
from __future__ import annotations

import time

from repro.core import quantize

from .common import emit, synthetic_image, timed_quant

METHODS = ["kmeans", "kmeans_ls", "l0", "iter_l1", "dp"]
LAM_METHODS = ["l1", "l1_ls", "tv"]
COUNTS = [2, 4, 8, 16, 32]
LAMS = [1e-3, 4e-3, 1.6e-2, 6.4e-2]


def run() -> None:
    img = synthetic_image()
    for method in METHODS:
        for l in COUNTS:
            (qt, info), dt = timed_quant(img, method, num_values=l,
                                         clip=(0.0, 1.0))
            emit(f"image/{method}/l{l}", dt * 1e6,
                 f"l2={info['l2_loss']:.5f};n={info['n_values']}")
    for method in LAM_METHODS:
        for lam in LAMS:
            (qt, info), dt = timed_quant(img, method, lam=lam,
                                         clip=(0.0, 1.0))
            emit(f"image/{method}/lam{lam:g}", dt * 1e6,
                 f"l2={info['l2_loss']:.5f};n={info['n_values']}")
