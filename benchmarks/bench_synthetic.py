"""Paper fig. 7/8: quantize MoG / uniform / Gaussian samples (500 points in
[0,100]); l2 loss and runtime per method per cluster count."""
from __future__ import annotations

import time

from repro.core import quantize

from .common import emit, synthetic_distributions, timed_quant

METHODS = ["kmeans", "kmeans_ls", "mog", "dtc", "iter_l1", "dp", "tv_iter"]
LAM_METHODS = ["l1", "l1_ls", "tv"]
COUNTS = [2, 4, 8, 16, 32, 64]
LAMS = [0.5, 2.0, 8.0, 32.0, 128.0]


def run() -> None:
    data = synthetic_distributions()
    for dist, w in data.items():
        for method in METHODS:
            for l in COUNTS:
                (qt, info), dt = timed_quant(w, method, num_values=l,
                                             clip=(0.0, 100.0))
                emit(f"synthetic/{dist}/{method}/l{l}", dt * 1e6,
                     f"l2={info['l2_loss']:.4f};n={info['n_values']}")
        for method in LAM_METHODS:
            for lam in LAMS:
                (qt, info), dt = timed_quant(w, method, lam=lam,
                                             clip=(0.0, 100.0))
                emit(f"synthetic/{dist}/{method}/lam{lam:g}", dt * 1e6,
                     f"l2={info['l2_loss']:.4f};n={info['n_values']}")
