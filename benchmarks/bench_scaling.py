"""Paper §3.6 complexity claims: runtime vs m (unique values) and vs k
(clusters). The l1/CD path is O(t*m) (our O(m)-per-sweep reformulation,
vs the paper's O(t*m^2)); k-means is O(t*k*T*m). The crossover where CD
wins - k in Theta(m), 'high-resolution quantization' - is the paper's
headline runtime scenario."""
from __future__ import annotations

import time

import numpy as np

from repro.core import quantize

from .common import emit


def run() -> None:
    rng = np.random.default_rng(0)
    # runtime vs m at fixed k
    for m in (256, 1024, 4096, 16384):
        w = rng.normal(0, 1, m * 2).round(6)   # ~m unique values
        quantize(w, "l1_ls:lam=0.001")
        t0 = time.perf_counter()
        _, i1 = quantize(w, "l1_ls:lam=0.001")
        t1 = time.perf_counter()
        quantize(w, "kmeans@64")
        t2 = time.perf_counter()
        _, i2 = quantize(w, "kmeans@64")
        t3 = time.perf_counter()
        emit(f"scaling_m/{m}", (t1 - t0) * 1e6,
             f"l1_ls_s={t1-t0:.4f};kmeans_s={t3-t2:.4f}")
    # runtime vs k at fixed m: high-resolution regime (k -> m)
    w = rng.normal(0, 1, 4096).round(6)
    for k in (16, 64, 256, 1024):
        quantize(w, f"kmeans@{k}")
        t0 = time.perf_counter()
        quantize(w, f"kmeans@{k}")
        t1 = time.perf_counter()
        quantize(w, f"tv_iter@{k}")
        t2 = time.perf_counter()
        quantize(w, f"tv_iter@{k}")
        t3 = time.perf_counter()
        emit(f"scaling_k/{k}", (t1 - t0) * 1e6,
             f"kmeans_s={t1-t0:.4f};tv_iter_s={t3-t2:.4f}")
