"""Quant-API microbench: host vs device-batch solve latency per registered
method.

One row per registry entry: the host path times ``core.quantize`` on a
single gaussian vector; methods with a ``device_batch`` registry entry
additionally time the batched device row solver (the KV-freeze path) on a
(R, E) row block and report the per-row amortized cost. Every row carries
the originating QuantSpec JSON so the perf trajectory attributes to an
exact solver configuration.

Emits CSV rows plus the standard BENCH_quant_api.json artifact.

    PYTHONPATH=src python -m benchmarks.run quant_api
    PYTHONPATH=src python -m benchmarks.bench_quant_api --n 512 --rows 16
"""
from __future__ import annotations

import argparse

from .common import bench_json, emit, timed


def _spec_for(method: str, num_values: int):
    from repro.core import QuantSpec, registry

    if registry.get(method).param_kind == "count":
        return QuantSpec(method, num_values=num_values, weighted=True)
    return QuantSpec(method, lam=0.05, weighted=True)


def run(n: int = 512, rows: int = 16, num_values: int = 16,
        iters: int = 2, seed: int = 0) -> None:
    import jax
    import numpy as np

    from repro.core import quantize, registry

    rng = np.random.default_rng(seed)
    w = rng.normal(size=n).astype(np.float32)
    row_block = jax.numpy.asarray(
        rng.normal(size=(rows, n)).astype(np.float32))
    results = []
    for method in registry.methods():
        spec = _spec_for(method, num_values)
        (_, info), dt_host = timed(quantize, w, spec, warmup=1, iters=iters)
        row = {"method": method, "spec": spec.to_json(),
               "param_kind": spec.param_kind, "n": n,
               "host_us_per_call": dt_host * 1e6,
               "l2_loss": info["l2_loss"], "n_values": info["n_values"],
               "device_batch": spec.device_capable}
        if spec.device_capable:
            solve = registry.device_batch_solve(method)
            _, dt_dev = timed(
                lambda: jax.block_until_ready(solve(row_block, spec)),
                warmup=1, iters=iters)
            row["device_us_per_batch"] = dt_dev * 1e6
            row["device_us_per_row"] = dt_dev * 1e6 / rows
            row["device_rows"] = rows
        results.append(row)
        dev = (f";dev_us_per_row={row['device_us_per_row']:.0f}"
               if spec.device_capable else "")
        emit(f"quant_api/{spec}", dt_host * 1e6,
             f"l2={info['l2_loss']:.4f};n_values={info['n_values']}{dev}")
    bench_json("quant_api", results,
               meta={"n": n, "rows": rows, "num_values": num_values,
                     "backend": jax.default_backend()})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--num-values", type=int, default=16)
    ap.add_argument("--iters", type=int, default=2)
    args = ap.parse_args()
    run(n=args.n, rows=args.rows, num_values=args.num_values,
        iters=args.iters)
