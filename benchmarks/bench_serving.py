"""Continuous-batching serving benchmark: tokens/s, TTFT, and p50/p99 TPOT
under Poisson arrivals at several request rates, fp vs codebook-quantized
KV pages. Each rate is measured two ways:

  cache="unbounded"  both engines get pages for every slot — isolates the
      pure compute overhead quantization adds (freeze solves + dequant).
  cache="matched"    both engines get the same KV byte budget (enough fp
      pages for half the slots) and the trace arrives as one burst, so
      admission control is the bottleneck; the quantized engine's frozen
      pages cost ~7x less, the same bytes hold more pages, and more
      requests decode concurrently — the throughput KV compression
      actually buys at fixed cache memory.

Emits CSV rows plus the standard BENCH_serving.json artifact.

    PYTHONPATH=src python -m benchmarks.run serving
    PYTHONPATH=src python -m benchmarks.bench_serving --rates 2,8 --gen 12
"""
from __future__ import annotations

import argparse
import dataclasses

from .common import bench_json, emit

ARCH = "qwen3_0_6b"


def _budget_blocks(cfg, *, block_size, kv_quant, kv_num_values, bpr,
                   max_slots):
    """Page counts under a shared byte budget of ``max_slots/2`` requests'
    fp pages. Steady state keeps one hot (fp) page per sequence and
    freezes the rest, so quantized pages cost the blended per-request mix."""
    from repro.serving import page_bytes

    budget = max(1, max_slots // 2) * bpr * page_bytes(
        cfg, block_size, quantized=False, num_values=kv_num_values)["fp"]
    pb = page_bytes(cfg, block_size, quantized=kv_quant is not None,
                    num_values=kv_num_values)
    blended = (pb["frozen"] * (bpr - 1) + pb["fp"]) / bpr
    return int(budget // blended) + 1, budget


def _engine(params, cfg, *, prompt_len, gen, kv_quant, kv_num_values,
            max_slots, block_size, num_blocks=None):
    from repro.serving import ContinuousBatchingEngine

    return ContinuousBatchingEngine(
        params, cfg, max_slots=max_slots, block_size=block_size,
        max_seq_len=-(-(prompt_len + gen) // block_size) * block_size,
        kv_quant=kv_quant, kv_num_values=kv_num_values,
        num_blocks=num_blocks)


def _one(params, cfg, *, rate, n, prompt_len, gen, kv_quant, kv_num_values,
         max_slots, block_size, seed, cache="unbounded"):
    from repro.serving.scheduler import poisson_trace

    num_blocks = budget = None
    if cache == "matched":
        bpr = -(-(prompt_len + gen) // block_size)
        num_blocks, budget = _budget_blocks(
            cfg, block_size=block_size, kv_quant=kv_quant,
            kv_num_values=kv_num_values, bpr=bpr, max_slots=max_slots)
    eng = _engine(params, cfg, prompt_len=prompt_len, gen=gen,
                  kv_quant=kv_quant, kv_num_values=kv_num_values,
                  max_slots=max_slots, block_size=block_size,
                  num_blocks=num_blocks)
    trace = poisson_trace(n, rate, vocab=cfg.vocab, prompt_len=prompt_len,
                          max_new_tokens=gen, seed=seed)
    if cache == "matched":      # burst: page budget, not arrivals, gates
        trace = [dataclasses.replace(r, arrival_time=0.0) for r in trace]
    s = eng.run(trace)
    s.update(rate=rate, kv="fp" if eng.kv_spec is None else str(eng.kv_spec),
             num_requests=n, prompt_len=prompt_len, gen=gen, cache=cache,
             num_blocks=eng.num_blocks, cache_budget_bytes=budget,
             # originating QuantSpec, so perf trajectories attribute to an
             # exact solver configuration
             spec=None if eng.kv_spec is None else eng.kv_spec.to_json())
    return s


def run(rates=(2.0, 8.0), n=8, prompt_len=32, gen=12, kv_num_values=16,
        max_slots=4, block_size=16, seed=0) -> None:
    import jax
    import numpy as np

    from repro import models
    from repro.configs import get_reduced_config

    cfg = get_reduced_config(ARCH)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    results = []
    for kv_quant in (None, "kmeans_ls"):
        for cache in ("unbounded", "matched"):
            # warm the shared jit caches at this pool geometry (prefill and
            # decode at every block count, freeze solver shapes) so measured
            # runs report steady-state serving
            rng = np.random.default_rng(123)
            nb = None
            if cache == "matched":
                bpr = -(-(prompt_len + gen) // block_size)
                nb, _ = _budget_blocks(cfg, block_size=block_size,
                                       kv_quant=kv_quant,
                                       kv_num_values=kv_num_values, bpr=bpr,
                                       max_slots=max_slots)
            warm = _engine(params, cfg, prompt_len=prompt_len, gen=gen,
                           kv_quant=kv_quant, kv_num_values=kv_num_values,
                           max_slots=max_slots, block_size=block_size,
                           num_blocks=nb)
            # decreasing bursts cover every freeze-flush bucket (aligned
            # prefills) on top of the prefill/decode block counts
            for burst in (max_slots, 2, 1):
                warm.generate([rng.integers(0, cfg.vocab, prompt_len).tolist()
                               for _ in range(burst)], max_new_tokens=gen)
            # matched is one burst scenario (arrivals are zeroed, so the
            # nominal rate is irrelevant); best-of-reps de-noises shared
            # hosts, since a burst run lasts only a few hundred ms
            scenarios = ([("burst", r) for r in (rates[:1] * 3)]
                         if cache == "matched"
                         else [(f"rate{r:g}", r) for r in rates])
            best = {}
            for label, rate in scenarios:
                s = _one(params, cfg, rate=rate, n=n, prompt_len=prompt_len,
                         gen=gen, kv_quant=kv_quant,
                         kv_num_values=kv_num_values, max_slots=max_slots,
                         block_size=block_size, seed=seed, cache=cache)
                s["trace"] = label
                if (label not in best or s["throughput_tok_s"]
                        > best[label]["throughput_tok_s"]):
                    best[label] = s
            for label, s in best.items():
                results.append(s)
                emit(f"serving/{s['kv']}/{cache}/{label}",
                     s["tpot_p50_s"] * 1e6,
                     f"tok_s={s['throughput_tok_s']:.1f};"
                     f"ttft_p50_ms={s['ttft_p50_s']*1e3:.0f};"
                     f"tpot_p99_ms={s['tpot_p99_s']*1e3:.1f};"
                     f"pages={s['num_blocks']};"
                     f"compress={s.get('cache_compression_final', 1.0):.2f}x")
    bench_json("serving", results,
               meta={"arch": ARCH, "reduced": True, "max_slots": max_slots,
                     "block_size": block_size, "kv_num_values": kv_num_values})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="2,8")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--kv-num-values", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    args = ap.parse_args()
    run(rates=tuple(float(r) for r in args.rates.split(",")),
        n=args.num_requests, prompt_len=args.prompt_len, gen=args.gen,
        kv_num_values=args.kv_num_values, max_slots=args.max_slots,
        block_size=args.block_size)
