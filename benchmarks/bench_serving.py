"""Continuous-batching serving benchmark: tokens/s, TTFT, and p50/p99 TPOT
under Poisson arrivals at several request rates, fp vs codebook-quantized
KV pages. Emits CSV rows plus the standard BENCH_serving.json artifact.

    PYTHONPATH=src python -m benchmarks.run serving
    PYTHONPATH=src python -m benchmarks.bench_serving --rates 2,8 --gen 12
"""
from __future__ import annotations

import argparse

from .common import bench_json, emit

ARCH = "qwen3_0_6b"


def _one(params, cfg, *, rate, n, prompt_len, gen, kv_quant, kv_num_values,
         max_slots, block_size, seed):
    from repro.serving import ContinuousBatchingEngine
    from repro.serving.scheduler import poisson_trace

    eng = ContinuousBatchingEngine(
        params, cfg, max_slots=max_slots, block_size=block_size,
        max_seq_len=-(-(prompt_len + gen) // block_size) * block_size,
        kv_quant=kv_quant, kv_num_values=kv_num_values)
    trace = poisson_trace(n, rate, vocab=cfg.vocab, prompt_len=prompt_len,
                          max_new_tokens=gen, seed=seed)
    s = eng.run(trace)
    s.update(rate=rate, kv="fp" if kv_quant is None else
             f"{kv_quant}@{kv_num_values}", num_requests=n,
             prompt_len=prompt_len, gen=gen)
    return s


def run(rates=(2.0, 8.0), n=6, prompt_len=32, gen=12, kv_num_values=16,
        max_slots=4, block_size=16, seed=0) -> None:
    import jax

    from repro import models
    from repro.configs import get_reduced_config

    cfg = get_reduced_config(ARCH)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    results = []
    for kv_quant in (None, "kmeans_ls"):
        for rate in rates:
            s = _one(params, cfg, rate=rate, n=n, prompt_len=prompt_len,
                     gen=gen, kv_quant=kv_quant, kv_num_values=kv_num_values,
                     max_slots=max_slots, block_size=block_size, seed=seed)
            results.append(s)
            emit(f"serving/{s['kv']}/rate{rate:g}", s["tpot_p50_s"] * 1e6,
                 f"tok_s={s['throughput_tok_s']:.1f};"
                 f"ttft_p50_ms={s['ttft_p50_s']*1e3:.0f};"
                 f"tpot_p99_ms={s['tpot_p99_s']*1e3:.1f};"
                 f"compress={s.get('cache_compression_final', 1.0):.2f}x")
    bench_json("serving", results,
               meta={"arch": ARCH, "reduced": True, "max_slots": max_slots,
                     "block_size": block_size, "kv_num_values": kv_num_values})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="2,8")
    ap.add_argument("--num-requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--kv-num-values", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    args = ap.parse_args()
    run(rates=tuple(float(r) for r in args.rates.split(",")),
        n=args.num_requests, prompt_len=args.prompt_len, gen=args.gen,
        kv_num_values=args.kv_num_values, max_slots=args.max_slots,
        block_size=args.block_size)
