"""Continuous-batching serving benchmark: tokens/s, TTFT, and p50/p99 TPOT
under Poisson arrivals at several request rates, fp vs codebook-quantized
KV pages. Each rate is measured two ways:

  cache="unbounded"  both engines get pages for every slot — isolates the
      pure compute overhead quantization adds (freeze solves + dequant).
  cache="matched"    both engines get the same KV byte budget (enough fp
      pages for half the slots) and the trace arrives as one burst, so
      admission control is the bottleneck; the quantized engine's frozen
      pages cost ~7x less, the same bytes hold more pages, and more
      requests decode concurrently — the throughput KV compression
      actually buys at fixed cache memory.

Emits CSV rows plus the standard BENCH_serving.json artifact.

    PYTHONPATH=src python -m benchmarks.run serving
    PYTHONPATH=src python -m benchmarks.bench_serving --rates 2,8 --gen 12
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from .common import bench_json, emit

ARCH = "qwen3_0_6b"


def _budget_blocks(cfg, *, block_size, kv_quant, kv_num_values, bpr,
                   max_slots):
    """Page counts under a shared byte budget of ``max_slots/2`` requests'
    fp pages. Steady state keeps one hot (fp) page per sequence and
    freezes the rest, so quantized pages cost the blended per-request mix."""
    from repro.serving import page_bytes

    budget = max(1, max_slots // 2) * bpr * page_bytes(
        cfg, block_size, quantized=False, num_values=kv_num_values)["fp"]
    pb = page_bytes(cfg, block_size, quantized=kv_quant is not None,
                    num_values=kv_num_values)
    blended = (pb["frozen"] * (bpr - 1) + pb["fp"]) / bpr
    return int(budget // blended) + 1, budget


def _engine(params, cfg, *, prompt_len, gen, kv_quant, kv_num_values,
            max_slots, block_size, num_blocks=None):
    from repro.serving import ContinuousBatchingEngine

    return ContinuousBatchingEngine(
        params, cfg, max_slots=max_slots, block_size=block_size,
        max_seq_len=-(-(prompt_len + gen) // block_size) * block_size,
        kv_quant=kv_quant, kv_num_values=kv_num_values,
        num_blocks=num_blocks)


def _one(params, cfg, *, rate, n, prompt_len, gen, kv_quant, kv_num_values,
         max_slots, block_size, seed, cache="unbounded"):
    from repro.serving.scheduler import poisson_trace

    num_blocks = budget = None
    if cache == "matched":
        bpr = -(-(prompt_len + gen) // block_size)
        num_blocks, budget = _budget_blocks(
            cfg, block_size=block_size, kv_quant=kv_quant,
            kv_num_values=kv_num_values, bpr=bpr, max_slots=max_slots)
    eng = _engine(params, cfg, prompt_len=prompt_len, gen=gen,
                  kv_quant=kv_quant, kv_num_values=kv_num_values,
                  max_slots=max_slots, block_size=block_size,
                  num_blocks=num_blocks)
    trace = poisson_trace(n, rate, vocab=cfg.vocab, prompt_len=prompt_len,
                          max_new_tokens=gen, seed=seed)
    if cache == "matched":      # burst: page budget, not arrivals, gates
        trace = [dataclasses.replace(r, arrival_time=0.0) for r in trace]
    s = eng.run(trace)
    s.update(rate=rate, kv="fp" if eng.kv_spec is None else str(eng.kv_spec),
             num_requests=n, prompt_len=prompt_len, gen=gen, cache=cache,
             num_blocks=eng.num_blocks, cache_budget_bytes=budget,
             # originating QuantSpec, so perf trajectories attribute to an
             # exact solver configuration
             spec=None if eng.kv_spec is None else eng.kv_spec.to_json())
    return s


def run(rates=(2.0, 8.0), n=8, prompt_len=32, gen=12, kv_num_values=16,
        max_slots=4, block_size=16, seed=0) -> None:
    import jax
    import numpy as np

    from repro import models
    from repro.configs import get_reduced_config

    cfg = get_reduced_config(ARCH)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    results = []
    for kv_quant in (None, "kmeans_ls"):
        for cache in ("unbounded", "matched"):
            # warm the shared jit caches at this pool geometry (prefill and
            # decode at every block count, freeze solver shapes) so measured
            # runs report steady-state serving
            rng = np.random.default_rng(123)
            nb = None
            if cache == "matched":
                bpr = -(-(prompt_len + gen) // block_size)
                nb, _ = _budget_blocks(cfg, block_size=block_size,
                                       kv_quant=kv_quant,
                                       kv_num_values=kv_num_values, bpr=bpr,
                                       max_slots=max_slots)
            warm = _engine(params, cfg, prompt_len=prompt_len, gen=gen,
                           kv_quant=kv_quant, kv_num_values=kv_num_values,
                           max_slots=max_slots, block_size=block_size,
                           num_blocks=nb)
            # decreasing bursts cover every freeze-flush bucket (aligned
            # prefills) on top of the prefill/decode block counts
            for burst in (max_slots, 2, 1):
                warm.generate([rng.integers(0, cfg.vocab, prompt_len).tolist()
                               for _ in range(burst)], max_new_tokens=gen)
            # matched is one burst scenario (arrivals are zeroed, so the
            # nominal rate is irrelevant); best-of-reps de-noises shared
            # hosts, since a burst run lasts only a few hundred ms
            scenarios = ([("burst", r) for r in (rates[:1] * 3)]
                         if cache == "matched"
                         else [(f"rate{r:g}", r) for r in rates])
            best = {}
            for label, rate in scenarios:
                s = _one(params, cfg, rate=rate, n=n, prompt_len=prompt_len,
                         gen=gen, kv_quant=kv_quant,
                         kv_num_values=kv_num_values, max_slots=max_slots,
                         block_size=block_size, seed=seed, cache=cache)
                s["trace"] = label
                if (label not in best or s["throughput_tok_s"]
                        > best[label]["throughput_tok_s"]):
                    best[label] = s
            for label, s in best.items():
                results.append(s)
                emit(f"serving/{s['kv']}/{cache}/{label}",
                     s["tpot_p50_s"] * 1e6,
                     f"tok_s={s['throughput_tok_s']:.1f};"
                     f"ttft_p50_ms={s['ttft_p50_s']*1e3:.0f};"
                     f"tpot_p99_ms={s['tpot_p99_s']*1e3:.1f};"
                     f"pages={s['num_blocks']};"
                     f"compress={s.get('cache_compression_final', 1.0):.2f}x")
    results.append(run_obs_overhead(
        params, cfg, n=n, prompt_len=prompt_len, gen=gen,
        kv_num_values=kv_num_values, max_slots=max_slots,
        block_size=block_size, seed=seed))
    results += run_chunked_prefill(
        params, cfg, max_slots=max_slots, block_size=block_size, seed=seed)
    bench_json("serving", results,
               meta={"arch": ARCH, "reduced": True, "max_slots": max_slots,
                     "block_size": block_size, "kv_num_values": kv_num_values})


# ------------------------------------------------------- obs overhead


def run_obs_overhead(params, cfg, *, n=8, prompt_len=32, gen=12,
                     kv_num_values=16, max_slots=4, block_size=16, reps=3,
                     seed=0) -> dict:
    """Observability overhead guard -> one BENCH_serving.json row.

    The same quantized burst trace is served with tracing fully on
    (``Tracer()``: router, decode-step phases, per-page freeze lifecycle,
    cache/roofline counter tracks all recorded) and with the default
    ``NULL_TRACER``; best-of-``reps`` throughput per arm de-noises shared
    hosts. The in-bench assert is the regression gate: tracing must not
    cost 5% tokens/s."""
    from repro.obs import NULL_TRACER, Tracer
    from repro.serving import ContinuousBatchingEngine
    from repro.serving.scheduler import make_requests

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, prompt_len).tolist()
               for _ in range(n)]

    def one(tracer):
        eng = ContinuousBatchingEngine(
            params, cfg, max_slots=max_slots, block_size=block_size,
            max_seq_len=-(-(prompt_len + gen) // block_size) * block_size,
            kv_quant="kmeans_ls", kv_num_values=kv_num_values,
            tracer=tracer)
        return eng.run(make_requests(prompts, gen))

    one(NULL_TRACER)                          # warm the jit caches
    tok = {}
    for arm, make_tracer in (("off", lambda: NULL_TRACER), ("on", Tracer)):
        tok[arm] = max(one(make_tracer())["throughput_tok_s"]
                       for _ in range(reps))
    frac = 1.0 - tok["on"] / tok["off"]
    emit("serving/obs_overhead", 1e6 / tok["on"],
         f"tok_s_on={tok['on']:.1f};tok_s_off={tok['off']:.1f};"
         f"overhead={frac*100:.1f}%")
    assert tok["on"] >= 0.95 * tok["off"], (
        f"tracer overhead {frac*100:.1f}% >= 5%: "
        f"on={tok['on']:.1f} off={tok['off']:.1f} tok/s")
    return {"scenario": "obs_overhead", "tok_s_tracer_on": tok["on"],
            "tok_s_tracer_off": tok["off"], "overhead_frac": frac,
            "reps": reps, "num_requests": n, "prompt_len": prompt_len,
            "gen": gen}


# ------------------------------------------------------ chunked prefill


def run_chunked_prefill(params, cfg, *, max_slots=4, block_size=16, reps=3,
                        seed=0) -> list:
    """Chunked-prefill itl_max guard -> BENCH_serving.json rows.

    Short requests decode while a burst of long prompts lands — the
    colocated engine's worst case, where each inline prefill stalls every
    in-flight decode by a whole prompt's forward pass. ``prefill_chunk``
    admits those prompts ``block_size`` tokens per engine iteration
    instead, so the short cohort's worst inter-token gap (itl_max) shrinks
    to roughly one chunk's compute. Both arms are greedy token-identical
    (asserted); the rows compare tail latency, never quality."""
    from repro.serving import ContinuousBatchingEngine

    prompt_short, gen_short = 16, 48
    prompt_long, gen_long = 96, 4
    n_short, n_long = 2, 3
    max_seq_len = -(-(prompt_long + gen_long) // block_size) * block_size
    n = n_short + n_long

    def short_gaps(eng):
        gaps = [g for rid in range(n_short)
                for g in eng.metrics.traces[rid].gaps]
        return np.asarray(gaps) if gaps else np.zeros(1)

    def engine(chunk):
        return ContinuousBatchingEngine(
            params, cfg, max_slots=max_slots, block_size=block_size,
            max_seq_len=max_seq_len, prefill_chunk=chunk)

    rows, arms, outs = [], {}, {}
    for chunk in (None, block_size):
        rng = np.random.default_rng(123)
        warm = engine(chunk)
        warm.generate([rng.integers(0, cfg.vocab, p).tolist()
                       for p in (prompt_short, prompt_long)],
                      max_new_tokens=gen_long)
        best = None
        for _ in range(reps):
            eng = engine(chunk)
            trace = _burst_trace(cfg, n_short=n_short,
                                 prompt_short=prompt_short,
                                 gen_short=gen_short, n_long=n_long,
                                 prompt_long=prompt_long, gen_long=gen_long,
                                 burst_at=0.05, seed=seed)
            s = eng.run(trace)
            gaps = short_gaps(eng)
            s["short_itl_max_s"] = float(gaps.max())
            s["short_itl_p99_s"] = float(np.percentile(gaps, 99))
            if best is None or s["short_itl_max_s"] < best["short_itl_max_s"]:
                best = s
                outs[chunk] = {i: eng.outputs.get(i) for i in range(n)}
        label = "inline" if chunk is None else f"chunk{chunk}"
        best.update(scenario="chunked_prefill_burst", prefill_chunk=chunk,
                    n_short=n_short, n_long=n_long,
                    prompt_short=prompt_short, prompt_long=prompt_long)
        arms[label] = best
        rows.append(best)
        emit(f"serving/chunked_prefill/{label}",
             best["short_itl_max_s"] * 1e6,
             f"itl_max_ms={best['short_itl_max_s']*1e3:.1f};"
             f"itl_p99_ms={best['short_itl_p99_s']*1e3:.1f};"
             f"chunks={best.get('prefill_chunks', 0)};"
             f"tok_s={best['throughput_tok_s']:.1f}")
    # chunking reorders prefill compute, never logits: greedy-identical
    assert outs[block_size] == outs[None], \
        "chunked prefill diverged from inline prefill tokens"
    ratio = (arms["inline"]["short_itl_max_s"]
             / max(arms[f"chunk{block_size}"]["short_itl_max_s"], 1e-9))
    rows.append({"scenario": "chunked_prefill_burst",
                 "prefill_chunk": "comparison",
                 "short_itl_max_improvement_x": ratio})
    print(f"# chunked prefill: short-cohort itl_max "
          f"{arms['inline']['short_itl_max_s']*1e3:.1f}ms inline vs "
          f"{arms[f'chunk{block_size}']['short_itl_max_s']*1e3:.1f}ms "
          f"chunked ({ratio:.2f}x)")
    return rows


# -------------------------------------------------------- prefix sharing


def run_prefix_sharing(reps=3, seed=0, n=6, shared_prefix_len=32,
                       unique_len=8, gen=8, max_slots=6,
                       block_size=8) -> None:
    """Shared-prefix burst -> BENCH_prefix_sharing.json.

    n requests with one common ``shared_prefix_len``-token prompt head (a
    system prompt / few-shot block) and short unique tails land as one
    burst on a page pool deliberately sized to hold only two requests at
    their worst-case page cost. Two arms at the SAME pool:

      baseline   prefix cache off — every request allocates its prompt
          pages privately, so the pool admits two at a time and the burst
          serves in waves (later waves inherit a full generation of queue
          wait in their TTFT).
      shared     ``prefix_cache=True`` — the first request publishes its
          full prompt pages; every follower splices them (refcounted,
          copy-on-write tail) and is charged worst-case-minus-shared at
          admission, so the same bytes hold >1.5x the concurrent
          sequences and follower TTFT drops to roughly one iteration.

    Claims measured per row: peak_resident (max concurrently resident
    sequences, from per-request prefill-start/finish timestamps — the
    capacity sharing buys at fixed cache memory), ttft_p50/p99, and the
    prefix_hits / prefix_shared_pages / cow_copies counters. Sharing
    reuses bitwise-identical pages and CoW isolates every write-hot tail,
    so both arms are greedy token-identical (asserted); the rows compare
    capacity and latency, never quality."""
    import jax

    from repro import models
    from repro.configs import get_reduced_config
    from repro.serving import ContinuousBatchingEngine
    from repro.serving.scheduler import poisson_trace

    cfg = get_reduced_config(ARCH)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    prompt_len = shared_prefix_len + unique_len
    bpr = -(-(prompt_len + gen) // block_size)
    # the last prompt page is always written privately (CoW tail), so at
    # most (prompt_len-1)//block_size pages per follower are shareable
    shareable = (prompt_len - 1) // block_size
    num_blocks = 2 * bpr + 2            # two worst-case requests + slack
    max_seq_len = bpr * block_size

    def engine(prefix_cache):
        return ContinuousBatchingEngine(
            params, cfg, max_slots=max_slots, block_size=block_size,
            max_seq_len=max_seq_len, num_blocks=num_blocks,
            prefix_cache=prefix_cache)

    def trace():
        t = poisson_trace(n, 1.0, vocab=cfg.vocab, prompt_len=prompt_len,
                          max_new_tokens=gen, seed=seed,
                          shared_prefix_len=shared_prefix_len)
        # burst: the page pool, not arrivals, gates admission
        return [dataclasses.replace(r, arrival_time=0.0) for r in t]

    def peak_resident(eng):
        # a sequence holds pool pages from prefill start to finish; the
        # max overlap of those intervals is the measured capacity
        evs = []
        for rid in range(n):
            tr = eng.metrics.traces[rid]
            evs.append((tr.prefill_start_t, 1))
            evs.append((tr.finish_t, -1))
        peak = cur = 0
        for _, d in sorted(evs):
            cur += d
            peak = max(peak, cur)
        return peak

    results, arms, outs = [], {}, {}
    for label, pc in (("baseline", False), ("shared", True)):
        # warm the jit caches for this arm's shapes (full-prompt prefill,
        # and for the shared arm the tail-only prefill after a splice)
        warm = engine(pc)
        warm.run(trace())
        best = None
        for _ in range(reps):
            eng = engine(pc)
            s = eng.run(trace())
            s["peak_resident"] = peak_resident(eng)
            if best is None or s["ttft_p99_s"] < best["ttft_p99_s"]:
                best = s
                outs[label] = {i: eng.outputs.get(i) for i in range(n)}
        best.update(scenario="shared_prefix_burst", prefix_cache=pc,
                    num_requests=n, prompt_len=prompt_len,
                    shared_prefix_len=shared_prefix_len, gen=gen,
                    num_blocks=num_blocks, shareable_pages=shareable)
        arms[label] = best
        results.append(best)
        emit(f"serving/prefix_sharing/{label}", best["ttft_p99_s"] * 1e6,
             f"ttft_p50_ms={best['ttft_p50_s']*1e3:.0f};"
             f"peak_resident={best['peak_resident']};"
             f"hits={best.get('prefix_hits', 0)};"
             f"shared_pages={best.get('prefix_shared_pages', 0)};"
             f"cow={best.get('cow_copies', 0)}")
    # sharing splices bitwise-identical pages and CoW isolates the tails:
    # the sampled tokens must not change
    assert outs["shared"] == outs["baseline"], \
        "prefix sharing diverged from the no-sharing tokens"
    cap_x = (arms["shared"]["peak_resident"]
             / max(arms["baseline"]["peak_resident"], 1))
    ttft_x = (arms["baseline"]["ttft_p99_s"]
              / max(arms["shared"]["ttft_p99_s"], 1e-9))
    assert cap_x > 1.5, (
        f"prefix sharing bought only {cap_x:.2f}x capacity at equal pool "
        f"({arms['shared']['peak_resident']} vs "
        f"{arms['baseline']['peak_resident']} resident)")
    assert ttft_x > 1.0, (
        f"prefix sharing did not reduce tail TTFT: "
        f"{arms['baseline']['ttft_p99_s']*1e3:.1f}ms baseline vs "
        f"{arms['shared']['ttft_p99_s']*1e3:.1f}ms shared")
    results.append({
        "scenario": "shared_prefix_burst", "prefix_cache": "comparison",
        "effective_capacity_x": cap_x, "ttft_p99_improvement_x": ttft_x,
        "ttft_p50_improvement_x": (arms["baseline"]["ttft_p50_s"]
                                   / max(arms["shared"]["ttft_p50_s"], 1e-9)),
        "greedy_identical": True})
    print(f"# prefix sharing: {arms['shared']['peak_resident']} vs "
          f"{arms['baseline']['peak_resident']} resident at "
          f"{num_blocks} pages ({cap_x:.2f}x capacity); ttft_p99 "
          f"{arms['baseline']['ttft_p99_s']*1e3:.1f}ms -> "
          f"{arms['shared']['ttft_p99_s']*1e3:.1f}ms ({ttft_x:.2f}x)")
    bench_json("prefix_sharing", results,
               meta={"arch": ARCH, "reduced": True, "reps": reps,
                     "max_slots": max_slots, "block_size": block_size,
                     "num_blocks": num_blocks})


# ----------------------------------------------------------- speculative


def run_speculative(reps=3, seed=0, n=6, prompt_len=32, gen=16,
                    max_slots=3, block_size=16) -> None:
    """Speculative-decoding scenarios -> BENCH_spec_decode.json.

    The same burst trace is served three ways at equal compute budget
    (same target model, slots, pages): the non-speculative baseline, and
    draft-k speculation for k in (2, 4) with the layer-truncated shared-
    weight draft (``derive_draft``: half the scanned groups, ~half the
    decode FLOPs per draft token). Claims measured per row:

      tokens_per_step   decode-generated tokens per per-sequence decode
          step (batching factored out): 1.0 for the baseline by
          definition, > 1 whenever the verify window accepts drafts.
      tpot_p50/p99      the latency the accepted tokens actually buy —
          a verify window costs ~1 target step + k cheap draft steps for
          up to k+1 tokens.
      spec_acceptance_rate   drafted-token survival under target argmax
          verification.

    Tokens are greedy-identical across all three runs by construction
    (asserted here), so the rows compare speed, never quality.
    """
    import jax

    from repro import models
    from repro.configs import get_reduced_config
    from repro.serving import ContinuousBatchingEngine, derive_draft
    from repro.serving.scheduler import make_requests

    cfg = get_reduced_config(ARCH)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    draft = derive_draft(params, cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, prompt_len).tolist()
               for _ in range(n)]
    max_seq_len = -(-(prompt_len + gen + 8) // block_size) * block_size
    geometry = dict(max_slots=max_slots, block_size=block_size,
                    max_seq_len=max_seq_len)

    def engine(k):
        return ContinuousBatchingEngine(
            params, cfg, speculate=k, draft=draft if k else None, **geometry)

    results, outs = [], {}
    for k in (0, 2, 4):
        # warm the jit caches for this window geometry (prefill, verify
        # window, draft catch-up/single steps, every gather block count)
        warm = engine(k)
        warm.generate(prompts[:2], max_new_tokens=gen)
        best = None
        for _ in range(reps):
            eng = engine(k)
            trace = make_requests(prompts, gen)
            s = eng.run(trace)
            if best is None or s["tpot_p50_s"] < best["tpot_p50_s"]:
                best = s
                outs[k] = {i: eng.outputs.get(i) for i in range(n)}
        best.update(scenario="spec_decode", k=k,
                    draft=None if k == 0 else draft[1].name,
                    num_requests=n, prompt_len=prompt_len, gen=gen)
        results.append(best)
        emit(f"spec_decode/k{k}", best["tpot_p50_s"] * 1e6,
             f"tokens_per_step={best.get('tokens_per_step', 1.0):.2f};"
             f"accept={best.get('spec_acceptance_rate', 0.0):.2f};"
             f"tok_s={best['throughput_tok_s']:.1f};"
             f"tpot_p99_ms={best['tpot_p99_s']*1e3:.1f}")
        # speculative decoding must not change the trace
        assert outs[k] == outs[0], f"k={k} diverged from the baseline trace"
    by_k = {r["k"]: r for r in results}
    results.append({
        "scenario": "spec_decode", "k": "comparison",
        "tokens_per_step_k2": by_k[2].get("tokens_per_step", 1.0),
        "tokens_per_step_k4": by_k[4].get("tokens_per_step", 1.0),
        "tpot_p50_speedup_k4": (by_k[0]["tpot_p50_s"]
                                / max(by_k[4]["tpot_p50_s"], 1e-9)),
        "greedy_identical": True})
    print(f"# spec_decode: tokens/step "
          f"{by_k[2].get('tokens_per_step', 1.0):.2f} (k=2) "
          f"{by_k[4].get('tokens_per_step', 1.0):.2f} (k=4) vs 1.00 "
          f"baseline; tpot_p50 {by_k[0]['tpot_p50_s']*1e3:.1f}ms -> "
          f"{by_k[4]['tpot_p50_s']*1e3:.1f}ms")
    bench_json("spec_decode", results,
               meta={"arch": ARCH, "reduced": True, "reps": reps,
                     "draft": draft[1].name, **geometry})


# ---------------------------------------------------------------- disagg


def _burst_trace(cfg, *, n_short, prompt_short, gen_short, n_long,
                 prompt_long, gen_long, burst_at, seed):
    """Short requests start decoding at t=0; a burst of long prompts lands
    at ``burst_at`` while they decode — the scenario where inline prefill
    stalls every in-flight sequence's inter-token latency."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)

    def mk(i, plen, gen, t):
        return Request(id=i, prompt=tuple(int(x) for x in
                                          rng.integers(0, cfg.vocab, plen)),
                       max_new_tokens=gen, arrival_time=t)

    reqs = [mk(i, prompt_short, gen_short, 0.0) for i in range(n_short)]
    reqs += [mk(n_short + j, prompt_long, gen_long, burst_at)
             for j in range(n_long)]
    return reqs


def _disagg_engine(params, cfg, *, kind, migrate, kv_quant, max_slots,
                   block_size, max_seq_len):
    from repro.serving import ContinuousBatchingEngine, DisaggEngine

    if kind == "colocated":
        return ContinuousBatchingEngine(
            params, cfg, max_slots=max_slots, block_size=block_size,
            max_seq_len=max_seq_len, kv_quant=kv_quant)
    return DisaggEngine(
        params, cfg, prefill_workers=1, decode_workers=1, migrate=migrate,
        max_slots=max_slots, block_size=block_size, max_seq_len=max_seq_len,
        kv_quant=kv_quant)


def run_disagg(reps=3, seed=0, block_size=16, max_slots=6) -> None:
    """Disaggregated-serving scenarios -> BENCH_disagg_serving.json.

    long_prompt_burst   colocated vs disagg(1P/1D) on the same fp trace at
        equal total compute: n_short short requests decode while n_long
        long prompts burst in. Disaggregation's claim is decode isolation —
        the short cohort's inter-token p99 (itl_p99, measured per decode
        gap) must not inherit the burst's prefill time.

    migration           disagg fp vs frozen handoff on a quantized-KV
        burst of block-aligned long prompts: measured bytes crossing the
        prefill->decode seam (frozen = packed 4-bit codes + per-block
        codebooks via the device freeze path) and the latency both modes
        pay. The acceptance ratio is measured-bytes(fp)/measured-bytes(frozen).
    """
    import jax

    from repro import models
    from repro.configs import get_reduced_config

    cfg = get_reduced_config(ARCH)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    prompt_short, gen_short = 16, 64
    prompt_long, gen_long = 112, 4          # 7 full pages at block 16
    n_short, n_long = 2, 4
    max_seq_len = -(-(prompt_long + gen_long) // block_size) * block_size
    geometry = dict(block_size=block_size, max_slots=max_slots,
                    max_seq_len=max_seq_len)
    results = []

    def short_itl_p99(eng):
        gaps = [g for rid in range(n_short)
                for g in eng.metrics.traces[rid].gaps]
        return float(np.percentile(np.asarray(gaps), 99)) if gaps else 0.0

    # --- scenario 1: decode TPOT isolation under a long-prompt burst ----
    iso = {}
    for kind in ("colocated", "disagg"):
        # warm the jit caches for this composition (prefill at both prompt
        # paddings, decode at every gathered block count)
        warm = _disagg_engine(params, cfg, kind=kind, migrate="fp",
                              kv_quant=None, **geometry)
        rng = np.random.default_rng(123)
        warm.generate([rng.integers(0, cfg.vocab, p).tolist()
                       for p in (prompt_short, prompt_long)],
                      max_new_tokens=gen_long)
        best = None
        for rep in range(reps):
            eng = _disagg_engine(params, cfg, kind=kind, migrate="fp",
                                 kv_quant=None, **geometry)
            trace = _burst_trace(cfg, n_short=n_short,
                                 prompt_short=prompt_short,
                                 gen_short=gen_short, n_long=n_long,
                                 prompt_long=prompt_long, gen_long=gen_long,
                                 burst_at=0.05, seed=seed)
            s = eng.run(trace)
            s["short_itl_p99_s"] = short_itl_p99(eng)
            if best is None or s["short_itl_p99_s"] < best["short_itl_p99_s"]:
                best = s
        best.update(scenario="long_prompt_burst", engine=kind,
                    n_short=n_short, n_long=n_long,
                    prompt_short=prompt_short, prompt_long=prompt_long)
        iso[kind] = best
        results.append(best)
        emit(f"disagg/{kind}/long_prompt_burst",
             best["short_itl_p99_s"] * 1e6,
             f"itl_p99_ms={best.get('itl_p99_s', 0)*1e3:.1f};"
             f"itl_max_ms={best.get('itl_max_s', 0)*1e3:.1f};"
             f"ttft_p99_ms={best['ttft_p99_s']*1e3:.0f};"
             f"tok_s={best['throughput_tok_s']:.1f}")
    iso_x = (iso["colocated"]["short_itl_p99_s"]
             / max(iso["disagg"]["short_itl_p99_s"], 1e-9))
    results.append({"scenario": "long_prompt_burst", "engine": "comparison",
                    "decode_itl_p99_improvement_x": iso_x})
    # dimensionless comparison: JSON row + comment line only (the CSV
    # latency column must stay microseconds)
    print(f"# disagg isolation: short-cohort itl_p99 "
          f"{iso['colocated']['short_itl_p99_s']*1e3:.1f}ms colocated vs "
          f"{iso['disagg']['short_itl_p99_s']*1e3:.1f}ms disagg "
          f"({iso_x:.2f}x)")

    # --- scenario 2: fp vs frozen page migration ------------------------
    kv = f"kmeans_ls@{16}"
    mig = {}
    for migrate in ("fp", "frozen"):
        warm = _disagg_engine(params, cfg, kind="disagg", migrate=migrate,
                              kv_quant=kv, **geometry)
        rng = np.random.default_rng(321)
        warm.generate([rng.integers(0, cfg.vocab, prompt_long).tolist()],
                      max_new_tokens=gen_long)
        best = None
        for rep in range(reps):
            eng = _disagg_engine(params, cfg, kind="disagg", migrate=migrate,
                                 kv_quant=kv, **geometry)
            trace = _burst_trace(cfg, n_short=n_short,
                                 prompt_short=prompt_short,
                                 gen_short=gen_short, n_long=n_long,
                                 prompt_long=prompt_long, gen_long=gen_long,
                                 burst_at=0.05, seed=seed)
            s = eng.run(trace)
            if best is None or s["ttft_p99_s"] < best["ttft_p99_s"]:
                best = s
        # originating QuantSpec, so perf trajectories attribute to an
        # exact solver configuration (same convention as the serving rows)
        best.update(scenario="migration", kv=str(eng.kv_spec),
                    spec=eng.kv_spec.to_json())
        mig[migrate] = best
        results.append(best)
        emit(f"disagg/migrate_{migrate}", best["ttft_p99_s"] * 1e6,
             f"bytes={best['migrate_bytes']};"
             f"pages={best['migrated_pages']};"
             f"tok_s={best['throughput_tok_s']:.1f};"
             f"host_solves={best['host_page_solves']}")
    ratio = (mig["fp"]["migrate_bytes"]
             / max(mig["frozen"]["migrate_bytes"], 1))
    results.append({"scenario": "migration", "migrate": "comparison",
                    "kv_bytes_ratio_fp_over_frozen": ratio,
                    "fp_bytes": mig["fp"]["migrate_bytes"],
                    "frozen_bytes": mig["frozen"]["migrate_bytes"]})
    print(f"# disagg migration: fp {mig['fp']['migrate_bytes']} B vs frozen "
          f"{mig['frozen']['migrate_bytes']} B ({ratio:.1f}x fewer)")
    bench_json("disagg_serving", results,
               meta={"arch": ARCH, "reduced": True, "reps": reps,
                     "prefill_workers": 1, "decode_workers": 1, **geometry})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="2,8")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--kv-num-values", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--disagg", action="store_true",
                    help="run the disaggregated-serving scenarios instead")
    ap.add_argument("--speculative", action="store_true",
                    help="run the speculative-decoding scenarios instead")
    ap.add_argument("--prefix", action="store_true",
                    help="run the shared-prefix burst scenario instead")
    args = ap.parse_args()
    if args.disagg:
        run_disagg(block_size=args.block_size, max_slots=args.max_slots)
    elif args.prefix:
        run_prefix_sharing(gen=args.gen)
    elif args.speculative:
        run_speculative(n=args.num_requests, prompt_len=args.prompt_len,
                        gen=args.gen, block_size=args.block_size)
    else:
        run(rates=tuple(float(r) for r in args.rates.split(",")),
            n=args.num_requests, prompt_len=args.prompt_len, gen=args.gen,
            kv_num_values=args.kv_num_values, max_slots=args.max_slots,
            block_size=args.block_size)
