"""Roofline table reader: aggregates results/dryrun/*.json into CSV rows
(one per arch x shape x mesh cell) - the §Roofline source of truth."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run() -> None:
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        emit("roofline/none", 0.0, "run repro.launch.dryrun --all first")
        return
    n_ok = n_skip = n_err = 0
    for f in files:
        d = json.load(open(f))
        cell = f"{d['arch']}.{d['shape']}.{d['mesh']}"
        if d["status"] == "skipped":
            n_skip += 1
            emit(f"roofline/{cell}", 0.0, "skipped_by_design")
            continue
        if d["status"] != "ok":
            n_err += 1
            emit(f"roofline/{cell}", 0.0, f"ERROR:{d.get('reason','')[:60]}")
            continue
        n_ok += 1
        r = d["roofline"]
        emit(f"roofline/{cell}", r["t_compute_s"] * 1e6,
             f"tmem={r['t_memory_s']:.3f};tcoll={r['t_collective_s']:.3f};"
             f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
             f"useful={r['useful_flops_ratio']:.2f};"
             f"mem_gib={d['memory']['peak_estimate']/2**30:.1f}")
    emit("roofline/summary", 0.0, f"ok={n_ok};skipped={n_skip};errors={n_err}")
