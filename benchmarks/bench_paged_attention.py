"""Paged-attention microbench: decode three ways (gather dense-expand vs
fused kernel serial-DMA vs fused double-buffered DMA) and chunked prefill
(gather vs fused) on a frozen-heavy paged layer and an fp-only one.
Reports wall-clock tokens/s plus the modeled HBM bytes/token each path
moves (the bandwidth a TPU step actually pays — off-TPU the fused kernel
runs interpreted, so bytes/token is the portable metric; the serial vs
double-buffered split is a wall-clock row only on real hardware, and the
two variants are asserted bitwise identical either way).
Emits CSV rows plus the standard BENCH_paged_attention.json artifact.

    PYTHONPATH=src python -m benchmarks.run paged_attention
    PYTHONPATH=src python -m benchmarks.bench_paged_attention --iters 5
"""
from __future__ import annotations

import argparse
import dataclasses

from .common import bench_json, emit, timed

ARCH = "qwen3_0_6b"


def _build_state(cfg, *, B, mb, block_size, num_values, quantized, seed=0):
    """One paged layer: B sequences over mb distinct pages each, every full
    page frozen (device solver), last page of each sequence left hot."""
    import jax.numpy as jnp
    import numpy as np

    from repro.serving.kv_cache import freeze_blocks, init_paged_layer

    rng = np.random.default_rng(seed)
    nb = B * mb + 1
    leaf = init_paged_layer(
        cfg, num_blocks=nb, block_size=block_size, batch=B, max_blocks=mb,
        quantized=quantized, num_values=num_values, dtype=jnp.float32,
        fused=True)
    table = np.arange(1, nb).reshape(B, mb).astype(np.int32)
    lens = np.full((B,), mb * block_size - block_size // 2 - 1, np.int32)
    leaf = dataclasses.replace(
        leaf,
        k_fp=jnp.asarray(rng.normal(size=leaf.k_fp.shape), jnp.float32),
        v_fp=jnp.asarray(rng.normal(size=leaf.v_fp.shape), jnp.float32),
        block_table=jnp.asarray(table), seq_lens=jnp.asarray(lens))
    if quantized:
        full = [int(table[b, j]) for b in range(B)
                for j in range(int(lens[b]) // block_size)]
        leaf = freeze_blocks(leaf, full, f"kmeans_ls@{num_values}")
    return leaf, table, lens


def run(B=4, mb=4, block_size=16, num_values=16, iters=5, seed=0) -> None:
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.core import QuantSpec
    from repro.kernels import (default_interpret, modeled_hbm_bytes_per_token,
                               modeled_prefill_hbm_bytes_per_token,
                               paged_decode_attention,
                               paged_prefill_attention)
    from repro.models.attention import sdpa

    cfg = get_reduced_config(ARCH)
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    interp = default_interpret()
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, Dh)), jnp.float32)
    k1 = jnp.asarray(rng.normal(size=(B, 1, Hkv, Dh)), jnp.float32)
    v1 = jnp.asarray(rng.normal(size=(B, 1, Hkv, Dh)), jnp.float32)

    @functools.partial(jax.jit, static_argnames=("dbuf",))
    def fused_step(leaf, q, k, v, dbuf):
        new = leaf._write(k, v)
        return paged_decode_attention(
            q[:, 0], new.k_fp, new.v_fp, new.k_codes, new.v_codes,
            new.k_cb, new.v_cb, new.blk_q, new.block_table,
            new.seq_lens + 1, quantized=new.quantized, packed=new.packed,
            double_buffer=dbuf, interpret=interp)

    @jax.jit
    def gather_step(leaf, q, k, v):
        _, k_all, v_all, q_off, valid = leaf.update(k, v, 0)
        return sdpa(q, k_all, v_all, causal=True, q_offset=q_off,
                    kv_valid_len=valid)

    steps = (
        ("gather", lambda lf: gather_step(lf, q, k1, v1)),
        ("fused-serial", lambda lf: fused_step(lf, q, k1, v1, dbuf=False)),
        ("fused-dbuf", lambda lf: fused_step(lf, q, k1, v1, dbuf=True)),
    )
    results = []
    for quantized in (True, False):
        leaf, table, lens = _build_state(
            cfg, B=B, mb=mb, block_size=block_size, num_values=num_values,
            quantized=quantized, seed=seed)
        frozen_frac = (float(np.asarray(leaf.blk_q).mean())
                       if quantized else 0.0)
        kv = f"kmeans_ls@{num_values}" if quantized else "fp"
        bytes_kw = dict(block_size=block_size, n_kv_heads=Hkv, head_dim=Dh,
                        num_values=num_values, quantized=quantized,
                        packed=leaf.packed)
        fused_outs = {}
        for path, fn in steps:
            out, dt = timed(
                lambda fn=fn: jax.block_until_ready(fn(leaf)),
                warmup=1, iters=iters)
            if path.startswith("fused"):
                fused_outs[path] = np.asarray(out)
            bpt = modeled_hbm_bytes_per_token(
                table, lens, np.asarray(leaf.blk_q),
                path="gather" if path == "gather" else "fused", **bytes_kw)
            row = {"path": path, "kv": kv, "tok_s": B / dt,
                   "us_per_step": dt * 1e6, "hbm_bytes_per_token": bpt,
                   "frozen_frac": frozen_frac, "batch": B, "max_blocks": mb,
                   "block_size": block_size,
                   "spec": (QuantSpec.parse(kv).to_json()
                            if quantized else None)}
            results.append(row)
            emit(f"paged_attention/{kv}/{path}", dt * 1e6,
                 f"tok_s={row['tok_s']:.1f};bytes_per_tok={bpt:.0f};"
                 f"frozen={frozen_frac:.2f}")
        # identical per-page arithmetic, different DMA schedule -> bitwise
        assert np.array_equal(fused_outs["fused-serial"],
                              fused_outs["fused-dbuf"]), \
            "double-buffered fused decode diverged from serial"

    # chunked prefill over a >=50%-frozen shared prefix (restored system
    # context): one block_size-token chunk entering at the prompt's end,
    # scored against every earlier page
    leaf, table, lens = _build_state(
        cfg, B=B, mb=mb, block_size=block_size, num_values=num_values,
        quantized=True, seed=seed + 1)
    frozen_frac = float(np.asarray(leaf.blk_q)[1:].mean())
    C = block_size
    qc = jnp.asarray(rng.normal(size=(B, C, Hq, Dh)), jnp.float32)
    off = jnp.asarray(lens, jnp.int32) - C

    @jax.jit
    def prefill_fused(leaf, q, off):
        return paged_prefill_attention(
            q, leaf.k_fp, leaf.v_fp, leaf.k_codes, leaf.v_codes, leaf.k_cb,
            leaf.v_cb, leaf.blk_q, leaf.block_table, off,
            quantized=leaf.quantized, packed=leaf.packed, interpret=interp)

    @jax.jit
    def prefill_gather(leaf, q, off):
        k_all = leaf._gather(leaf.k_fp, leaf.k_codes, leaf.k_cb)
        v_all = leaf._gather(leaf.v_fp, leaf.v_codes, leaf.v_cb)
        return sdpa(q, k_all, v_all, causal=True, q_offset=off,
                    kv_valid_len=off + C)

    pf_kw = dict(chunk=C, block_size=block_size, n_kv_heads=Hkv, head_dim=Dh,
                 num_values=num_values, quantized=True, packed=leaf.packed)
    for path, fn in (("gather", prefill_gather), ("fused", prefill_fused)):
        _, dt = timed(
            lambda fn=fn: jax.block_until_ready(fn(leaf, qc, off)),
            warmup=1, iters=iters)
        bpt = modeled_prefill_hbm_bytes_per_token(
            table, lens, np.asarray(leaf.blk_q), path=path, **pf_kw)
        row = {"path": f"prefill-{path}", "kv": f"kmeans_ls@{num_values}",
               "tok_s": B * C / dt, "us_per_step": dt * 1e6,
               "hbm_bytes_per_token": bpt, "frozen_frac": frozen_frac,
               "batch": B, "max_blocks": mb, "block_size": block_size,
               "chunk": C,
               "spec": QuantSpec.parse(f"kmeans_ls@{num_values}").to_json()}
        results.append(row)
        emit(f"paged_attention/prefill/{path}", dt * 1e6,
             f"tok_s={row['tok_s']:.1f};bytes_per_tok={bpt:.0f};"
             f"frozen={frozen_frac:.2f}")

    by = {(r["kv"], r["path"]): r for r in results}
    qkv = f"kmeans_ls@{num_values}"
    ratio = (by[(qkv, "gather")]["hbm_bytes_per_token"]
             / by[(qkv, "fused-dbuf")]["hbm_bytes_per_token"])
    pf_ratio = (by[(qkv, "prefill-gather")]["hbm_bytes_per_token"]
                / by[(qkv, "prefill-fused")]["hbm_bytes_per_token"])
    emit("paged_attention/hbm_reduction", 0.0,
         f"decode gather/fused={ratio:.2f}x;"
         f"prefill gather/fused={pf_ratio:.2f}x")
    bench_json("paged_attention", results,
               meta={"arch": ARCH, "reduced": True,
                     "interpret": jax.default_backend() != "tpu",
                     "hbm_reduction_frozen": ratio,
                     "prefill_hbm_reduction_frozen": pf_ratio,
                     "prefill_frozen_frac": frozen_frac})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-blocks", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-values", type=int, default=16)
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()
    run(B=args.batch, mb=args.max_blocks, block_size=args.block_size,
        num_values=args.num_values, iters=args.iters)
