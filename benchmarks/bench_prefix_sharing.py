"""Prefix-sharing benchmark suite entry point.

The scenario lives in ``bench_serving.run_prefix_sharing`` (shared-prefix
burst at a fixed page pool: refcounted copy-on-write sharing vs the
no-sharing baseline — effective capacity, TTFT, prefix counters; greedy-
identical traces asserted); this module exists so
``python -m benchmarks.run prefix_sharing`` finds it under its artifact's
name, BENCH_prefix_sharing.json.

    PYTHONPATH=src python -m benchmarks.run prefix_sharing
    PYTHONPATH=src python -m benchmarks.bench_serving --prefix
"""
from __future__ import annotations

from .bench_serving import run_prefix_sharing as run

if __name__ == "__main__":
    run()
