"""Benchmark driver - one module per paper table/figure plus framework
benches. Prints ``name,us_per_call,derived`` CSV. Select with
``python -m benchmarks.run [name ...]``."""
from __future__ import annotations

import sys
import time

SUITES = ["nn_weights", "l1l2", "alpha_dist", "image", "synthetic",
          "scaling", "kernels", "roofline", "paged_attention", "serving",
          "disagg_serving", "spec_decode", "quant_api", "overload",
          "prefix_sharing"]


def main() -> None:
    want = sys.argv[1:] or SUITES
    for name in want:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        print(f"# --- bench_{name} ---", flush=True)
        mod.run()
        print(f"# bench_{name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
