"""Paper fig. 4: sole-l1 vs l1+(negative)l2 across lambda_1 - the combined
penalty reaches fewer distinct values at equal lambda_1 with comparable or
lower loss. lambda_2 = 4e-3 * lambda_1 scaling per the paper's figure."""
from __future__ import annotations

import time

import numpy as np

from repro.core import max_stable_lam2, make_problem, quantize, unique_with_counts

from .common import emit, train_paper_mlp


def run() -> None:
    params, *_ = train_paper_mlp()
    w = np.asarray(params[-1]["w"])
    vals, counts, _ = unique_with_counts(w)
    prob = make_problem(vals, counts)
    cap = max_stable_lam2(prob)
    for lam1 in [1e-4, 3e-4, 1e-3, 3e-3, 1e-2]:
        _, a = quantize(w, f"l1:lam={lam1!r}")
        lam2 = min(4e-3 * lam1, 0.49 * cap)
        _, b = quantize(w, f"l1l2:lam={lam1!r},lam2={lam2!r}")
        emit(f"l1l2/lam{lam1:g}", 0.0,
             f"n_l1={a['n_values']};n_l1l2={b['n_values']};"
             f"l2_l1={a['l2_loss']:.5f};l2_l1l2={b['l2_loss']:.5f}")
