"""Disaggregated-serving benchmark suite entry point.

Scenarios live in ``bench_serving.run_disagg`` (decode-TPOT isolation
under a long-prompt burst; fp-vs-frozen KV page migration bytes/latency);
this module exists so ``python -m benchmarks.run disagg_serving`` finds
them under their artifact's name, BENCH_disagg_serving.json.

    PYTHONPATH=src python -m benchmarks.run disagg_serving
    PYTHONPATH=src python -m benchmarks.bench_serving --disagg
"""
from __future__ import annotations

from .bench_serving import run_disagg as run

if __name__ == "__main__":
    run()
