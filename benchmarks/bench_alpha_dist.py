"""Paper fig. 3: distribution of the alpha vector for the NN last layer -
sign balance, exact-zero fraction, and the 'central zero area' the paper
observes for mid-range indices."""
from __future__ import annotations

import numpy as np

from repro.core import make_problem, quantize, unique_with_counts

from .common import emit, train_paper_mlp


def _alpha_stats(alpha):
    a = np.asarray(alpha)
    nz = np.abs(a) > 1e-10
    m = len(a)
    mid = nz[m // 3: 2 * m // 3]
    return {
        "nnz": int(nz.sum()),
        "pos_frac": float((a[nz] > 0).mean()) if nz.any() else 0.0,
        "central_zero_frac": float(1.0 - mid.mean()) if len(mid) else 0.0,
    }


def run() -> None:
    params, *_ = train_paper_mlp()
    w = np.asarray(params[-1]["w"])
    for method, spec in [("l1", "l1:lam=0.001"), ("l1_ls", "l1_ls:lam=0.001"),
                          ("kmeans_ls", "kmeans_ls@32")]:
        qt, info = quantize(w, spec)
        s = _alpha_stats(info["alpha"])
        emit(f"alpha_dist/{method}", 0.0,
             f"nnz={s['nnz']};pos_frac={s['pos_frac']:.3f};"
             f"central_zero={s['central_zero_frac']:.3f}")
    # paper: the l1 alphas are almost all positive (consistent with the
    # cumulative V and shrinkage); verified in tests/test_benchmarks.py
