"""Shared benchmark utilities: data generators matching the paper's §4 setup,
timing, CSV output (`name,us_per_call,derived`), and the standard
BENCH_<name>.json result files."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def bench_json(name: str, results, *, meta: dict | None = None,
               out_dir: str = "."):
    """Write the standard BENCH_<name>.json artifact:
    {"bench": name, "meta": {...}, "results": [...]}."""
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        # strict JSON: a NaN/Inf metric (e.g. a percentile over an empty
        # population) must fail the bench, not poison downstream parsers
        json.dump({"bench": name, "meta": meta or {}, "results": results},
                  f, indent=1, sort_keys=True, allow_nan=False)
    print(f"[bench] wrote {path}")
    return path


def timed(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / iters
    return out, dt


# ---------------------------------------------------- paper §4.3 data

def synthetic_distributions(n: int = 500, seed: int = 0):
    """MoG / Uniform / single Gaussian, 500 samples in [0, 100] (fig. 7)."""
    rng = np.random.default_rng(seed)
    mog = np.concatenate([
        rng.normal(20, 5, n // 3), rng.normal(50, 8, n // 3),
        rng.normal(80, 4, n - 2 * (n // 3))])
    uni = rng.uniform(0, 100, n)
    gauss = rng.normal(50, 15, n)
    return {
        "mog": np.clip(mog, 0, 100),
        "uniform": uni,
        "gaussian": np.clip(gauss, 0, 100),
    }


# ---------------------------------------------------- paper §4.1 MLP data

def synthetic_mnist(n_train: int = 4096, n_test: int = 1024, seed: int = 0):
    """Deterministic MNIST-stand-in: 10 class-conditioned 784-d blob patterns
    (real MNIST is not available offline; protocol in DESIGN.md §7)."""
    rng = np.random.default_rng(seed)
    protos = rng.uniform(0, 1, (10, 784)) * (rng.uniform(0, 1, (10, 784)) > 0.6)

    def make(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, 10, n)
        # noise 0.7: ~94% baseline with a clear accuracy-drop region below
        # ~4 quantization values - the regime of the paper's fig. 1/2
        x = protos[y] + r.normal(0, 0.7, (n, 784))
        return np.clip(x, 0, 1).astype(np.float32), y.astype(np.int32)

    return make(n_train, seed + 1), make(n_test, seed + 2)


def synthetic_image(seed: int = 0):
    """28x28 'digit-like' grayscale image in [0,1] (fig. 5/6 stand-in)."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:28, 0:28] / 27.0
    img = (np.exp(-((xx - 0.5) ** 2 + (yy - 0.35) ** 2) / 0.02)
           + 0.8 * np.exp(-((xx - 0.5) ** 2 + (yy - 0.7) ** 2) / 0.03))
    img = img / img.max() + rng.normal(0, 0.02, (28, 28))
    return np.clip(img, 0, 1).astype(np.float32)


def train_paper_mlp(steps: int = 400, lr: float = 1e-3, seed: int = 0):
    """Train the paper's 784-256-128-64-10 MLP; returns params + data + accs."""
    from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss

    (xtr, ytr), (xte, yte) = synthetic_mnist(seed=seed)
    params = init_mlp(jax.random.PRNGKey(seed))
    xtr_j, ytr_j = jnp.asarray(xtr), jnp.asarray(ytr)

    @jax.jit
    def step(params, i):
        idx = (jnp.arange(256) + i * 256) % xtr_j.shape[0]
        g = jax.grad(mlp_loss)(params, xtr_j[idx], ytr_j[idx])
        return jax.tree.map(lambda p, gg: p - lr * gg * 3.0, params, g), None

    params, _ = jax.lax.scan(step, params, jnp.arange(steps))
    acc_tr = float(mlp_accuracy(params, xtr_j, ytr_j))
    acc_te = float(mlp_accuracy(params, jnp.asarray(xte), jnp.asarray(yte)))
    return params, (xtr, ytr), (xte, yte), acc_tr, acc_te


def timed_quant(w, method, iters: int = 2, **kw):
    """Time quantize() excluding jit compilation (first call warms).

    ``method`` may be a QuantSpec / spec string, or a bare method name whose
    quantizer kwargs fold into the spec here (no deprecation detour)."""
    import time as _t

    from repro.core import QuantSpec
    from repro.core import quantize as _q

    if isinstance(method, str) and "@" not in method and ":" not in method:
        method = QuantSpec(method, **{
            k: kw.pop(k) for k in ("num_values", "lam", "lam2", "weighted",
                                   "clip", "seed") if k in kw})
    out = _q(w, method, **kw)
    t0 = _t.perf_counter()
    for _ in range(iters):
        out = _q(w, method, **kw)
    return out, (_t.perf_counter() - t0) / iters
