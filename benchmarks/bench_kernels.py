"""Framework kernels: batched-FISTA PTQ throughput and fused dequant matmul
(interpret mode on CPU - correctness-shaped timing; Mosaic on real TPU)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import cd_solve, make_problem
from repro.kernels import quant_matmul, ref_quant_matmul, solve_fista_batch

from .common import emit, timed


def run() -> None:
    rng = np.random.default_rng(0)
    # batched FISTA: 8 tensors solved in one launch vs sequential CD
    B, M = 8, 512
    W = np.sort(rng.normal(size=(B, M)), axis=1).astype(np.float32)
    D = np.diff(W, axis=1, prepend=0.0)
    N = np.ones((B, M), np.float32)
    _, dt_batch = timed(solve_fista_batch, W, D, N, 0.05, n_iters=300,
                        interpret=True)
    t0 = time.perf_counter()
    for i in range(B):
        prob = make_problem(W[i], N[i])
        cd_solve(prob, 0.05, max_sweeps=60)
    dt_cd = time.perf_counter() - t0
    emit("kernels/fista_batch8_m512", dt_batch * 1e6,
         f"cd_sequential_s={dt_cd:.4f}")

    # fused dequant matmul vs dense reference
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 256, (512, 256)), jnp.uint8)
    cb = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    out, dt_q = timed(lambda: quant_matmul(x, idx, cb, interpret=True)
                      .block_until_ready())
    ref, dt_d = timed(lambda: ref_quant_matmul(x, idx, cb).block_until_ready())
    err = float(jnp.abs(out - ref).max())
    emit("kernels/quant_matmul_256x512x256", dt_q * 1e6,
         f"dense_ref_us={dt_d*1e6:.1f};maxerr={err:.2e}")
