"""Overload-survival benchmark: graceful degradation under 2-4x KV-pool
oversubscription -> BENCH_overload.json.

The same mixed-priority request set (alternating ``latency`` /
``best_effort``, staggered arrivals) is served at shrinking page pools:

  1x   enough pages for every slot — the uncontended reference whose
       outputs are the greedy-token golden for every other run.
  2x/4x   the pool holds 1/2 resp. 1/4 of slot demand. Each factor runs
       two arms at identical compute:

       fcfs       plain admission, no preemption, no host tier — the
           cliff: latency-tier requests queue behind whatever arrived
           first and inherit the full contention tail.
       survival   ``preempt=True`` + ``offload_pages=True`` +
           ``admission="slo"``: best_effort victims demote to the host
           tier as packed codes+codebooks, latency heads take their
           pages, victims restore (bit-exact) when capacity returns.

Claims measured per row: per-tier itl_p99 / ttft_p99 (the latency tier
must degrade gracefully while best_effort absorbs the contention),
offload_compression (host-tier bytes vs demoting at fp width), and the
preempt/offload/restore counters. Asserted, not just reported:

  - zero greedy-token divergence: every completed request's tokens equal
    the uncontended golden's, in every arm (restore is bit-exact);
  - counters reconcile against the Perfetto trace on the harshest run:
    page_offload begin == end == offloaded_pages == restored_pages, all
    ends terminal-state "restored", preempt/restore instants match;
  - both engine compositions survive: the colocated grid above plus a
    disaggregated (1P/1D, migrate="frozen") survival run at 2x.

    PYTHONPATH=src python -m benchmarks.run overload
    PYTHONPATH=src python -m benchmarks.bench_overload --factors 2,4
"""
from __future__ import annotations

import argparse

import numpy as np

from .common import bench_json, emit

ARCH = "qwen3_0_6b"
KV = "kmeans_ls@16"


def _requests(cfg, *, n, prompt_len, gen, stagger, seed):
    """best_effort first (and generating 2x longer, so they still hold
    pages mid-decode), latency behind, arrivals ``stagger`` apart: the
    empty pool admits the best_effort cohort (occupancy is low), then the
    latency cohort lands on a full pool — the exact shape where fcfs
    queues the latency tier behind FCFS order while survival preempts
    best_effort victims to the host tier."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    return [Request(id=i,
                    prompt=tuple(int(x) for x in
                                 rng.integers(0, cfg.vocab, prompt_len)),
                    max_new_tokens=gen * 2 if i < n // 2 else gen,
                    arrival_time=i * stagger,
                    priority="best_effort" if i < n // 2 else "latency")
            for i in range(n)]


def _tier_tails(eng, requests):
    """Per-priority itl_p99 / ttft_p99 over the completed population."""
    out = {}
    pri = {r.id: r.priority for r in requests}
    for tier in ("latency", "best_effort"):
        done = [t for rid, t in eng.metrics.traces.items()
                if pri[rid] == tier and t.finish_t is not None
                and t.first_token_t is not None]
        gaps = [g for t in done for g in t.gaps]
        out[f"{tier}_completed"] = len(done)
        out[f"{tier}_itl_p99_s"] = (
            float(np.percentile(gaps, 99)) if gaps else None)
        out[f"{tier}_ttft_p99_s"] = (
            float(np.percentile([t.ttft for t in done], 99))
            if done else None)
    return out


def _assert_identical(outputs, golden, label):
    """Every COMPLETED request must match the uncontended trace exactly
    (shed requests finish zero-token and never enter ``outputs``)."""
    for rid, toks in outputs.items():
        assert toks == golden[rid], (
            f"{label}: request {rid} diverged from the uncontended golden")


def run(factors=(2, 4), n=8, prompt_len=32, gen=12, max_slots=4,
        block_size=16, stagger=0.01, seed=0) -> None:
    import jax

    from repro import models
    from repro.configs import get_reduced_config
    from repro.obs import Tracer, count_events
    from repro.serving import ContinuousBatchingEngine, DisaggEngine

    cfg = get_reduced_config(ARCH)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    bpr = -(-(prompt_len + 2 * gen) // block_size)   # best_effort length
    slot_demand = max_slots * bpr
    geometry = dict(max_slots=max_slots, block_size=block_size,
                    max_seq_len=bpr * block_size, kv_quant=KV,
                    freeze_async=False)
    requests = _requests(cfg, n=n, prompt_len=prompt_len, gen=gen,
                         stagger=stagger, seed=seed)

    def colocated(num_blocks, tracer=None, **overload_kw):
        kw = dict(geometry)
        if tracer is not None:
            kw["tracer"] = tracer
        return ContinuousBatchingEngine(params, cfg, num_blocks=num_blocks,
                                        **overload_kw, **kw)

    # --- 1x golden: uncontended, overload machinery off -----------------
    warm = colocated(slot_demand + 1)
    rng = np.random.default_rng(123)
    for burst in (max_slots, 2, 1):
        warm.generate([rng.integers(0, cfg.vocab, prompt_len).tolist()
                       for _ in range(burst)], max_new_tokens=gen * 2)
    golden_eng = colocated(slot_demand + 1)
    s = golden_eng.run(list(requests))
    golden = dict(golden_eng.outputs)
    assert len(golden) == n
    s.update(_tier_tails(golden_eng, requests))
    s.update(scenario="colocated", arm="golden", oversub=1,
             num_blocks=slot_demand + 1, num_requests=n,
             prompt_len=prompt_len, gen=gen)
    results = [s]
    # an achievable-but-tight SLO anchored on the uncontended tail: under
    # contention the windowed p99 blows past it and best_effort sheds
    itl_slo_s = 8.0 * max(s["latency_itl_p99_s"], 1e-4)

    # --- 2x/4x: fcfs cliff vs survival ----------------------------------
    for factor in factors:
        num_blocks = max(bpr + 1, slot_demand // factor) + 1
        for arm in ("fcfs", "survival"):
            tracer = Tracer() if (arm, factor) == ("survival", max(factors)) \
                else None
            kw = {} if arm == "fcfs" else dict(
                offload_pages=True, preempt=True, admission="slo",
                itl_slo_s=itl_slo_s)
            eng = colocated(num_blocks, tracer=tracer, **kw)
            s = eng.run(list(requests))
            _assert_identical(eng.outputs, golden, f"{arm}@{factor}x")
            s.update(_tier_tails(eng, requests))
            s.update(scenario="colocated", arm=arm, oversub=factor,
                     num_blocks=num_blocks, num_requests=n,
                     prompt_len=prompt_len, gen=gen,
                     itl_slo_s=None if arm == "fcfs" else itl_slo_s)
            results.append(s)
            lat = s["latency_itl_p99_s"]
            emit(f"overload/{arm}/{factor}x",
                 (lat or 0.0) * 1e6,
                 f"lat_ttft_p99_ms={(s['latency_ttft_p99_s'] or 0)*1e3:.0f};"
                 f"be_done={s['best_effort_completed']};"
                 f"preempt={s.get('preemptions', 0)};"
                 f"shed={s.get('shed_slo', 0)};"
                 f"compress={s.get('offload_compression', 0.0):.2f}x")
            if tracer is not None:
                # counters must reconcile against the trace exactly
                b = count_events(tracer.events, name="page_offload", ph="b")
                e = count_events(tracer.events, name="page_offload", ph="e")
                assert b == e == s["offloaded_pages"] == s["restored_pages"]
                ends = [ev["args"]["state"] for ev in tracer.events
                        if ev.get("name") == "page_offload"
                        and ev["ph"] == "e"]
                assert all(st == "restored" for st in ends)
                assert count_events(tracer.events, name="preempt",
                                    ph="i") == s["preemptions"]
                assert count_events(tracer.events, name="restore",
                                    ph="i") == s["restored_seqs"]
                results.append({
                    "scenario": "span_reconcile", "oversub": factor,
                    "page_offload_begins": b, "page_offload_ends": e,
                    "offloaded_pages": s["offloaded_pages"],
                    "restored_pages": s["restored_pages"],
                    "terminal_states_restored": True})

    # --- disagg composition survives the same squeeze at 2x -------------
    dkw = dict(prefill_workers=1, decode_workers=1, migrate="frozen",
               **geometry)
    warm = DisaggEngine(params, cfg, **dkw)
    warm.generate([rng.integers(0, cfg.vocab, prompt_len).tolist()
                   for _ in range(2)], max_new_tokens=gen * 2)
    dg = DisaggEngine(params, cfg, **dkw)
    dg.run(list(requests))
    dgold = dict(dg.outputs)
    eng = DisaggEngine(params, cfg, num_blocks=slot_demand // 2 + 1,
                       offload_pages=True, preempt=True, admission="slo",
                       itl_slo_s=itl_slo_s, **dkw)
    s = eng.run(list(requests))
    _assert_identical(eng.outputs, dgold, "disagg-survival@2x")
    s.update(_tier_tails(eng, requests))
    s.update(scenario="disagg", arm="survival", oversub=2,
             num_blocks=slot_demand // 2 + 1, num_requests=n,
             prompt_len=prompt_len, gen=gen, itl_slo_s=itl_slo_s)
    results.append(s)
    emit("overload/disagg_survival/2x",
         (s["latency_itl_p99_s"] or 0.0) * 1e6,
         f"preempt={s.get('preemptions', 0)};"
         f"offload_pages={s.get('offloaded_pages', 0)};"
         f"compress={s.get('offload_compression', 0.0):.2f}x")

    by = {(r["scenario"], r.get("arm"), r["oversub"]): r
          for r in results if r.get("arm")}
    g1 = by[("colocated", "golden", 1)]["latency_itl_p99_s"]
    print("# overload: latency-tier itl_p99 "
          + " ".join(
              f"{f}x fcfs={by[('colocated', 'fcfs', f)]['latency_itl_p99_s']*1e3:.1f}ms"
              f"/survival={by[('colocated', 'survival', f)]['latency_itl_p99_s']*1e3:.1f}ms"
              for f in factors)
          + f" (1x golden {g1*1e3:.1f}ms); zero token divergence")
    bench_json("overload", results,
               meta={"arch": ARCH, "reduced": True, "kv": KV,
                     "max_slots": max_slots, "block_size": block_size,
                     "stagger_s": stagger, "itl_slo_s": itl_slo_s})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--factors", default="2,4")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    args = ap.parse_args()
    run(factors=tuple(int(f) for f in args.factors.split(",")),
        n=args.num_requests, prompt_len=args.prompt_len, gen=args.gen,
        max_slots=args.max_slots, block_size=args.block_size)
