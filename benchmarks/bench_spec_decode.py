"""Speculative-decoding benchmark suite entry point.

Scenarios live in ``bench_serving.run_speculative`` (non-speculative
baseline vs draft-k verify windows at equal compute: tokens/step, TPOT,
acceptance rate; greedy-identical traces asserted); this module exists so
``python -m benchmarks.run spec_decode`` finds them under their
artifact's name, BENCH_spec_decode.json.

    PYTHONPATH=src python -m benchmarks.run spec_decode
    PYTHONPATH=src python -m benchmarks.bench_serving --speculative
"""
from __future__ import annotations

from .bench_serving import run_speculative as run

if __name__ == "__main__":
    run()
