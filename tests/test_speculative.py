"""Speculative decoding tests: greedy token-identity against the
non-speculative baseline (the acceptance bar — every emitted token is a
target argmax for its exact accepted context), acceptance accounting,
the rollback-vs-async-freeze watermark invariant, and the verify-window
paths (gather and fused/interpret, colocated and disaggregated)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_reduced_config
from repro.serving import (ContinuousBatchingEngine, DisaggEngine, Request,
                           derive_draft)

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def qwen_reduced():
    cfg = get_reduced_config("qwen3_0_6b")
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def draft(qwen_reduced):
    cfg, params = qwen_reduced
    return derive_draft(params, cfg)


def _prompts(cfg, n, plen, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, plen).tolist() for _ in range(n)]


# ------------------------------------------------------------- identity


def test_decode_window_matches_sequential_steps(qwen_reduced):
    """The verify primitive itself: one (B, W) window pass == W sequential
    single-token decode steps, bit-for-bit on the paged gather path."""
    from repro.serving.kv_cache import (init_paged_cache, merge_pools,
                                        with_tables)

    cfg, params = qwen_reduced
    bs, P, W = 8, 11, 3
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (1, P + W))

    def prefill(tree, table):
        pad = -(-P // bs) * bs
        tp = np.zeros((1, pad), np.int32)
        tp[0, :P] = toks[0, :P]
        t1 = with_tables(tree, table, np.zeros((1,), np.int32))
        _, new = models.prefill(params, cfg, {"tokens": jnp.asarray(tp)}, t1)
        return merge_pools(tree, new)

    kw = dict(num_blocks=6, block_size=bs, batch=1, max_blocks=4)
    table = np.asarray([[1, 2, 3, 4]], np.int32)

    tree = prefill(init_paged_cache(cfg, **kw), table)
    win = with_tables(tree, table, np.asarray([P], np.int32))
    logits_w, _ = models.decode_window(
        params, cfg, jnp.asarray(toks[:, P:P + W]), win,
        jnp.asarray([P], np.int32))

    tree = prefill(init_paged_cache(cfg, **kw), table)
    seq = []
    for w in range(W):
        cur = with_tables(tree, table, np.asarray([P + w], np.int32))
        lg, new = models.decode_step(
            params, cfg, jnp.asarray(toks[:, P + w:P + w + 1]), cur,
            jnp.asarray([P + w], np.int32))
        tree = merge_pools(tree, new)
        seq.append(np.asarray(lg[0, 0]))
    np.testing.assert_array_equal(np.asarray(logits_w[0]), np.stack(seq))


def test_spec_token_identical_and_accepts(qwen_reduced, draft):
    """Truncated-draft speculation reproduces the baseline greedy trace
    exactly (tokens AND logits), accepts drafts (rate > 0), and needs
    fewer verify steps than the baseline needs decode steps."""
    cfg, params = qwen_reduced
    prompts = _prompts(cfg, 3, 12)
    gen = 8
    base = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                    max_seq_len=48, record_logits=True)
    out_b = base.generate(prompts, max_new_tokens=gen)
    spec = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                    max_seq_len=48, record_logits=True,
                                    speculate=3, draft=draft)
    out_s = spec.generate(prompts, max_new_tokens=gen)
    assert out_s == out_b
    for i in range(len(prompts)):
        np.testing.assert_allclose(spec.request_logits[i],
                                   base.request_logits[i], atol=1e-3,
                                   rtol=0)
    s = spec.metrics.summary()
    assert s["spec_acceptance_rate"] > 0
    assert s["spec_proposed"] == 3 * s["spec_steps"]
    assert spec.counters["decode_steps"] < base.counters["decode_steps"]
    # tokens/step: decode-generated tokens per per-sequence verify step
    tps = (s["gen_tokens"] - s["completed"]) / spec.counters["seq_decode_steps"]
    assert tps > 1.0


def test_spec_identical_under_random_draft_rollbacks(qwen_reduced):
    """A random-init draft (near-zero agreement) still yields the exact
    baseline trace — correctness never depends on draft quality — while
    rollbacks dominate."""
    cfg, params = qwen_reduced
    dcfg = get_reduced_config("qwen3_0_6b")
    dparams = models.init_params(dcfg, jax.random.PRNGKey(99))
    prompts = _prompts(cfg, 2, 10, seed=1)
    gen = 6
    base = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                    max_seq_len=48)
    out_b = base.generate(prompts, max_new_tokens=gen)
    spec = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                    max_seq_len=48, speculate=2,
                                    draft=(dparams, dcfg))
    out_s = spec.generate(prompts, max_new_tokens=gen)
    assert out_s == out_b
    s = spec.metrics.summary()
    assert s["spec_rollbacks"] > 0


def test_spec_disagg_matches_colocated(qwen_reduced, draft):
    """Speculation composes with disaggregated serving (draft prefill runs
    at the decode worker on import): same tokens as the colocated
    speculative engine and the plain baseline."""
    cfg, params = qwen_reduced
    prompts = _prompts(cfg, 3, 10, seed=2)
    gen = 6
    base = ContinuousBatchingEngine(params, cfg, max_slots=3, block_size=8,
                                    max_seq_len=48)
    out_b = base.generate(prompts, max_new_tokens=gen)
    dz = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                      max_slots=3, block_size=8, max_seq_len=48,
                      speculate=3, draft=draft)
    out_d = dz.generate(prompts, max_new_tokens=gen)
    assert out_d == out_b
    s = dz._summary()
    assert s["spec_acceptance_rate"] > 0 and s["tokens_per_step"] > 1.0


def test_spec_fused_interpret_matches_gather(qwen_reduced, draft):
    """The fused verify window (Pallas kernel, interpret mode) reproduces
    the gather verify window on a frozen-page cache."""
    cfg, params = qwen_reduced
    prompts = _prompts(cfg, 2, 12, seed=3)
    gen = 6
    runs = {}
    for impl in ("gather", "fused"):
        eng = ContinuousBatchingEngine(
            params, cfg, max_slots=2, block_size=8, max_seq_len=48,
            kv_quant="kmeans_ls@16", record_logits=True, attn_impl=impl,
            freeze_async=False, speculate=3, draft=draft)
        runs[impl] = (eng, eng.generate(prompts, max_new_tokens=gen))
    (g_eng, g_out), (f_eng, f_out) = runs["gather"], runs["fused"]
    assert f_out == g_out
    for i in range(len(prompts)):
        np.testing.assert_allclose(f_eng.request_logits[i],
                                   g_eng.request_logits[i], atol=1e-3,
                                   rtol=0)


# ------------------------------------------------------------- watermark


def _frozen_watermark_ok(w):
    """No page is frozen, freeze-queued, or pending-kept beyond its slot's
    accepted seq_lens watermark."""
    page_slot = {}
    for slot, s in enumerate(w.slots):
        for j, b in enumerate(s.blocks):
            page_slot[int(b)] = (slot, j)
    suspect = set(w._frozen_pages) | set(w._freeze_bids)
    for _, pending in w._pending_freezes:
        suspect |= {int(b) for b in pending.bids[pending.keep]}
    for b in suspect:
        if b not in page_slot:      # just-freed page awaiting drop/install
            continue
        slot, j = page_slot[b]
        if not (j + 1) * w.block_size <= int(w.lens[slot]):
            return False, (b, slot, j, int(w.lens[slot]))
    return True, None


def test_rollback_never_freezes_past_watermark(qwen_reduced):
    """The tentpole invariant: with a disagreeing draft forcing rollbacks
    on a quantized cache with async freezing, no frozen/queued/pending
    page ever extends past the accepted seq_lens — checked at every step
    boundary."""
    cfg, params = qwen_reduced
    dcfg = get_reduced_config("qwen3_0_6b")
    dparams = models.init_params(dcfg, jax.random.PRNGKey(123))
    eng = ContinuousBatchingEngine(
        params, cfg, max_slots=2, block_size=4,      # small pages: many
        max_seq_len=64, kv_quant="kmeans_ls@16",     # freeze boundaries
        freeze_page_budget=1, speculate=3, draft=(dparams, dcfg))
    w = eng.worker
    orig_step = w.step
    violations = []

    def checked_step(now_fn):
        orig_step(now_fn)
        ok, info = _frozen_watermark_ok(w)
        if not ok:
            violations.append(info)

    w.step = checked_step
    out = eng.generate(_prompts(cfg, 3, 9, seed=4), max_new_tokens=10)
    assert not violations, violations
    s = eng.metrics.summary()
    assert s["spec_rollbacks"] > 0          # the invariant was exercised
    assert all(len(v) == 10 for v in out.values())
    # pages fully recycled afterwards
    assert eng.alloc.num_free == eng.num_blocks - 1


def test_rollback_unqueues_freeze_bids(qwen_reduced):
    """Unit-level rollback contract: optimistic bids for pages past the
    rolled-back watermark leave the queue (and in-flight keeps), frozen
    watermark shrinks, lens lands on the accepted length."""
    from repro.serving import DecodeWorker
    from repro.serving.kv_cache import resolve_kv_spec

    cfg, params = qwen_reduced
    dcfg = get_reduced_config("qwen3_0_6b")
    dparams = models.init_params(dcfg, jax.random.PRNGKey(5))
    w = DecodeWorker(params, cfg, max_slots=1, block_size=4, max_seq_len=32,
                     kv_spec=resolve_kv_spec("kmeans_ls@16"), speculate=2,
                     draft=(dparams, dcfg))
    s = w.slots[0]
    s.blocks = [3, 5, 7]
    w.table[0, :3] = [3, 5, 7]
    # pretend the verify wrote optimistically through 11 rows (3 pages)
    w.lens[0] = 11
    w._queue_freeze(0)
    assert w._freeze_bids == [3, 5] and s.frozen_upto == 2
    # rollback to 6 accepted rows: page 5 (rows 4..7) is past the
    # watermark and must leave the queue; page 3 (rows 0..3) stays
    w._rollback_slot(0, 6)
    assert w._freeze_bids == [3]
    assert s.frozen_upto == 1
    assert int(w.lens[0]) == 6


# ------------------------------------------------------------- guards


def test_spec_engine_guards(qwen_reduced, draft):
    """Fail-fast surface: speculation without a draft, vocab mismatch,
    sampled requests, and oversized fused windows are all named errors."""
    cfg, params = qwen_reduced
    with pytest.raises(ValueError, match="draft"):
        ContinuousBatchingEngine(params, cfg, speculate=2)
    import dataclasses
    bad_cfg = dataclasses.replace(draft[1], vocab=cfg.vocab + 1)
    with pytest.raises(ValueError, match="vocab"):
        ContinuousBatchingEngine(params, cfg, speculate=2,
                                 draft=(draft[0], bad_cfg))
    with pytest.raises(ValueError, match="window"):
        ContinuousBatchingEngine(params, cfg, block_size=4, speculate=4,
                                 attn_impl="fused", draft=draft)
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=48, speculate=2, draft=draft)
    with pytest.raises(ValueError, match="greedy"):
        eng.submit(Request(id=0, prompt=(1, 2), max_new_tokens=2,
                           temperature=1.0), 0.0)
    # lookahead rows count against the sequence budget
    assert not eng.submit(Request(id=1, prompt=(1,) * 40,
                                  max_new_tokens=8), 0.0)
    assert eng.sched.rejected == [1]
