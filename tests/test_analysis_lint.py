"""Fixture tests for the repro.analysis lint suite.

Each pass gets good/bad fixture pairs asserting exact finding codes and
line numbers, pragma suppression is exercised per pass, the baseline
round-trips, and a self-check asserts the repo itself scans clean (the
same invariant the CI fast-lane gate enforces).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis import lint
from repro.analysis.counters import CounterNamePass
from repro.analysis.hostsync import HostSyncPass
from repro.analysis.retrace import RetracePass
from repro.analysis.spans import SpanLifecyclePass

REPO_ROOT = Path(__file__).resolve().parents[1]

HOT_PATH = "fx/serving/workers.py"       # matches a hostsync HOT_SUFFIX
AUDITED_PATH = "fx/serving/metrics.py"   # matches a counters audit marker
COLD_PATH = "fx/launch/tool.py"


def run_src(src, relpath=HOT_PATH, passes=None):
    src = textwrap.dedent(src).lstrip("\n")
    mod = lint.Module(Path(relpath), relpath, src)
    return lint.run_passes([mod], passes)


def line_of(src, needle):
    """1-based line of the first source line containing ``needle``."""
    src = textwrap.dedent(src).lstrip("\n")
    for i, ln in enumerate(src.splitlines(), start=1):
        if needle in ln:
            return i
    raise AssertionError(f"needle {needle!r} not in fixture")


def codes(findings):
    return sorted((f.code, f.line) for f in findings)


# ------------------------------------------------------------------ sync

SYNC_BAD = """
import jax
import jax.numpy as jnp
import numpy as np


class W:
    def step(self):
        logits = self.decode_fn(self.tok)
        nxt = np.asarray(jnp.argmax(logits, -1))
        jax.block_until_ready(logits)
        v = logits.item()
        self.payload.to_host()
        x = float(jnp.max(logits))
        return nxt, v, x
"""


def test_sync_codes_and_lines():
    findings = run_src(SYNC_BAD, passes=[HostSyncPass])
    assert codes(findings) == [
        ("SYNC001", line_of(SYNC_BAD, "block_until_ready")),
        ("SYNC002", line_of(SYNC_BAD, "np.asarray")),
        ("SYNC003", line_of(SYNC_BAD, ".item()")),
        ("SYNC004", line_of(SYNC_BAD, ".to_host()")),
        ("SYNC005", line_of(SYNC_BAD, "float(")),
    ]
    for f in findings:
        assert "W.step" in f.message  # names the hot function, not a line


def test_sync_only_fires_on_step_reachable_functions():
    src = """
    import jax.numpy as jnp
    import numpy as np


    class W:
        def cold_admin_path(self):
            return np.asarray(jnp.zeros((2,)))
    """
    assert run_src(src, passes=[HostSyncPass]) == []


def test_sync_reaches_through_the_call_graph():
    src = """
    import jax.numpy as jnp
    import numpy as np


    class W:
        def step(self):
            return self.helper()

        def helper(self):
            return np.asarray(jnp.zeros((2,)))
    """
    (f,) = run_src(src, passes=[HostSyncPass])
    assert f.code == "SYNC002"
    assert "W.helper" in f.message


def test_sync_host_only_numpy_is_clean():
    src = """
    import numpy as np


    class W:
        def step(self, ids):
            return np.asarray(sorted(ids))
    """
    assert run_src(src, passes=[HostSyncPass]) == []


def test_sync_ignores_non_hot_modules():
    assert run_src(SYNC_BAD, relpath=COLD_PATH, passes=[HostSyncPass]) == []


# --------------------------------------------------------------- retrace

RETRACE_BAD = """
import functools

import jax


def step(params, cfg):
    return params


def make_worker(mesh):
    return jax.jit(step, static_argnames=("cfg",))


@functools.partial(jax.jit, static_argnames=("cfg", "oops"))
def prefill(params, cfg: int):
    return params


@functools.partial(jax.jit, static_argnames=("tbl",))
def decode(params, tbl: list):
    return params


doubler = jax.jit(lambda x: x * 2)
"""


def test_retrace_codes_and_lines():
    findings = run_src(RETRACE_BAD, relpath=COLD_PATH, passes=[RetracePass])
    assert codes(findings) == [
        ("RET001", line_of(RETRACE_BAD, 'jax.jit(step')),
        ("RET002", line_of(RETRACE_BAD, '"oops"')),
        ("RET003", line_of(RETRACE_BAD, "tbl: list")),
        ("RET004", line_of(RETRACE_BAD, "lambda x")),
    ]


def test_retrace_module_scope_jit_is_clean():
    src = """
    import functools

    import jax


    @functools.partial(jax.jit, static_argnames=("cfg",))
    def step(params, cfg: int):
        return params


    other = jax.jit(step, static_argnames=("cfg",))
    """
    assert run_src(src, relpath=COLD_PATH, passes=[RetracePass]) == []


def test_retrace_bare_jit_needs_the_import():
    src = """
    def jit(f):
        return f


    def make():
        return jit(lambda x: x)
    """
    assert run_src(src, relpath=COLD_PATH, passes=[RetracePass]) == []


# ------------------------------------------------------------------ span

# page_freeze deliberately never closes with "offloaded" (a removed
# terminal state), plus one typo'd state and one non-literal state.
SPAN_BAD = """
def freeze(tr, sid):
    tr.async_begin("w0", "page_freeze", sid)
    tr.async_end("w0", "page_freeze", sid, state="installed")
    tr.async_end("w0", "page_freeze", sid, state="dropped")
    tr.async_end("w0", "page_freeze", sid, state="rolled_back")
    tr.async_end("w0", "page_freeze", sid, state="zombie")
    tr.async_end("w0", "page_freeze", sid, state=mode)
    tr.async_begin("w0", "orphan", sid)
    tr.async_end("w0", "ghost", sid)
"""


def test_span_codes_and_lines():
    findings = run_src(SPAN_BAD, relpath=COLD_PATH,
                       passes=[SpanLifecyclePass])
    begin_line = line_of(SPAN_BAD, 'async_begin("w0", "page_freeze"')
    assert codes(findings) == [
        ("SPAN001", begin_line),                        # missing "offloaded"
        ("SPAN001", line_of(SPAN_BAD, '"zombie"')),     # undeclared state
        ("SPAN002", line_of(SPAN_BAD, "state=mode")),   # non-literal state
        ("SPAN003", line_of(SPAN_BAD, '"orphan"')),
        ("SPAN004", line_of(SPAN_BAD, '"ghost"')),
    ]
    missing = [f for f in findings if f.line == begin_line]
    assert "offloaded" in missing[0].message


def test_span_complete_machine_is_clean():
    src = """
    def freeze(tr, sid):
        tr.async_begin("w0", "page_freeze", sid)
        tr.async_end("w0", "page_freeze", sid, state="installed")
        tr.async_end("w0", "page_freeze", sid, state="dropped")
        tr.async_end("w0", "page_freeze", sid, state="rolled_back")
        tr.async_end("w0", "page_freeze", sid, state="offloaded")
        tr.async_begin("w0", "page_offload", sid)
        tr.async_end("w0", "page_offload", sid, state="restored")
        tr.async_begin("w0", "plain_span", sid)
        tr.async_end("w0", "plain_span", sid)
    """
    assert run_src(src, relpath=COLD_PATH, passes=[SpanLifecyclePass]) == []


# --------------------------------------------------------------- counter

COUNTER_BAD = """
class Worker:
    def __init__(self):
        self.counters = {"tokens": 0}

    def summary(self):
        out = {"spec_steps": 1}
        out["extra"] = 2
        return out


def ingest(stats, sched):
    stats.gauge("hbm_bytes_per_token").set(1.0)
    sched.admission("backpressure")


def report(s, stats):
    ok = (s.get("tokens", 0), s.get("spec_steps", 0), s.get("extra", 0),
          s.get("backpressure", 0))
    h = stats.histogram("hbm_bytes_per_token")
    bad = s.get("typo_key", 0)
    worse = stats.gauge("hbm_bytez")
    return ok, h, bad, worse
"""


def test_counter_codes_and_lines():
    findings = run_src(COUNTER_BAD, relpath=AUDITED_PATH,
                       passes=[CounterNamePass])
    assert codes(findings) == [
        ("CTR001", line_of(COUNTER_BAD, '"typo_key"')),
        ("CTR001", line_of(COUNTER_BAD, '"hbm_bytez"')),
    ]


def test_counter_skips_unaudited_modules():
    assert run_src(COUNTER_BAD, relpath="fx/core/solver.py",
                   passes=[CounterNamePass]) == []


# --------------------------------------------------------------- pragmas


def test_pragma_suppresses_on_same_line():
    src = """
    import jax.numpy as jnp
    import numpy as np


    class W:
        def step(self):
            return np.asarray(jnp.zeros(2))  # lint: sync(step-end sync)
    """
    assert run_src(src, passes=[HostSyncPass]) == []


def test_pragma_suppresses_from_line_above():
    src = """
    import jax.numpy as jnp
    import numpy as np


    class W:
        def step(self):
            # lint: sync(step-end sync on purpose)
            return np.asarray(jnp.zeros(2))
    """
    assert run_src(src, passes=[HostSyncPass]) == []


def test_pragma_is_per_pass():
    src = """
    import jax.numpy as jnp
    import numpy as np


    class W:
        def step(self):
            # lint: retrace(wrong pass name for this site)
            return np.asarray(jnp.zeros(2))
    """
    findings = run_src(src, passes=[HostSyncPass, RetracePass])
    assert [f.code for f in findings] == ["LINT003", "SYNC002"]


def test_pragma_empty_reason_is_lint001():
    src = """
    import jax.numpy as jnp
    import numpy as np


    class W:
        def step(self):
            return np.asarray(jnp.zeros(2))  # lint: sync()
    """
    (f,) = run_src(src, passes=[HostSyncPass])
    assert f.code == "LINT001"


def test_pragma_unknown_pass_is_lint002():
    src = """
    x = 1  # lint: hotloop(no such pass)
    """
    (f,) = run_src(src, relpath=COLD_PATH, passes=[HostSyncPass])
    assert f.code == "LINT002"
    assert "hotloop" in f.message


def test_pragma_unused_is_lint003():
    src = """
    x = 1  # lint: sync(nothing here needed suppressing)
    """
    (f,) = run_src(src, relpath=COLD_PATH, passes=[HostSyncPass])
    assert f.code == "LINT003"


def test_docstring_pragma_examples_do_not_count():
    src = '''
    def helper():
        """Example:  # lint: sync(docstring, not a comment)"""
        return 1
    '''
    assert run_src(src, relpath=COLD_PATH, passes=[HostSyncPass]) == []


# -------------------------------------------------------------- baseline


def test_baseline_round_trip(tmp_path):
    findings = run_src(SYNC_BAD, passes=[HostSyncPass])
    assert findings
    bpath = tmp_path / "baseline.json"
    lint.save_baseline(bpath, findings)
    baseline = lint.load_baseline(bpath)
    new, old = lint.partition_baseline(findings, baseline)
    assert new == [] and len(old) == len(findings)

    extra = lint.Finding("fx/serving/workers.py", 99, "SYNC001", "sync",
                         "a finding the baseline has never seen")
    new, old = lint.partition_baseline(findings + [extra], baseline)
    assert new == [extra]


def test_baseline_fingerprint_is_line_independent():
    f1 = lint.Finding("a.py", 10, "SYNC001", "sync", "msg")
    f2 = lint.Finding("a.py", 99, "SYNC001", "sync", "msg")
    assert f1.fingerprint == f2.fingerprint
    assert lint.load_baseline(None) == set()


def test_missing_baseline_is_empty(tmp_path):
    assert lint.load_baseline(tmp_path / "nope.json") == set()


# ------------------------------------------------------------ self-check


def test_repo_scans_clean():
    """The invariant CI enforces: zero unbaselined findings on src/repro,
    and zero findings at all under serving/ and kernels/."""
    findings = lint.run_paths([str(REPO_ROOT / "src" / "repro")])
    baseline = lint.load_baseline(REPO_ROOT / "analysis-baseline.json")
    new, _ = lint.partition_baseline(findings, baseline)
    assert new == [], "\n".join(f.render() for f in new)
    hot = [f for f in findings
           if "/serving/" in f.path or "/kernels/" in f.path]
    assert hot == [], "\n".join(f.render() for f in hot)


def test_all_passes_registered():
    names = set(lint.all_passes())
    assert names == {"sync", "retrace", "span", "counter"}


# ------------------------------------------------------------------- CLI


def _run_cli(*argv, cwd=REPO_ROOT):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        cwd=cwd, env=env, capture_output=True, text=True, timeout=120)


def test_cli_repo_gate_passes():
    r = _run_cli("src/repro")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 new finding(s)" in r.stdout


def test_cli_fails_on_injected_violation(tmp_path):
    bad = tmp_path / "serving" / "workers.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(textwrap.dedent("""
        import jax.numpy as jnp


        class W:
            def step(self, logits):
                return float(jnp.max(logits))
    """).lstrip("\n"))
    r = _run_cli(str(bad), "--baseline", str(tmp_path / "none.json"))
    assert r.returncode == 1
    assert "SYNC005" in r.stdout


def test_cli_json_format(tmp_path):
    bad = tmp_path / "serving" / "workers.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import jax\n\n\ndef step(x):\n"
                   "    jax.block_until_ready(x)\n")
    r = _run_cli(str(bad), "--format", "json",
                 "--baseline", str(tmp_path / "none.json"))
    assert r.returncode == 1
    payload = json.loads(r.stdout)
    assert payload["scanned_files"] == 1
    assert [f["code"] for f in payload["new"]] == ["SYNC001"]
    assert payload["baselined"] == []


def test_cli_list_passes():
    r = _run_cli("--list-passes")
    assert r.returncode == 0
    for name in ("sync", "retrace", "span", "counter"):
        assert name in r.stdout
