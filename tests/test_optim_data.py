"""Optimizers (AdamW, Adafactor) and the data pipeline."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, adamw, cosine_with_warmup


def _quadratic_losses(mod, state_dtype=jnp.float32, steps=60, lr=0.1, **kw):
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 4), jnp.float32)}
    state = mod.init(params, state_dtype)
    losses = []
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        params, state = mod.update(g, state, params, lr=lr, weight_decay=0.0,
                                   **kw)
        losses.append(float(jnp.mean((params["w"] - target) ** 2)))
    return losses


@pytest.mark.parametrize("mod", [adamw, adafactor])
def test_optimizers_converge_on_quadratic(mod):
    losses = _quadratic_losses(mod)
    assert losses[-1] < 0.01 * losses[0], losses[-1]


def test_adamw_bf16_state_still_converges():
    losses = _quadratic_losses(adamw, state_dtype=jnp.bfloat16)
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    state = adafactor.init(params)
    assert state["v"]["w"]["vr"].shape == (64,)
    assert state["v"]["w"]["vc"].shape == (32,)
    assert state["v"]["b"]["v"].shape == (32,)     # rank-1: unfactored


def test_adamw_weight_decay_decoupled():
    params = {"w": jnp.ones((4,))}
    state = adamw.init(params)
    zero_g = {"w": jnp.zeros((4,))}
    new_p, _ = adamw.update(zero_g, state, params, lr=0.1, weight_decay=0.5)
    np.testing.assert_allclose(np.asarray(new_p["w"]), 0.95)  # 1 - lr*wd


def test_cosine_schedule_shape():
    s = lambda t: float(cosine_with_warmup(jnp.float32(t), peak_lr=1.0,
                                           warmup_steps=10, total_steps=100))
    assert s(0) == 0.0
    assert abs(s(10) - 1.0) < 1e-6
    assert s(50) < 1.0
    assert abs(s(100) - 0.1) < 1e-6   # final_frac


def test_pipeline_deterministic_and_sharded():
    from repro.configs import get_reduced_config
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh

    cfg = get_reduced_config("qwen3_0_6b")
    mesh = make_host_mesh(2, 4)
    pipe = SyntheticLM(cfg, 8, 32, seed=3)
    specs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    a = pipe.next_batch(7, mesh, specs)
    b = pipe.next_batch(7, mesh, specs)
    c = pipe.next_batch(8, mesh, specs)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(a["labels"])[:, :-1],
                                  np.asarray(a["tokens"])[:, 1:])
    assert a["tokens"].sharding.mesh.shape["data"] == 2


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """The required deliverable path end-to-end: lower+compile one cell on
    the 256-chip mesh in a fresh process (512 forced host devices)."""
    import subprocess
    import sys
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "qwen3_0_6b", "--shape", "decode_32k", "--out", d],
            capture_output=True, text=True, timeout=560,
            env={**os.environ, "XLA_FLAGS": ""})
        assert r.returncode == 0, r.stdout + r.stderr
        assert "OK" in r.stdout
