"""Overload survival: tiered host offload, preempt-and-requeue, SLO-aware
admission.

The load-bearing guarantees under test:

  * typed allocator failure — ``BlockAllocator.alloc`` raises
    ``PoolExhausted`` carrying requested/free counts (and stays a
    ``MemoryError`` for legacy callers);
  * the restore-vs-recompute cost model's hard rules (quantized or
    sampled runs must restore — recompute is not value-exact for them);
  * SLO admission decisions (latency protected; best_effort shed on a
    breached windowed itl p99, deferred at high occupancy, re-admitted
    with hysteresis);
  * seeded fault injection forcing a preempt-offload at the WORST moment
    — the victim's pages demanded by the very next decode window — must
    be token-identical to the never-offloaded golden run, on both engine
    compositions, with and without speculation, and under sampling
    (restore carries the live rng);
  * offload/restore counters reconcile against the ``page_offload``
    trace-span lifecycle.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import models
from repro.configs import get_reduced_config
from repro.obs import FakeClock, Tracer, count_events
from repro.serving import (BlockAllocator, ContinuousBatchingEngine,
                           DisaggEngine, PoolExhausted, Request, SLOAdmission,
                           choose_resume, derive_draft)

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def qwen_reduced():
    cfg = get_reduced_config("qwen3_0_6b")
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk_requests(cfg, n, *, max_new=12, temperature=0.0, top_k=0, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(id=i,
                    prompt=tuple(int(x) for x in
                                 rng.integers(0, cfg.vocab, 12)),
                    max_new_tokens=max_new, temperature=temperature,
                    top_k=top_k, seed=1000 + i,
                    priority="best_effort" if i % 2 else "latency")
            for i in range(n)]


# ------------------------------------------------------- typed exhaustion


def test_pool_exhausted_typed():
    a = BlockAllocator(4)
    a.alloc(2)
    with pytest.raises(PoolExhausted) as ei:
        a.alloc(5)
    assert ei.value.requested == 5
    assert ei.value.free == 1
    assert "5" in str(ei.value) and "1" in str(ei.value)
    # legacy callers that only catch MemoryError keep working
    with pytest.raises(MemoryError):
        a.alloc(5)


# --------------------------------------------------------- cost model


def test_choose_resume_cost_model():
    # quantized / sampled runs MUST restore: recompute re-prefills through
    # exact fp where the first life served reconstructions
    assert choose_resume(frozen_pages=0, total_pages=4, restore_bytes=4000,
                         fp_equiv_bytes=4000, exact_required=True) == "restore"
    # well-compressed payload (most pages frozen): moving it back is cheap
    assert choose_resume(frozen_pages=3, total_pages=4, restore_bytes=1000,
                         fp_equiv_bytes=4000,
                         exact_required=False) == "restore"
    # nothing frozen: payload is full-width, re-prefill instead
    assert choose_resume(frozen_pages=0, total_pages=4, restore_bytes=4000,
                         fp_equiv_bytes=4000,
                         exact_required=False) == "recompute"
    assert choose_resume(frozen_pages=0, total_pages=0, restore_bytes=0,
                         fp_equiv_bytes=0, exact_required=False) == "recompute"


# ------------------------------------------------------- SLO admission


class _Hist:
    def __init__(self):
        from repro.obs.stats import Registry
        self.stats = Registry()


def test_slo_admission_decisions():
    m = _Hist()
    pol = SLOAdmission(m, itl_slo_s=0.010, occ_defer=0.95, occ_resume=0.80,
                       min_samples=4)
    lat = Request(id=0, prompt=(1,), max_new_tokens=4, priority="latency")
    be = Request(id=1, prompt=(1,), max_new_tokens=4, priority="best_effort")
    # no samples yet: no shed signal; low occupancy: admit
    assert pol.decide(be, occupancy=0.5) == "admit"
    # latency tier passes regardless of pressure
    assert pol.decide(lat, occupancy=1.0) == "admit"
    # best_effort defers at the occupancy door
    assert pol.decide(be, occupancy=0.99) == "defer"
    # breach the itl SLO: windowed p99 over min_samples gaps
    h = m.stats.histogram("itl_s")
    for _ in range(8):
        h.observe(0.050)
    assert pol.decide(be, occupancy=0.5) == "shed"
    assert pol.decide(lat, occupancy=0.5) == "admit"
    # hysteresis band for deferred retries
    assert not pol.may_resume(occupancy=0.90, idle=False)
    assert pol.may_resume(occupancy=0.70, idle=False)
    assert pol.may_resume(occupancy=1.0, idle=True)


def test_slo_windowed_not_lifetime():
    """The shed signal is the WINDOWED p99 — a bad cold-start tail must
    wash out once the live window is healthy again."""
    m = _Hist()
    pol = SLOAdmission(m, itl_slo_s=0.010, window=16, min_samples=4)
    be = Request(id=1, prompt=(1,), max_new_tokens=4,
                 priority="best_effort")
    h = m.stats.histogram("itl_s")
    for _ in range(32):                      # terrible cold-start window
        h.observe(0.100)
    assert pol.decide(be, occupancy=0.1) == "shed"
    for _ in range(64):                      # recovered steady state
        h.observe(0.001)
    assert pol.decide(be, occupancy=0.1) == "admit"


def test_defer_and_retry(qwen_reduced):
    """A best_effort arrival against a ~full pool parks in the deferred
    queue (arrival metered once); once occupancy recedes it rejoins the
    ordinary waiting queue behind the FCFS door."""
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=48, num_blocks=7,
                                   admission="slo")
    om, w = eng.overload, eng.worker
    held = w.alloc.alloc(6)                   # occupy the whole pool
    # a live occupant, so the retry gate can't take the idle shortcut
    w.sched.admit_direct(Request(id=9, prompt=(1,), max_new_tokens=2))
    be = Request(id=0, prompt=(1, 2, 3), max_new_tokens=4,
                 priority="best_effort")
    lat = Request(id=1, prompt=(1, 2, 3), max_new_tokens=4)
    assert eng.submit(be, 0.0) is True        # accepted... into deferral
    assert list(om.deferred) == [be]
    assert not w.sched.waiting
    assert eng.submit(lat, 0.0) is True       # latency passes the door
    assert list(w.sched.waiting) == [lat]
    # pressure stays: retry is a no-op (hysteresis)
    assert om.retry_deferred(w) == 0
    w.alloc.free(held)
    assert om.retry_deferred(w) == 1
    assert list(w.sched.waiting) == [lat, be]
    s = eng.metrics.summary()
    assert s["deferred"] == 1


# ------------------------------------- fault injection: worst-moment restore


def _forced_offload_outputs(eng, requests, *, at_steps, worker=None):
    """Run ``requests`` on ``eng``, force-preempting (offload mode) the
    longest active sequence at each decode step in ``at_steps`` — its
    pages are then demanded by the very next decode window, so the
    restore-ahead path has zero slack. Returns (outputs, summary)."""
    om = eng.overload
    w = worker if worker is not None else eng.worker
    orig_step = w.step
    fired = set()

    def step(now_fn):
        n = w.counters["decode_steps"]
        if n in at_steps and n not in fired and w.sched.active:
            fired.add(n)
            slot = max(w.sched.active,
                       key=lambda i: (int(w.lens[i]), i))
            st = w.sched.active[slot]
            if not st.done and w.slots[slot].out:
                entry = w.preempt(st, "restore", now_fn())
                om.store.put(entry)
                om.resume.append(entry)
        orig_step(now_fn)

    w.step = step
    summary = eng.run(requests)
    assert fired, "fault injection never fired — trace too short"
    assert len(om.store) == 0 and not om.resume
    return dict(eng.outputs), summary


@pytest.mark.parametrize("speculate", [0, 2])
def test_forced_restore_token_identity_colocated(qwen_reduced, speculate):
    cfg, params = qwen_reduced
    kw = dict(max_slots=2, block_size=8, max_seq_len=48,
              kv_quant="kmeans_ls@16", freeze_async=False,
              speculate=speculate,
              draft=derive_draft(params, cfg) if speculate else None)
    golden_eng = ContinuousBatchingEngine(params, cfg, **kw)
    golden_eng.run(_mk_requests(cfg, 3))
    golden = dict(golden_eng.outputs)
    eng = ContinuousBatchingEngine(params, cfg, offload_pages=True, **kw)
    outs, s = _forced_offload_outputs(eng, _mk_requests(cfg, 3),
                                      at_steps={3, 7})
    assert outs == golden
    assert s["preempt_offloads"] == s["restored_seqs"] >= 1
    assert s["offloaded_pages"] == s["restored_pages"]
    assert s["offload_bytes"] == s["restore_bytes"] > 0


def test_forced_restore_token_identity_disagg(qwen_reduced):
    cfg, params = qwen_reduced
    kw = dict(max_slots=2, block_size=8, max_seq_len=48,
              kv_quant="kmeans_ls@16", freeze_async=False)
    golden_eng = ContinuousBatchingEngine(params, cfg, **kw)
    golden_eng.run(_mk_requests(cfg, 3))
    golden = dict(golden_eng.outputs)
    eng = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                       migrate="frozen", offload_pages=True, **kw)
    outs, s = _forced_offload_outputs(eng, _mk_requests(cfg, 3),
                                      at_steps={3, 7}, worker=eng.decode[0])
    assert outs == golden
    assert s["preempt_offloads"] == s["restored_seqs"] >= 1


def test_forced_restore_sampled_rng_carries(qwen_reduced):
    """A sampled sequence restores with its live Generator: the tokens
    drawn after the stall must equal the uninterrupted run's."""
    cfg, params = qwen_reduced
    kw = dict(max_slots=2, block_size=8, max_seq_len=48,
              kv_quant="kmeans_ls@16", freeze_async=False)
    reqs = lambda: _mk_requests(cfg, 3, temperature=0.7, top_k=5)
    golden_eng = ContinuousBatchingEngine(params, cfg, **kw)
    golden_eng.run(reqs())
    golden = dict(golden_eng.outputs)
    eng = ContinuousBatchingEngine(params, cfg, offload_pages=True, **kw)
    outs, _ = _forced_offload_outputs(eng, reqs(), at_steps={4})
    assert outs == golden


# ------------------------------------- retirement while offloaded (leaks)


def test_retire_while_offloaded_drains_host_tier(qwen_reduced):
    """A request cancelled/finished while its pages sit in the host tier
    must be explicitly retired — its store entry and resume-queue slot
    released — or the entry leaks for the life of the process."""
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(
        params, cfg, max_slots=2, block_size=8, max_seq_len=48,
        kv_quant="kmeans_ls@16", freeze_async=False, offload_pages=True)
    om, w = eng.overload, eng.worker
    retired = []
    orig_step = w.step

    def step(now_fn):
        n = w.counters["decode_steps"]
        if n == 3 and w.sched.active and not retired:
            slot = max(w.sched.active, key=lambda i: (int(w.lens[i]), i))
            st = w.sched.active[slot]
            if not st.done and w.slots[slot].out:
                entry = w.preempt(st, "restore", now_fn())
                om.store.put(entry)
                om.resume.append(entry)
                assert len(om.store) == 1 and len(om.resume) == 1
                # ... and the request is cancelled while demoted:
                got = om.retire(st.req.id)
                assert got is entry
                assert om.retire(st.req.id) is None      # idempotent
                retired.append(st.req.id)
        orig_step(now_fn)

    w.step = step
    eng.run(_mk_requests(cfg, 3))
    assert retired, "fault injection never fired"
    # both tiers drained: no store entry, no resume ghost, pool whole
    assert len(om.store) == 0 and not om.resume and not om.deferred
    assert om.store.pages == 0
    assert eng.alloc.num_free == eng.num_blocks - 1
    assert set(eng.outputs) == {0, 1, 2} - set(retired)


# ---------------------------------------- preemption visibility at attach


def test_just_attached_victim_visible_to_preemption(qwen_reduced):
    """The LRU signal seeds at attach: a best_effort sequence that has
    held pages for ZERO decode steps is the coldest possible victim and
    must be visible to ``pick_victim`` immediately — a capacity-blocked
    latency head cannot wait for the victim's first decode step."""
    cfg, params = qwen_reduced
    kw = dict(max_slots=1, block_size=8, max_seq_len=48,
              kv_quant="kmeans_ls@16", freeze_async=False)
    reqs = lambda: [
        Request(id=0, prompt=tuple(range(1, 13)), max_new_tokens=8,
                priority="best_effort"),
        Request(id=1, prompt=tuple(range(20, 32)), max_new_tokens=8),
    ]
    golden_eng = ContinuousBatchingEngine(params, cfg, **kw)
    golden_eng.run(reqs())
    golden = dict(golden_eng.outputs)
    eng = ContinuousBatchingEngine(params, cfg, offload_pages=True,
                                   preempt=True, **kw)
    om, w = eng.overload, eng.worker
    seen = []
    orig_attach = w.attach

    def spy_attach(st, fin, now):
        orig_attach(st, fin, now)
        if st.req.priority == "best_effort" and not st.done:
            # forced preempt-at-attach: the latency head (id 1) is slot-
            # blocked right now, so the victim scan runs before this
            # sequence's first decode step — it must be found
            v = om.pick_victim(w)
            seen.append(None if v is None else v.req.id)

    w.attach = spy_attach
    s = eng.run(reqs())
    assert seen == [0], "just-attached best_effort victim was invisible"
    assert s["preemptions"] >= 1
    assert dict(eng.outputs) == golden


# ----------------------------------------- preempt-and-requeue, end to end


@pytest.mark.parametrize("offload", [True, False])
def test_preempt_under_pressure_completes_identically(qwen_reduced, offload):
    """2x-oversubscribed pool with preemption on: every request still
    completes, outputs are token-identical to the uncontended golden run,
    and the chosen resume path matches the cost model (quantized -> must
    restore; fp greedy with the host tier off -> recompute)."""
    cfg, params = qwen_reduced
    kv = "kmeans_ls@16" if offload else None
    kw = dict(max_slots=2, block_size=8, max_seq_len=48, kv_quant=kv,
              freeze_async=False)
    golden_eng = ContinuousBatchingEngine(params, cfg, **kw)
    golden_eng.run(_mk_requests(cfg, 4))
    golden = dict(golden_eng.outputs)
    eng = ContinuousBatchingEngine(params, cfg, num_blocks=8,
                                   offload_pages=offload, preempt=True, **kw)
    s = eng.run(_mk_requests(cfg, 4))
    assert dict(eng.outputs) == golden
    assert s["preemptions"] >= 1
    if offload:
        assert s["preempt_recomputes"] == 0
        assert s["preempt_offloads"] == s["restored_seqs"] >= 1
    else:
        assert s["preempt_offloads"] == 0
        assert s["preempt_recomputes"] >= 1


def test_preempted_requeue_ahead_of_fcfs():
    """A preempted request outranks every queued arrival at re-admission."""
    from repro.serving import ContinuousBatchingScheduler
    sched = ContinuousBatchingScheduler(max_slots=1, block_size=8)
    a = Request(id=0, prompt=(1,) * 8, max_new_tokens=8)
    b = Request(id=1, prompt=(1,) * 8, max_new_tokens=8)
    sched.submit(a)
    sched.preempted.append(b)
    admitted = sched.schedule(free_blocks=64)
    assert [st.req.id for st in admitted] == [1]


# -------------------------------------------------- span/counter reconcile


def test_offload_spans_reconcile(qwen_reduced):
    """Every offloaded page opens a ``page_offload`` async span and every
    restore closes it ``restored``; the victim's open ``page_freeze``
    spans terminate ``offloaded``. Counters must agree exactly."""
    cfg, params = qwen_reduced
    tr = Tracer(clock=FakeClock())
    # freeze_page_budget=1 keeps freezes queued across step boundaries so
    # preemption catches in-flight page_freeze spans (terminal "offloaded")
    kw = dict(max_slots=2, block_size=8, max_seq_len=48,
              kv_quant="kmeans_ls@16", freeze_async=False,
              freeze_page_budget=1, tracer=tr)
    eng = ContinuousBatchingEngine(params, cfg, num_blocks=8,
                                   offload_pages=True, preempt=True, **kw)
    s = eng.run(_mk_requests(cfg, 4))
    assert s["preempt_offloads"] >= 1
    b = count_events(tr.events, name="page_offload", ph="b")
    e = count_events(tr.events, name="page_offload", ph="e")
    assert b == e == s["offloaded_pages"] == s["restored_pages"]
    restored = [ev for ev in tr.events if ev.get("name") == "page_offload"
                and ev["ph"] == "e"]
    assert all(ev["args"]["state"] == "restored" for ev in restored)
    frz_ends = [ev["args"]["state"] for ev in tr.events
                if ev.get("name") == "page_freeze" and ev["ph"] == "e"]
    assert "offloaded" in frz_ends
    assert count_events(tr.events, name="preempt", ph="i") \
        == s["preemptions"]
    assert count_events(tr.events, name="restore", ph="i") \
        == s["restored_seqs"]


# ----------------------------------------------- admission reason counters


def test_admission_reason_counters(qwen_reduced):
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=48, max_queue=1)
    # never fits the pool/sequence budget
    assert not eng.submit(Request(id=0, prompt=(1,) * 40,
                                  max_new_tokens=40), 0.0)
    # queue-depth door
    assert eng.submit(Request(id=1, prompt=(1, 2), max_new_tokens=2), 0.0)
    assert not eng.submit(Request(id=2, prompt=(1, 2), max_new_tokens=2),
                          0.0)
    snap = eng.metrics.snapshot()
    assert snap["rejected_pool_full"] == 1
    assert snap["rejected_queue_full"] == 1


def test_summary_keys_absent_without_overload(qwen_reduced):
    """Runs that never shed/deferred/rejected keep the legacy summary key
    set — the reason counters only appear when nonzero."""
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=48)
    s = eng.run(_mk_requests(cfg, 2))
    for k in ("rejected_queue_full", "rejected_pool_full", "shed_slo",
              "deferred"):
        assert k not in s


def test_shed_slo_end_to_end(qwen_reduced):
    """With an impossible itl SLO, later best_effort arrivals shed while
    every latency-tier request completes."""
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=48, admission="slo",
                                   itl_slo_s=1e-9)
    reqs = [dataclasses.replace(r, arrival_time=0.3 * i)
            for i, r in enumerate(_mk_requests(cfg, 8))]
    s = eng.run(reqs)
    shed = s.get("shed_slo", 0)
    assert shed >= 1
    assert s["rejected"] == shed               # shed are the only rejects
    done = set(eng.outputs)
    assert {r.id for r in reqs if r.priority == "latency"} <= done
