"""Framework-level quantization: PTQ over model params, batched-FISTA PTQ,
QAT straight-through, quantized serving matmul equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_reduced_config
from repro.core import QuantizedTensor
from repro.quant.ptq import (compression_ratio, dequantize_tree,
                             quantize_tree, quantize_tree_batched_fista)
from repro.quant.qat import fake_quant
from repro.quant.serve import qmatmul


def _params():
    cfg = get_reduced_config("qwen3_0_6b")
    return cfg, models.init_params(cfg, jax.random.PRNGKey(0))


def test_ptq_tree_roundtrip_and_compression():
    cfg, params = _params()
    qtree, report = quantize_tree(params, method="kmeans_ls", num_values=16)
    assert report, "nothing quantized"
    assert all(r["n_values"] <= 16 for r in report.values())
    ratio = compression_ratio(report)
    assert ratio > 3.0, ratio           # 16 values = 4 bits vs f32
    dense = dequantize_tree(qtree)
    # quantized model still runs and is close-ish
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32),
             "labels": jnp.zeros((1, 8), jnp.int32)}
    out_q = models.forward(dense, cfg, batch, train=False)
    assert bool(jnp.isfinite(out_q).all())


def test_ptq_batched_fista_quantizes_everything():
    cfg, params = _params()
    qtree, report = quantize_tree_batched_fista(params, lam=2e-4, n_iters=150)
    n_q = sum(isinstance(l, QuantizedTensor)
              for l in jax.tree.leaves(
                  qtree, is_leaf=lambda l: isinstance(l, QuantizedTensor)))
    assert n_q == len(report) and n_q > 0
    for key, r in report.items():
        assert r["n_values"] >= 1


def test_qat_fake_quant_ste():
    cb = jnp.asarray([-1.0, 0.0, 1.0])
    x = jnp.asarray([-0.9, -0.2, 0.4, 2.0])
    y = fake_quant(x, cb)
    np.testing.assert_allclose(np.asarray(y), [-1.0, 0.0, 0.0, 1.0], atol=0.26)
    g = jax.grad(lambda t: jnp.sum(fake_quant(t, cb) ** 2))(x)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).sum()) > 0


def test_quantized_serving_matmul_matches_dense():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    from repro.core import quantize
    qt, _ = quantize(w, "kmeans_ls", num_values=16)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    out_q = qmatmul(x, qt)
    out_d = x @ jnp.asarray(np.asarray(qt.to_dense()))
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_d),
                               atol=1e-4, rtol=1e-4)
