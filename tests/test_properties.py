"""Property-based serving invariants.

Three differential/invariant suites over the paged serving stack:

  * fused/interpret paged attention == the dense ``ref.ref_paged_decode``
    oracle across randomized geometries (batch, kv heads, GQA factor, page
    size, frozen fraction, per-sequence lengths, verify-window width);
  * ``extract_pages`` -> ``to_host`` -> ``splice_payload`` round-trips
    BITWISE for ``migrate="fp"`` under randomized page counts/tails;
  * page-pool conservation: a randomized admit/decode/finish trace driven
    through the real engine (async freezes in flight, speculative or not)
    never leaks or double-books a page — the free list and the live block
    tables partition the pool at every step boundary.

Two further differential suites ride the same dual-driver pattern:

  * chunked prefill == single-shot prefill, BITWISE, at both levels: raw
    ``paged_prefill_attention`` chunk sequences vs one whole-prompt call
    (chunk boundaries crossing page boundaries, frozen and fp pages), and
    the continuous engine with ``prefill_chunk`` vs inline prefill (same
    tokens, same recorded logits, same frozen-page installs);
  * stacked-group ``quant_matmul_stacked`` vs the dense oracle and the
    flat per-group kernel, <= 1e-5, across padded/unpadded tile shapes.

Each property has two drivers sharing one check body: a seeded random
corpus that runs everywhere (no hypothesis required — the same pattern as
``test_spec``), and a hypothesis-randomized variant when hypothesis is
installed. The hypothesis run is bounded by default (profile "ci", the CI
fast-lane budget); set HYPOTHESIS_PROFILE=thorough for a deeper sweep.
"""
import dataclasses
import os
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_reduced_config
from repro.kernels import (modeled_prefill_hbm_bytes_per_token, pack4,
                           paged_decode_attention, paged_prefill_attention,
                           quant_matmul, quant_matmul_stacked,
                           ref_paged_decode, ref_quant_matmul_stacked)
from repro.serving import (ContinuousBatchingEngine, Request, derive_draft,
                           extract_pages, init_paged_cache, splice_payload)
from repro.serving.transfer import collect_leaves

pytestmark = pytest.mark.serving

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
    settings.register_profile("ci", max_examples=12, deadline=None,
                              derandomize=True)
    settings.register_profile("thorough", max_examples=100, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:                                   # pragma: no cover
    HAVE_HYP = False

needs_hyp = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis not installed")


@pytest.fixture(scope="module")
def qwen_reduced():
    cfg = get_reduced_config("qwen3_0_6b")
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------- fused vs dense oracle


def _check_paged_attention(bs, Hkv, G, Dh, B, mb, W, frozen, lens, softcap):
    nb, L, Hq = B * mb + 1, 16, Hkv * G
    rng = np.random.default_rng(0)
    kfp = jnp.asarray(rng.normal(size=(nb, bs, Hkv, Dh)), jnp.float32)
    vfp = jnp.asarray(rng.normal(size=(nb, bs, Hkv, Dh)), jnp.float32)
    kcodes = rng.integers(0, L, (nb, bs, Hkv, Dh)).astype(np.uint8)
    vcodes = rng.integers(0, L, (nb, bs, Hkv, Dh)).astype(np.uint8)
    kc = pack4(jnp.asarray(kcodes))
    vc = pack4(jnp.asarray(vcodes))
    kcb = jnp.asarray(rng.normal(size=(nb, L)), jnp.float32)
    vcb = jnp.asarray(rng.normal(size=(nb, L)), jnp.float32)
    blkq = np.zeros((nb,), np.int32)
    blkq[list(frozen)] = 1
    state = (kfp, vfp, kc, vc, kcb, vcb, jnp.asarray(blkq))
    table = jnp.asarray(1 + np.arange(B * mb).reshape(B, mb), jnp.int32)
    valid = jnp.asarray(lens, jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, W, Hq, Dh)), jnp.float32)
    out = paged_decode_attention(q, *state, table, valid, softcap=softcap,
                                 quantized=True, packed=True, interpret=True)
    ref = ref_paged_decode(q, *state, table, valid, softcap=softcap,
                           quantized=True, packed=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=1e-4)


def _random_attention_geom(rng):
    bs = int(rng.choice([4, 8, 16]))
    Hkv = int(rng.choice([1, 2]))
    G = int(rng.choice([1, 2, 4]))
    Dh = int(rng.choice([8, 16]))
    B = int(rng.integers(1, 4))
    mb = int(rng.integers(1, 4))
    W = int(rng.choice([1, 2, 4]))
    nb = B * mb + 1
    n_frozen = int(rng.integers(0, nb))
    frozen = rng.choice(np.arange(1, nb), size=min(n_frozen, nb - 1),
                        replace=False).tolist()
    # valid lengths in [W, mb*bs]: every window query sees >= 1 position
    lens = rng.integers(W, mb * bs + 1, size=B).tolist()
    softcap = None if rng.integers(2) else 30.0
    return bs, Hkv, G, Dh, B, mb, W, frozen, lens, softcap


def test_fused_matches_oracle_seeded_corpus():
    """Seeded random-geometry corpus — runs everywhere."""
    rng = np.random.default_rng(7)
    for _ in range(12):
        _check_paged_attention(*_random_attention_geom(rng))


if HAVE_HYP:
    @st.composite
    def attention_geoms(draw):
        bs = draw(st.sampled_from([4, 8, 16]))
        Hkv = draw(st.sampled_from([1, 2]))
        G = draw(st.sampled_from([1, 2, 4]))
        Dh = draw(st.sampled_from([8, 16]))
        B = draw(st.integers(1, 3))
        mb = draw(st.integers(1, 3))
        W = draw(st.sampled_from([1, 2, 4]))
        nb = B * mb + 1
        frozen = draw(st.lists(st.integers(1, nb - 1), unique=True,
                               max_size=nb - 1))
        lens = draw(st.lists(st.integers(min_value=W, max_value=mb * bs),
                             min_size=B, max_size=B))
        softcap = draw(st.sampled_from([None, 30.0]))
        return bs, Hkv, G, Dh, B, mb, W, frozen, lens, softcap

    @needs_hyp
    @given(attention_geoms())
    def test_fused_matches_oracle_property(geom):
        """Hypothesis-randomized geometries, incl. multi-query verify
        windows and ragged frozen pages."""
        _check_paged_attention(*geom)


# ------------------------------------------------- fp migration bitwise


def _check_fp_roundtrip(bs, max_blocks, n_tokens, seed):
    cfg = get_reduced_config("qwen3_0_6b")
    kw = dict(num_blocks=2 * max_blocks + 1, block_size=bs, batch=1,
              max_blocks=max_blocks, quantized=False)
    rng = np.random.default_rng(seed)
    src = init_paged_cache(cfg, **kw)
    src = jax.tree_util.tree_map(
        lambda l: dataclasses.replace(
            l, k_fp=jnp.asarray(rng.normal(size=l.k_fp.shape), jnp.float32),
            v_fp=jnp.asarray(rng.normal(size=l.v_fp.shape), jnp.float32)),
        src, is_leaf=lambda x: hasattr(x, "k_fp"))
    n_pages = -(-n_tokens // bs)
    perm = rng.permutation(np.arange(1, 2 * max_blocks + 1))
    blocks = [int(b) for b in perm[:n_pages]]
    new_blocks = [int(b) for b in perm[n_pages:2 * n_pages]]
    payload = extract_pages(src, blocks, n_tokens, block_size=bs,
                            mode="fp").to_host()
    assert payload.n_pages == n_pages
    assert payload.nbytes == payload.fp_equiv_bytes > 0
    dst = splice_payload(init_paged_cache(cfg, **kw), payload, new_blocks)
    for sl, dl in zip(collect_leaves(src), collect_leaves(dst)):
        stacked = sl.k_fp.ndim == 5
        ax = 1 if stacked else 0
        for s_pool, d_pool in ((sl.k_fp, dl.k_fp), (sl.v_fp, dl.v_fp)):
            s_rows = np.take(np.asarray(s_pool), blocks, axis=ax)
            d_rows = np.take(np.asarray(d_pool), new_blocks, axis=ax)
            # collapse (page, row) -> token rows; only the n_tokens
            # written rows must land (the tail page's padding rows keep
            # the destination's contents)
            lead = (s_rows.shape[0],) if stacked else ()
            s_tok = s_rows.reshape(lead + (-1,) + s_rows.shape[-2:])
            d_tok = d_rows.reshape(lead + (-1,) + d_rows.shape[-2:])
            np.testing.assert_array_equal(d_tok[..., :n_tokens, :, :],
                                          s_tok[..., :n_tokens, :, :])


def test_fp_migration_roundtrip_seeded_corpus():
    rng = np.random.default_rng(11)
    for _ in range(6):
        bs = int(rng.choice([4, 8]))
        n_tokens = int(rng.integers(1, bs * 4 + 1))
        _check_fp_roundtrip(bs, 4, n_tokens, int(rng.integers(2**16)))


if HAVE_HYP:
    @needs_hyp
    @given(st.sampled_from([4, 8]), st.integers(1, 32),
           st.integers(0, 2**16))
    def test_fp_migration_roundtrip_property(bs, n_tokens, seed):
        """extract -> to_host -> splice is bitwise for migrate="fp" at any
        token count (full pages, ragged tail, single-row prompt)."""
        _check_fp_roundtrip(bs, 4, min(n_tokens, bs * 4), seed)


# ------------------------------------------------- pool conservation


def assert_pool_partition(worker):
    """Free list + refcounted live block tables partition the page pool:
    no page leaked, allocator refcounts exactly the multiset of table
    references (a page in N tables has rc == N; without prefix sharing
    every rc is 1, the old no-double-booking invariant)."""
    free = set(worker.alloc._free)
    used = set(worker.alloc._used)
    live = Counter()
    for s in worker.slots:
        live.update(int(b) for b in s.blocks)
    assert dict(live) == dict(worker.alloc._rc), \
        "allocator refcounts != live table reference counts"
    assert set(live) == used, "allocator used-set != live tables"
    assert not (free & used), "page both free and used"
    assert free | used == set(range(1, worker.num_blocks)), "page leaked"
    # frozen bookkeeping never refers to an unallocated page
    assert worker._frozen_pages <= used
    assert set(worker._freeze_bids) <= used
    # sharing only splices published *prefix* runs, so refcounts are
    # monotone non-increasing along every table
    for s in worker.slots:
        rcs = [worker.alloc.refcount(int(b)) for b in s.blocks]
        assert all(x >= y for x, y in zip(rcs, rcs[1:])), rcs


def _check_conservation(qwen_reduced, reqs, speculate):
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(
        params, cfg, max_slots=2, block_size=8, max_seq_len=48,
        kv_quant="kmeans_ls@16", freeze_page_budget=1,   # keep solves queued
        speculate=speculate,
        draft=derive_draft(params, cfg) if speculate else None)
    w = eng.worker
    orig_step = w.step

    def checked_step(now_fn):
        orig_step(now_fn)
        assert_pool_partition(w)

    w.step = checked_step
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, p).tolist() for p, _ in reqs]
    requests = [Request(id=i, prompt=tuple(p), max_new_tokens=reqs[i][1])
                for i, p in enumerate(prompts)]
    eng.run(requests)
    assert_pool_partition(w)
    # everything completed and every page returned — including sequences
    # that finished with freeze solves still in flight (budget=1 defers)
    assert sorted(eng.outputs) == list(range(len(reqs)))
    assert eng.alloc.num_free == eng.num_blocks - 1
    assert not w._pending_freezes and not w._freeze_bids
    if speculate:
        assert not any(w.draft.blocks)
        assert w.draft.alloc.num_free == w.draft.num_blocks - 1


def test_page_pool_conservation_seeded_corpus(qwen_reduced):
    """Randomized admit/decode/finish traces (ragged prompts and budgets,
    async freezes outliving sequences, with and without speculation) keep
    the free list + live page tables an exact partition of the pool at
    every worker step, and drain back to an empty pool."""
    rng = np.random.default_rng(3)
    for speculate in (0, 2):
        reqs = [(int(rng.integers(1, 21)), int(rng.integers(1, 9)))
                for _ in range(int(rng.integers(2, 6)))]
        _check_conservation(qwen_reduced, reqs, speculate)


if HAVE_HYP:
    @needs_hyp
    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(st.lists(st.tuples(st.integers(1, 20), st.integers(1, 8)),
                    min_size=2, max_size=5),
           st.sampled_from([0, 2]))
    def test_page_pool_conservation_property(qwen_reduced, reqs, speculate):
        _check_conservation(qwen_reduced, reqs, speculate)


# --------------------------------------- tiered residency (host offload)


def assert_tiered_partition(worker, om):
    """Device free list + live tables + host-resident set partition the
    logical pool: the device-side invariants hold unchanged, and every
    demoted sequence's residency moved WHOLE to the host tier (no page of
    it left on device, its payload staged, its resume entry queued)."""
    assert_pool_partition(worker)
    active_rids = {st.req.id for st in worker.sched.active.values()}
    store_rids = {e.req.id for e in om.store.entries()}
    assert not (store_rids & active_rids), "sequence resident in both tiers"
    for e in om.store.entries():
        assert e.payload is not None and e.payload.staged
        assert e.payload.n_pages >= 1
        assert len(e.out) == e.generated
    assert {e.req.id for e in om.resume} == store_rids
    assert om.store.pages == sum(e.payload.n_pages
                                 for e in om.store.entries())


def _check_tiered_conservation(qwen_reduced, reqs, speculate):
    """Overloaded engine (pool ~half the demand) with preempt + offload
    on: the two-tier partition must hold at every step — including
    preemptions landing between speculative verify windows and offloaded
    payloads sitting in the host store while OTHER sequences finish and
    recycle their device pages — and the run must drain both tiers."""
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(
        params, cfg, max_slots=2, block_size=8, max_seq_len=48,
        kv_quant="kmeans_ls@16", freeze_page_budget=1, num_blocks=8,
        offload_pages=True, preempt=True, speculate=speculate,
        draft=derive_draft(params, cfg) if speculate else None)
    w, om = eng.worker, eng.overload
    orig_step = w.step
    outlived = [False]

    def checked_step(now_fn):
        orig_step(now_fn)
        assert_tiered_partition(w, om)
        if len(om.store) and eng.outputs:
            outlived[0] = True          # host entries outlive finished seqs

    w.step = checked_step
    rng = np.random.default_rng(0)
    requests = [Request(id=i,
                        prompt=tuple(rng.integers(0, cfg.vocab, p).tolist()),
                        max_new_tokens=n,
                        priority="best_effort" if i % 2 else "latency")
                for i, (p, n) in enumerate(reqs)]
    s = eng.run(requests)
    assert_tiered_partition(w, om)
    assert sorted(eng.outputs) == list(range(len(reqs)))
    assert eng.alloc.num_free == eng.num_blocks - 1
    assert len(om.store) == 0 and not om.resume and not om.deferred
    assert not w._pending_freezes and not w._freeze_bids
    # quantized serving must never pick the recompute path (not exact)
    assert s["preempt_recomputes"] == 0
    return s["preemptions"], outlived[0]


def test_tiered_residency_conservation_seeded_corpus(qwen_reduced):
    rng = np.random.default_rng(5)
    preempted = outlived = 0
    for speculate in (0, 2):
        reqs = [(int(rng.integers(4, 21)), int(rng.integers(4, 9)))
                for _ in range(4)]
        p, o = _check_tiered_conservation(qwen_reduced, reqs, speculate)
        preempted += p
        outlived += o
    # the corpus must actually exercise the machinery it checks
    assert preempted >= 1
    assert outlived >= 1


if HAVE_HYP:
    @needs_hyp
    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(st.lists(st.tuples(st.integers(4, 20), st.integers(4, 8)),
                    min_size=3, max_size=5),
           st.sampled_from([0, 2]))
    def test_tiered_residency_conservation_property(qwen_reduced, reqs,
                                                    speculate):
        _check_tiered_conservation(qwen_reduced, reqs, speculate)


# ----------------------------------------- prefix sharing / refcount CoW


def _shared_prefix_requests(cfg, rng, shapes, shared_tokens):
    """Requests whose prompts share a ``shared_tokens``-long prefix (page-
    aligned sharing is up to the engine; prompts just overlap)."""
    common = tuple(int(x) for x in rng.integers(0, cfg.vocab, shared_tokens))
    reqs = []
    for i, (extra, gen) in enumerate(shapes):
        tail = tuple(int(x) for x in rng.integers(0, cfg.vocab, extra))
        reqs.append(Request(id=i, prompt=common + tail, max_new_tokens=gen,
                            priority="best_effort" if i % 2 else "latency"))
    return reqs


def _check_refcount_conservation(qwen_reduced, shapes, speculate):
    """Prefix sharing under overload: shared attach/detach interleaved
    with preemption (victims drop refs on shared pages instead of
    demoting them), speculative rollback, and async freeze installs must
    keep "free list + refcounted live tables" an exact partition at every
    step, and drain pool, host tier, AND prefix index to empty."""
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(
        params, cfg, max_slots=2, block_size=8, max_seq_len=48,
        kv_quant="kmeans_ls@16", freeze_page_budget=2, num_blocks=10,
        offload_pages=True, preempt=True, prefix_cache=True,
        speculate=speculate,
        draft=derive_draft(params, cfg) if speculate else None)
    w, om = eng.worker, eng.overload
    orig_step = w.step

    def checked_step(now_fn):
        orig_step(now_fn)
        assert_pool_partition(w)

    w.step = checked_step
    rng = np.random.default_rng(0)
    requests = _shared_prefix_requests(cfg, rng, shapes, 16)
    s = eng.run(requests)
    assert_pool_partition(w)
    assert sorted(eng.outputs) == list(range(len(requests)))
    assert eng.alloc.num_free == eng.num_blocks - 1
    assert len(om.store) == 0 and not om.resume and not om.deferred
    assert not w._pending_freezes and not w._freeze_bids
    assert len(w.prefix) == 0, "prefix index must drain with the pool"
    return s


def test_refcount_conservation_seeded_corpus(qwen_reduced):
    rng = np.random.default_rng(9)
    hits = preempted = 0
    for speculate in (0, 2):
        shapes = [(int(rng.integers(2, 9)), int(rng.integers(4, 13)))
                  for _ in range(4)]
        s = _check_refcount_conservation(qwen_reduced, shapes, speculate)
        hits += s["prefix_hits"]
        preempted += s["preemptions"]
    # the corpus must actually exercise the machinery it checks
    assert hits >= 1, "no prefill ever spliced shared pages"
    assert preempted >= 1, "no victim ever dropped refs under pressure"


if HAVE_HYP:
    @needs_hyp
    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(st.lists(st.tuples(st.integers(2, 8), st.integers(4, 12)),
                    min_size=3, max_size=4),
           st.sampled_from([0, 2]))
    def test_refcount_conservation_property(qwen_reduced, shapes, speculate):
        _check_refcount_conservation(qwen_reduced, shapes, speculate)


def _check_cow_divergence(qwen_reduced, shapes, shared_tokens):
    """CoW divergence is invisible in the numerics: on unquantized pools
    (shared pages are exact-fp prompt rows) every sequence's recorded
    logits must be BITWISE identical to an unshared replay of the same
    trace — sharing changes which pages serve the prefix, never what the
    model sees."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(sum(e for e, _ in shapes) + shared_tokens)
    requests = _shared_prefix_requests(cfg, rng, shapes, shared_tokens)
    engines = []
    for pc in (False, True):
        eng = ContinuousBatchingEngine(
            params, cfg, max_slots=2, block_size=8, max_seq_len=64,
            kv_quant=None, record_logits=True, prefix_cache=pc)
        eng.run([dataclasses.replace(r) for r in requests])
        assert eng.alloc.num_free == eng.num_blocks - 1
        engines.append(eng)
    base, shared = engines
    assert base.outputs == shared.outputs
    for i in range(len(requests)):
        assert np.array_equal(base.request_logits[i],
                              shared.request_logits[i]), i
    return shared


def test_cow_divergence_bitwise_seeded_corpus(qwen_reduced):
    # staggered gens keep a shared-page holder live across admissions;
    # 24 shared tokens = 3 full pages at block size 8
    shared = _check_cow_divergence(qwen_reduced, [(5, 2), (5, 7), (5, 4)],
                                   24)
    s = shared.worker.counters
    assert s["prefix_hits"] >= 1 and s["prefix_shared_pages"] >= 3
    # page-aligned prompts: the raw match covers the whole prompt, the
    # splice stops one page short (the logits row must prefill privately)
    # and counts the truncation as a copy-on-write materialization
    shared = _check_cow_divergence(qwen_reduced, [(0, 2), (0, 7), (0, 4)],
                                   24)
    s = shared.worker.counters
    assert s["cow_copies"] >= 1
    assert s["prefix_shared_pages"] >= 2


if HAVE_HYP:
    @needs_hyp
    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(st.lists(st.tuples(st.integers(0, 8), st.integers(2, 8)),
                    min_size=2, max_size=3),
           st.sampled_from([8, 16, 24]))
    def test_cow_divergence_bitwise_property(qwen_reduced, shapes,
                                             shared_tokens):
        _check_cow_divergence(qwen_reduced, shapes, shared_tokens)


# --------------------------------------------- chunked prefill == single

def _prefill_state(bs, Hkv, G, Dh, B, mb, frozen, seed=1):
    L = 16
    rng = np.random.default_rng(seed)
    nb = B * mb + 1
    kfp = jnp.asarray(rng.normal(size=(nb, bs, Hkv, Dh)), jnp.float32)
    vfp = jnp.asarray(rng.normal(size=(nb, bs, Hkv, Dh)), jnp.float32)
    kc = pack4(jnp.asarray(rng.integers(0, L, (nb, bs, Hkv, Dh))
                           .astype(np.uint8)))
    vc = pack4(jnp.asarray(rng.integers(0, L, (nb, bs, Hkv, Dh))
                           .astype(np.uint8)))
    kcb = jnp.asarray(rng.normal(size=(nb, L)), jnp.float32)
    vcb = jnp.asarray(rng.normal(size=(nb, L)), jnp.float32)
    blkq = np.zeros((nb,), np.int32)
    blkq[list(frozen)] = 1
    state = (kfp, vfp, kc, vc, kcb, vcb, jnp.asarray(blkq))
    table = jnp.asarray(1 + np.arange(B * mb).reshape(B, mb), jnp.int32)
    return state, table, rng


def _check_chunked_prefill_kernel(bs, Hkv, G, Dh, mb, chunk, frozen, P,
                                  softcap):
    """A chunk sequence must be BITWISE equal to one whole-prompt call:
    same pages walked in the same order, per-row online-softmax carry."""
    B, Hq = 2, Hkv * G
    state, table, rng = _prefill_state(bs, Hkv, G, Dh, B, mb, frozen)
    q = jnp.asarray(rng.normal(size=(B, P, Hq, Dh)), jnp.float32)
    whole = paged_prefill_attention(
        q, *state, table, jnp.zeros((B,), jnp.int32), softcap=softcap,
        quantized=True, packed=True, interpret=True)
    parts = []
    for off in range(0, P, chunk):
        C = min(chunk, P - off)
        parts.append(paged_prefill_attention(
            q[:, off:off + C], *state, table,
            jnp.full((B,), off, jnp.int32), softcap=softcap,
            quantized=True, packed=True, interpret=True))
    got = jnp.concatenate(parts, axis=1)
    assert np.array_equal(np.asarray(got), np.asarray(whole))


def _random_chunk_geom(rng):
    bs = int(rng.choice([4, 8]))
    Hkv = int(rng.choice([1, 2]))
    G = int(rng.choice([1, 2]))
    Dh = int(rng.choice([8, 16]))
    mb = int(rng.integers(1, 4))
    P = int(rng.integers(1, mb * bs + 1))
    chunk = int(rng.integers(1, P + 1))
    nb = 2 * mb + 1
    n_frozen = int(rng.integers(0, nb))
    frozen = rng.choice(np.arange(1, nb), size=min(n_frozen, nb - 1),
                        replace=False).tolist()
    softcap = None if rng.integers(2) else 30.0
    return bs, Hkv, G, Dh, mb, chunk, frozen, P, softcap


def test_chunked_prefill_kernel_bitwise_seeded_corpus():
    # chunk 5 over page size 8: every boundary case (chunk crossing a page,
    # chunk == page, ragged tail) plus fully-frozen and fully-fp prefixes
    _check_chunked_prefill_kernel(8, 2, 2, 8, 3, 5, [1, 2, 4, 6], 21, None)
    _check_chunked_prefill_kernel(8, 1, 2, 8, 2, 8, [], 16, 30.0)
    _check_chunked_prefill_kernel(4, 2, 1, 16, 3, 1, list(range(1, 7)), 12,
                                  None)
    rng = np.random.default_rng(11)
    for _ in range(8):
        _check_chunked_prefill_kernel(*_random_chunk_geom(rng))


if HAVE_HYP:
    @st.composite
    def chunk_geoms(draw):
        bs = draw(st.sampled_from([4, 8]))
        Hkv = draw(st.sampled_from([1, 2]))
        G = draw(st.sampled_from([1, 2]))
        Dh = draw(st.sampled_from([8, 16]))
        mb = draw(st.integers(1, 3))
        P = draw(st.integers(1, mb * bs))
        chunk = draw(st.integers(1, P))
        nb = 2 * mb + 1
        frozen = draw(st.lists(st.integers(1, nb - 1), unique=True,
                               max_size=nb - 1))
        softcap = draw(st.sampled_from([None, 30.0]))
        return bs, Hkv, G, Dh, mb, chunk, frozen, P, softcap

    @needs_hyp
    @given(chunk_geoms())
    def test_chunked_prefill_kernel_bitwise_property(geom):
        _check_chunked_prefill_kernel(*geom)


def test_modeled_prefill_bytes_frozen_reduction():
    """>=50%-frozen shared context must model >= 2x fewer prefill HBM
    bytes/token for the fused chunked path than the gather expand."""
    B, mb, bs = 2, 4, 8
    table = 1 + np.arange(B * mb).reshape(B, mb).astype(np.int32)
    lens = np.full((B,), mb * bs, np.int32)
    blkq = np.zeros((B * mb + 1,), np.int32)
    blkq[1:1 + B * mb // 2 + 1] = 1          # just over half the pages
    kw = dict(chunk=bs, block_size=bs, n_kv_heads=2, head_dim=16,
              num_values=16, quantized=True, packed=True)
    fused = modeled_prefill_hbm_bytes_per_token(table, lens, blkq,
                                                path="fused", **kw)
    gather = modeled_prefill_hbm_bytes_per_token(table, lens, blkq,
                                                 path="gather", **kw)
    assert gather / fused >= 2.0, (gather, fused)


def _check_chunked_prefill_engine(qwen_reduced, plens, chunk, kv_quant,
                                  gen):
    """Engine-level differential: prefill_chunk vs inline prefill must
    emit the same tokens, the same recorded logits (bitwise), and freeze
    the same number of pages, with chunks interleaving live decodes
    (max_slots < len(plens) forces it)."""
    from repro.obs import Tracer, count_events

    cfg, params = qwen_reduced
    rng = np.random.default_rng(1000 * chunk + sum(plens) + gen)
    prompts = [rng.integers(0, cfg.vocab, p).tolist() for p in plens]
    outs, engines, tracers = [], [], []
    for pc in (None, chunk):
        tr = Tracer()
        eng = ContinuousBatchingEngine(
            params, cfg, max_slots=2, block_size=8, max_seq_len=64,
            kv_quant=kv_quant, record_logits=True, freeze_async=False,
            prefill_chunk=pc, tracer=tr)
        outs.append(eng.generate(prompts, max_new_tokens=gen))
        engines.append(eng)
        tracers.append(tr)
    single, chunked = engines
    assert outs[0] == outs[1]
    for i in range(len(prompts)):
        assert np.array_equal(single.request_logits[i],
                              chunked.request_logits[i])
    # flush batching is a scheduling artifact (chunked admission lands
    # bids on different iterations), but the freeze BIDS — one page_freeze
    # span opens per queued page, at attach for the whole prompt in both
    # modes — must be identical
    if kv_quant is not None:
        bids = [count_events(tr.events, name="page_freeze", ph="b")
                for tr in tracers]
        assert bids[0] == bids[1], bids
    want = sum(-(-(-(-p // 8) * 8) // chunk) for p in plens)
    assert chunked.prefill.counters["prefill_chunks"] == want
    assert single.prefill.counters["prefill_chunks"] == 0


def test_chunked_prefill_engine_bitwise_seeded_corpus(qwen_reduced):
    # chunk 5 on block 8 crosses page boundaries; fp and frozen pages
    for kv_quant in (None, "kmeans_ls@16"):
        _check_chunked_prefill_engine(qwen_reduced, (21, 13, 17), 5,
                                      kv_quant, 6)
    _check_chunked_prefill_engine(qwen_reduced, (16, 9), 8, "kmeans_ls@16",
                                  4)


if HAVE_HYP:
    @needs_hyp
    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(st.lists(st.integers(9, 25), min_size=2, max_size=3),
           st.integers(2, 9), st.sampled_from([None, "kmeans_ls@16"]))
    def test_chunked_prefill_engine_bitwise_property(qwen_reduced, plens,
                                                     chunk, kv_quant):
        _check_chunked_prefill_engine(qwen_reduced, tuple(plens), chunk,
                                      kv_quant, 4)


# ------------------------------------------- stacked quant_matmul oracle


def _check_stacked_qmatmul(G, M, K, N, L, seed):
    """Stacked-group kernel == dense oracle and == the flat kernel run
    group-by-group, <= 1e-5 (same fp32 accumulate, padded tiles)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(G, M, K)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, L, (G, K, N)).astype(np.uint8))
    cb = jnp.asarray(rng.normal(size=(G, L)), jnp.float32)
    out = np.asarray(quant_matmul_stacked(x, idx, cb, interpret=True))
    oracle = np.asarray(ref_quant_matmul_stacked(x, idx, cb))
    np.testing.assert_allclose(out, oracle, atol=1e-5, rtol=1e-5)
    flat = np.stack([np.asarray(quant_matmul(x[g], idx[g], cb[g],
                                             interpret=True))
                     for g in range(G)])
    np.testing.assert_allclose(out, flat, atol=1e-5, rtol=1e-5)


def test_stacked_qmatmul_matches_oracle_seeded_corpus():
    # ragged shapes exercise the pad/unpad wrapper; 1-group degenerates to
    # the flat kernel's tiling
    _check_stacked_qmatmul(3, 5, 17, 9, 16, 0)
    _check_stacked_qmatmul(1, 1, 8, 8, 4, 1)
    rng = np.random.default_rng(13)
    for _ in range(6):
        _check_stacked_qmatmul(int(rng.integers(1, 5)),
                               int(rng.integers(1, 20)),
                               int(rng.integers(1, 33)),
                               int(rng.integers(1, 20)),
                               int(rng.choice([4, 16])),
                               int(rng.integers(0, 100)))


if HAVE_HYP:
    @needs_hyp
    @given(st.integers(1, 4), st.integers(1, 12), st.integers(1, 24),
           st.integers(1, 12), st.sampled_from([4, 16]),
           st.integers(0, 50))
    def test_stacked_qmatmul_matches_oracle_property(G, M, K, N, L, seed):
        _check_stacked_qmatmul(G, M, K, N, L, seed)
