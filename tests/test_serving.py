"""Continuous-batching serving subsystem tests: deterministic scheduler
simulation, paged-allocator invariants, paged-cache round-trip vs the dense
ring cache, and quantized-KV numerics."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_reduced_config
from repro.serving import (BlockAllocator, ContinuousBatchingEngine,
                           ContinuousBatchingScheduler, DoubleFree,
                           PrefixIndex, Request, freeze_blocks,
                           freeze_markers, thaw_blocks)
from repro.serving.kv_cache import (_pack4, _unpack4, init_paged_layer,
                                    quantize_page)

pytestmark = pytest.mark.serving


# ------------------------------------------------------------- scheduler


def _simulate(sched, free_blocks):
    """Drive the scheduler like the engine does (prefill emits token #1,
    one decode step per iteration); returns the exact iteration schedule."""
    log = []
    free = free_blocks
    guard = 0
    while sched.has_work:
        admitted = sched.schedule(free)
        for st in admitted:
            free -= sched.blocks_for(st.req)
            st.length = st.req.prompt_len
            st.generated = 1                       # prefill's first token
        finished = sched.step_decoded()
        for st in finished:
            free += sched.blocks_for(st.req)
            sched.release(st)
        log.append((sorted(st.req.id for st in admitted),
                    sorted(st.req.id for st in finished)))
        guard += 1
        assert guard < 100, "scheduler did not converge"
    return log


def test_scheduler_exact_schedule():
    """Arrival trace in -> exact admission/eviction schedule out."""
    sched = ContinuousBatchingScheduler(max_slots=2, block_size=4,
                                        max_queue=8)
    for i in range(4):
        # 8 prompt + 4 new = 12 tokens = 3 blocks each
        assert sched.submit(Request(id=i, prompt=(1,) * 8, max_new_tokens=4))
    log = _simulate(sched, free_blocks=6)
    # 2 slots, 6 pages: r0+r1 run together; r2+r3 wait for both to evict
    assert log == [
        ([0, 1], []), ([], []), ([], [0, 1]),
        ([2, 3], []), ([], []), ([], [2, 3]),
    ]


def test_scheduler_page_budget_limits_admission():
    """Only one request fits the page budget; the second joins mid-flight
    as soon as pages free up (iteration-level batching)."""
    sched = ContinuousBatchingScheduler(max_slots=2, block_size=4,
                                        max_queue=8)
    for i in range(2):
        sched.submit(Request(id=i, prompt=(1,) * 8, max_new_tokens=4))
    log = _simulate(sched, free_blocks=3)
    assert log == [
        ([0], []), ([], []), ([], [0]),
        ([1], []), ([], []), ([], [1]),
    ]


def test_scheduler_queue_admission_control():
    sched = ContinuousBatchingScheduler(max_slots=1, block_size=4,
                                        max_queue=1)
    assert sched.submit(Request(id=0, prompt=(1,), max_new_tokens=1))
    assert not sched.submit(Request(id=1, prompt=(1,), max_new_tokens=1))
    assert sched.rejected == [1]


# ------------------------------------------------------------- allocator


def test_allocator_invariants():
    alloc = BlockAllocator(8)            # block 0 reserved -> 7 allocatable
    assert alloc.num_free == 7
    a = alloc.alloc(3)
    b = alloc.alloc(2)
    assert len(set(a) | set(b)) == 5 and 0 not in a + b
    with pytest.raises(MemoryError):
        alloc.alloc(3)
    alloc.free(a)
    with pytest.raises(ValueError):      # double free
        alloc.free(a)
    with pytest.raises(ValueError):      # foreign block
        alloc.free([0])
    assert alloc.num_free == 5
    c = alloc.alloc(5)
    assert 0 not in c


def test_allocator_refcounts_and_typed_double_free():
    alloc = BlockAllocator(8)
    a = alloc.alloc(3)
    alloc.retain(a[:2])                   # a second table splices two pages
    assert [alloc.refcount(b) for b in a] == [2, 2, 1]
    released = alloc.free(a)              # first table detaches
    assert released == [a[2]], "shared pages must survive a ref drop"
    assert alloc.num_free == 5
    with pytest.raises(DoubleFree) as ei:
        alloc.free([a[2]])                # rc already hit zero
    assert ei.value.block == a[2]
    assert isinstance(ei.value, ValueError)   # callers catching ValueError
    with pytest.raises(ValueError):
        alloc.retain([a[2]])              # retain needs a live block
    assert alloc.refcount(a[2]) == 0
    released = alloc.free(a[:2])          # last references drop together
    assert sorted(released) == sorted(a[:2])
    assert alloc.num_free == 7


def test_prefix_index_chain_lookup_and_invalidate():
    idx = PrefixIndex(4)
    toks = list(range(12))                # 3 full pages at block size 4
    assert idx.publish(toks, [1, 2, 3], None) == 3
    assert len(idx) == 3
    assert idx.lookup(toks, 3) == [1, 2, 3]
    assert idx.lookup(toks, 2) == [1, 2]             # caller's CoW cap
    assert idx.lookup(toks[:8] + [99] * 4, 3) == [1, 2]   # tail diverges
    assert idx.lookup([99] + toks[1:], 3) == []      # first page differs
    assert idx.lookup(toks[:7], 3) == [1]            # partial page ignored
    # a chain must be contiguous from the root: frozen gating stops it
    gated = PrefixIndex(4)
    assert gated.publish(toks, [4, 5, 6], frozen={4, 6}) == 1
    assert gated.lookup(toks, 3) == [4]
    # idempotent + first-publisher-wins: duplicates add nothing
    assert idx.publish(toks, [7, 8, 9], None) == 0
    assert idx.lookup(toks, 3) == [1, 2, 3]
    idx.invalidate([2])                   # page 2's last ref dropped
    assert idx.lookup(toks, 3) == [1], "chain must break at a dead page"
    idx.invalidate([1, 3])
    assert len(idx) == 0


# ------------------------------------------------------------- paged cache


def _mini_cfg():
    return get_reduced_config("qwen3_0_6b")


def test_paged_layer_roundtrip_matches_dense():
    """Block-table scatter/gather == a dense (B, L, H, D) cache."""
    cfg = _mini_cfg()
    bs, mb, B, S = 4, 3, 2, 4
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    leaf = init_paged_layer(cfg, num_blocks=8, block_size=bs, batch=B,
                            max_blocks=mb, quantized=False, num_values=16,
                            dtype=jnp.float32)
    table = np.zeros((B, mb), np.int32)
    table[0] = [3, 1, 2]
    table[1] = [5, 4, 0]
    lens = np.array([1, 2], np.int32)
    leaf = dataclasses.replace(leaf, block_table=jnp.asarray(table),
                               seq_lens=jnp.asarray(lens))
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    new, k_all, v_all, q_off, valid = leaf.update(k, v, 0)
    assert np.array_equal(np.asarray(q_off), lens)
    assert np.array_equal(np.asarray(valid), lens + S)
    dense = np.zeros((B, mb * bs, Hkv, Dh), np.float32)
    for b in range(B):
        dense[b, lens[b]:lens[b] + S] = np.asarray(k[b])
    for b in range(B):
        np.testing.assert_allclose(np.asarray(k_all)[b, lens[b]:lens[b] + S],
                                   dense[b, lens[b]:lens[b] + S])
    # a second write continues where the first stopped
    new = dataclasses.replace(new, seq_lens=new.seq_lens + S)
    k2 = jnp.asarray(rng.normal(size=(B, 1, Hkv, Dh)), jnp.float32)
    _, k_all2, _, _, _ = new.update(k2, k2, 0)
    for b in range(B):
        np.testing.assert_allclose(
            np.asarray(k_all2)[b, lens[b]:lens[b] + S],
            np.asarray(k_all)[b, lens[b]:lens[b] + S])
        np.testing.assert_allclose(np.asarray(k_all2)[b, lens[b] + S],
                                   np.asarray(k2)[b, 0])


@pytest.mark.parametrize("Dh", [6, 8, 32, 62])   # odd and even packed widths
def test_pack4_roundtrip(Dh):
    """np pack -> jnp unpack and jnp pack -> jnp unpack are exact inverses
    for every 4-bit code value, at odd/even packed dims (Dc = Dh/2)."""
    from repro.kernels import pack4, unpack4

    rng = np.random.default_rng(0)
    codes = rng.integers(0, 16, (5, 4, 2, Dh)).astype(np.uint8)
    # every code value in both nibble positions
    codes[0, 0, 0, :Dh // 2] = np.arange(Dh // 2) % 16
    codes[0, 0, 0, Dh // 2:] = 15 - (np.arange(Dh // 2) % 16)
    packed = _pack4(codes)
    assert packed.shape == (5, 4, 2, Dh // 2)
    np.testing.assert_array_equal(np.asarray(_unpack4(jnp.asarray(packed))),
                                  codes)
    # device pack agrees with the host pack bit-for-bit
    np.testing.assert_array_equal(np.asarray(pack4(jnp.asarray(codes))),
                                  packed)
    np.testing.assert_array_equal(
        np.asarray(unpack4(pack4(jnp.asarray(codes)))), codes)


def test_all_16_codes_dequantize_exactly():
    """Installing a freeze whose codes sweep all 16 values materializes
    exactly cb[codes] into the fp rows (the packed install/gather path) and
    serves it through _gather."""
    from repro.serving.kv_cache import PendingFreeze, install_freeze

    cfg = _mini_cfg()
    bs = 4
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    leaf = init_paged_layer(cfg, num_blocks=3, block_size=bs, batch=1,
                            max_blocks=1, quantized=True, num_values=16,
                            dtype=jnp.float32)
    codes = (np.arange(bs * Hkv * Dh) % 16).astype(np.uint8).reshape(
        bs, Hkv, Dh)
    cb = np.linspace(-2.0, 2.0, 16).astype(np.float32)
    packed = jnp.asarray(_pack4(codes))[None]             # (P=1, bs, H, Dc)
    cbj = jnp.asarray(cb)[None]                           # (P=1, L)
    pending = PendingFreeze(np.asarray([1], np.int32),
                            [(jnp.stack([packed, packed]),
                              jnp.stack([cbj, cbj]))])
    got = install_freeze(dataclasses.replace(
        leaf, block_table=jnp.asarray([[1]], np.int32),
        seq_lens=jnp.asarray([bs], np.int32)), pending)
    np.testing.assert_allclose(np.asarray(got.k_fp)[1], cb[codes])
    k_all = got._gather(got.k_fp, got.k_codes, got.k_cb)
    np.testing.assert_allclose(np.asarray(k_all)[0], cb[codes])
    assert np.asarray(got.blk_q)[1]


def test_null_page_write_masking():
    """Idle slots (table all-null) write into block 0; live pages stay
    untouched."""
    cfg = _mini_cfg()
    bs, mb = 4, 2
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    leaf = init_paged_layer(cfg, num_blocks=4, block_size=bs, batch=2,
                            max_blocks=mb, quantized=False, num_values=16,
                            dtype=jnp.float32)
    rng = np.random.default_rng(3)
    k_fp0 = jnp.asarray(rng.normal(size=leaf.k_fp.shape), jnp.float32)
    leaf = dataclasses.replace(
        leaf, k_fp=k_fp0, v_fp=k_fp0,
        block_table=jnp.asarray([[1, 2], [0, 0]], np.int32),  # slot 1 idle
        seq_lens=jnp.asarray([2, 0], np.int32))
    k = jnp.asarray(rng.normal(size=(2, 1, Hkv, Dh)), jnp.float32)
    new, *_ = leaf.update(k, k, 0)
    got = np.asarray(new.k_fp)
    want = np.asarray(k_fp0).copy()
    want[1, 2] = np.asarray(k)[0, 0]          # live slot's write
    want[0, 0] = np.asarray(k)[1, 0]          # idle slot -> null page trash
    np.testing.assert_allclose(got, want)
    # every non-null page except the live write position is untouched
    np.testing.assert_allclose(got[3], np.asarray(k_fp0)[3])


def test_freeze_thaw_dequantizes_within_tolerance():
    cfg = _mini_cfg()
    bs = 4
    leaf = init_paged_layer(cfg, num_blocks=4, block_size=bs, batch=1,
                            max_blocks=2, quantized=True, num_values=16,
                            dtype=jnp.float32)
    rng = np.random.default_rng(0)
    kd = rng.normal(size=leaf.k_fp.shape).astype(np.float32)
    leaf = dataclasses.replace(
        leaf, k_fp=jnp.asarray(kd), v_fp=jnp.asarray(kd * 0.5),
        block_table=jnp.asarray([[1, 2]], np.int32),
        seq_lens=jnp.asarray([2 * bs], np.int32))
    frozen = freeze_blocks(leaf, [1, 2], method="kmeans_ls", num_values=16)
    k_all = frozen._gather(frozen.k_fp, frozen.k_codes, frozen.k_cb)
    ref = np.concatenate([kd[1], kd[2]], axis=0)
    err = np.abs(np.asarray(k_all)[0] - ref)
    rms = np.sqrt((err ** 2).mean()) / np.sqrt((ref ** 2).mean())
    assert rms < 0.25, rms               # 16 shared values per page
    # the gather path serves exactly the codebook reconstruction (install
    # materialized cb[codes] into the fp rows)
    recon = np.asarray(frozen.k_cb)[[1, 2]][
        np.arange(2)[:, None],
        np.asarray(_unpack4(frozen.k_codes[np.asarray([1, 2])])
                   ).reshape(2, -1)].reshape(2, bs, *kd.shape[2:])
    np.testing.assert_allclose(np.asarray(k_all)[0],
                               recon.reshape(2 * bs, *kd.shape[2:]),
                               rtol=1e-6)
    # thaw: flag clears; the fp rows keep the reconstruction until the
    # reallocated page is overwritten by its next sequence (the original
    # values are gone once a page is frozen)
    thawed = thaw_blocks(frozen, [1, 2])
    assert not np.asarray(thawed.blk_q)[[1, 2]].any()
    k_fp = thawed._gather(thawed.k_fp, thawed.k_codes, thawed.k_cb)
    np.testing.assert_allclose(np.asarray(k_fp), np.asarray(k_all))


def test_quantize_page_tv_method():
    data = np.random.default_rng(0).normal(size=(4, 2, 8)).astype(np.float32)
    codes, cb = quantize_page(data, "tv", 8)
    assert codes.shape == data.shape and cb.shape == (8,)
    err = np.abs(cb[codes] - data).mean()
    assert err < np.abs(data).mean()


# ------------------------------------------------------------- engine


@pytest.fixture(scope="module")
def qwen_reduced():
    cfg = get_reduced_config("qwen3_0_6b")
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _dense_reference(cfg, params, prompt, gen):
    P = len(prompt)
    toks = jnp.asarray([prompt], jnp.int32)
    cache = models.init_cache(cfg, 1, P + gen)
    logits, cache = models.prefill(params, cfg, {"tokens": toks}, cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    lg = [np.asarray(logits[0, -1])]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    for i in range(gen - 1):
        logits, cache = models.decode_step(params, cfg, tok, cache,
                                           jnp.int32(P + i))
        out.append(int(jnp.argmax(logits[0, -1])))
        lg.append(np.asarray(logits[0, -1]))
        tok = jnp.asarray([[out[-1]]], jnp.int32)
    return out, np.stack(lg)


def test_paged_engine_matches_dense_cache(qwen_reduced):
    """Continuous-batching over the paged fp cache reproduces the dense
    ring-cache generation exactly (same argmax tokens, logits to 1e-3)."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 12).tolist() for _ in range(3)]
    gen = 6
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=32, record_logits=True)
    out = eng.generate(prompts, max_new_tokens=gen)
    for i, p in enumerate(prompts):
        ref, ref_logits = _dense_reference(cfg, params, p, gen)
        assert out[i] == ref, f"request {i} diverged"
        np.testing.assert_allclose(eng.request_logits[i], ref_logits,
                                   atol=1e-3, rtol=0)
    s = eng.metrics.summary()
    assert s["completed"] == 3 and s["gen_tokens"] == 18
    # all pages recycled
    assert eng.alloc.num_free == eng.num_blocks - 1


def test_quantized_kv_within_tolerance(qwen_reduced):
    """Codebook-quantized pages track the fp paged cache within the
    documented tolerance (abs<=2.5, rel<=8% at 16 values/page). kv_quant
    is given as a QuantSpec string (the legacy method+kv_num_values pair is
    covered elsewhere)."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, 16).tolist() for _ in range(2)]
    gen = 6
    runs = {}
    for kvq in (None, "kmeans_ls@16"):
        eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                       max_seq_len=32, kv_quant=kvq,
                                       record_logits=True)
        eng.generate(prompts, max_new_tokens=gen)
        runs[kvq] = eng
    fp, q = runs[None], runs["kmeans_ls@16"]
    assert q.kv_quant == "kmeans_ls" and q.kv_num_values == 16
    for i in range(len(prompts)):
        d = np.abs(fp.request_logits[i] - q.request_logits[i])
        scale = np.abs(fp.request_logits[i]).max()
        assert d.max() <= 2.5, d.max()
        assert d.max() / scale <= 0.08, (d.max(), scale)
    s = q.metrics.summary()
    # frozen pages store 4-bit codes + codebook: >= 3x smaller than fp pages
    assert fp._pb["fp"] / q._pb["frozen"] >= 3.0
    assert s.get("cache_compression_final", 0.0) > 1.0


def test_engine_serves_quantized_weight_tree(qwen_reduced):
    """PTQ'd params (QuantizedTensor leaves, stacked per-group codebooks)
    serve through qmatmul's fused dequant path without densifying, matching
    the dequantized-dense reference exactly."""
    from repro.quant.ptq import dequantize_tree, quantize_tree

    cfg, params = qwen_reduced
    qtree, report = quantize_tree(
        params, method="kmeans_ls", num_values=16, weighted=True,
        skip_patterns=("ln", "norm", "router", "A_log", "mix", "dt_bias",
                       "D_skip", "w0", "embed", "lm_head"))
    assert any(r["bytes"] < r["dense_bytes"] for r in report.values())
    prompt = np.random.default_rng(2).integers(0, cfg.vocab, 8).tolist()
    out = {}
    for tag, p in (("q", qtree), ("d", dequantize_tree(qtree))):
        eng = ContinuousBatchingEngine(p, cfg, max_slots=1, block_size=8,
                                       max_seq_len=16, record_logits=True)
        eng.generate([prompt], max_new_tokens=4)
        out[tag] = eng
    np.testing.assert_allclose(out["q"].request_logits[0],
                               out["d"].request_logits[0], atol=1e-3, rtol=0)
    assert out["q"].outputs[0] == out["d"].outputs[0]


def test_fused_decode_matches_gather_reference():
    """Pallas flash-decode (interpret) == _gather + masked sdpa on mixed
    frozen/hot pages with per-sequence lengths."""
    from repro.kernels import ref_paged_decode
    from repro.models.attention import sdpa

    cfg = _mini_cfg()
    bs, mb, B = 8, 3, 2
    Hkv, Dh, Hq = cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
    leaf = init_paged_layer(cfg, num_blocks=8, block_size=bs, batch=B,
                            max_blocks=mb, quantized=True, num_values=16,
                            dtype=jnp.float32, fused=True)
    rng = np.random.default_rng(0)
    leaf = dataclasses.replace(
        leaf,
        k_fp=jnp.asarray(rng.normal(size=leaf.k_fp.shape), jnp.float32),
        v_fp=jnp.asarray(rng.normal(size=leaf.v_fp.shape), jnp.float32),
        block_table=jnp.asarray([[3, 1, 2], [5, 4, 0]], np.int32),
        seq_lens=jnp.asarray([17, 9], np.int32))
    leaf = freeze_blocks(leaf, [3, 1, 5])          # hot pages 2 and 4 stay fp
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, Dh)), jnp.float32)
    k1 = jnp.asarray(rng.normal(size=(B, 1, Hkv, Dh)), jnp.float32)
    v1 = jnp.asarray(rng.normal(size=(B, 1, Hkv, Dh)), jnp.float32)
    new, out = leaf.fused_decode(q, k1, v1)
    _, k_all, v_all, q_off, valid = leaf.update(k1, v1, 0)
    ref = sdpa(q, k_all, v_all, causal=True, q_offset=q_off,
               kv_valid_len=valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)
    oracle = ref_paged_decode(q[:, 0], new.k_fp, new.v_fp, new.k_codes,
                              new.v_codes, new.k_cb, new.v_cb, new.blk_q,
                              new.block_table, new.seq_lens + 1,
                              quantized=True, packed=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(oracle),
                               atol=1e-5, rtol=1e-4)


def test_engine_fused_matches_gather(qwen_reduced):
    """The fused-attention engine reproduces the gather engine's generation
    (same greedy tokens, logits to interpret-kernel precision)."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, 10).tolist() for _ in range(2)]
    runs = {}
    for impl in ("gather", "fused"):
        # sync freezing: codes take over at a deterministic step, so the two
        # engines see bit-identical cache state
        eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                       max_seq_len=32, kv_quant="kmeans_ls",
                                       record_logits=True, attn_impl=impl,
                                       freeze_async=False)
        out = eng.generate(prompts, max_new_tokens=4)
        runs[impl] = (eng, out)
    (g_eng, g_out), (f_eng, f_out) = runs["gather"], runs["fused"]
    assert g_out == f_out
    for i in range(len(prompts)):
        np.testing.assert_allclose(f_eng.request_logits[i],
                                   g_eng.request_logits[i], atol=1e-3, rtol=0)


def test_device_freeze_async_no_host_solves(qwen_reduced):
    """Steady-state freezing is an async device dispatch: no per-page host
    numpy solves, every dispatch eventually installs (or is dropped with
    its finished sequence), and decode steps run between dispatch and
    install with no data dependency on the solve."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 16).tolist() for _ in range(2)]
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=48, kv_quant="kmeans_ls")
    assert eng.freeze_async
    eng.generate(prompts, max_new_tokens=10)
    c = eng.counters
    assert c["freeze_dispatches"] > 0
    assert c["host_page_solves"] == 0, "kmeans_ls must not solve on host"
    assert c["freeze_installs"] == c["freeze_dispatches"]
    assert not eng._pending_freezes          # run() drains
    assert c["decode_steps"] > 0 and c["freeze_overlap_steps"] >= 0
    # non-device methods keep the host fallback and are counted (the
    # request must outlive the iteration flush or its queued pages are
    # dropped with the freed blocks)
    eng2 = ContinuousBatchingEngine(params, cfg, max_slots=1, block_size=8,
                                    max_seq_len=16, kv_quant="dtc")
    eng2.generate([prompts[0][:8]], max_new_tokens=4)
    assert eng2.counters["host_page_solves"] > 0


def test_pending_freeze_drop_and_install():
    """dispatch -> drop(freed pages) -> install only marks the surviving
    pages frozen, with the same codes a direct freeze produces."""
    from repro.serving.kv_cache import dispatch_freeze, install_freeze

    cfg = _mini_cfg()
    bs = 4
    leaf = init_paged_layer(cfg, num_blocks=6, block_size=bs, batch=1,
                            max_blocks=3, quantized=True, num_values=16,
                            dtype=jnp.float32)
    rng = np.random.default_rng(7)
    leaf = dataclasses.replace(
        leaf, k_fp=jnp.asarray(rng.normal(size=leaf.k_fp.shape), jnp.float32),
        v_fp=jnp.asarray(rng.normal(size=leaf.v_fp.shape), jnp.float32))
    dropped = dispatch_freeze(leaf, [1, 2, 3], num_values=16)
    dropped.drop([2])                       # sequence owning page 2 finished
    got = install_freeze(leaf, dropped)
    bq = np.asarray(got.blk_q)
    assert bq[1] and bq[3] and not bq[2]
    # identical dispatch without the drop: surviving pages install the same
    # codes/codebooks; the dropped page's slots stay untouched
    full = install_freeze(leaf, dispatch_freeze(leaf, [1, 2, 3],
                                                num_values=16))
    for p in (1, 3):
        np.testing.assert_array_equal(np.asarray(got.k_codes[p]),
                                      np.asarray(full.k_codes[p]))
        np.testing.assert_array_equal(np.asarray(got.v_cb[p]),
                                      np.asarray(full.v_cb[p]))
    np.testing.assert_array_equal(np.asarray(got.k_codes[2]),
                                  np.asarray(leaf.k_codes[2]))


def test_freeze_dispatch_returns_before_completion():
    """freeze_blocks with the device solver is async: the call returns with
    the result arrays still computing (decode work can be enqueued behind
    them), and the markers eventually complete."""
    cfg = _mini_cfg()
    bs = 32
    leaf = init_paged_layer(cfg, num_blocks=64, block_size=bs, batch=1,
                            max_blocks=4, quantized=True, num_values=16,
                            dtype=jnp.float32)
    rng = np.random.default_rng(6)
    leaf = dataclasses.replace(
        leaf, k_fp=jnp.asarray(rng.normal(size=leaf.k_fp.shape), jnp.float32),
        v_fp=jnp.asarray(rng.normal(size=leaf.v_fp.shape), jnp.float32))
    jax.block_until_ready(leaf.k_fp)
    # warm the jitted solve/install for this shape so the timed dispatch
    # below measures dispatch, not compilation
    jax.block_until_ready(freeze_markers(
        freeze_blocks(leaf, list(range(1, 51)), method="kmeans_ls",
                      num_values=16)))
    t0 = time.perf_counter()
    frozen = freeze_blocks(leaf, list(range(1, 51)), method="kmeans_ls",
                           num_values=16)
    t_dispatch = time.perf_counter() - t0
    markers = freeze_markers(frozen)
    jax.block_until_ready(markers)
    t_total = time.perf_counter() - t0
    assert all(m.is_ready() for m in markers)
    # 50 pages x k/v batched through one device solve: dispatch must come
    # back well before the result does (a blocking host path pays the whole
    # solve before returning). Timing-ratio based so a fast machine that
    # finishes the solve before we could poll is_ready() doesn't flake.
    assert t_dispatch < 0.5 * t_total, (t_dispatch, t_total)


def test_decode_clamps_gather_window(qwen_reduced):
    """Short batches must not pay max_blocks bandwidth: the gathered table
    is clamped to the longest live sequence's block count."""
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=128)     # 16 blocks/slot
    prompt = list(range(1, 9))
    eng.generate([prompt], max_new_tokens=6)
    assert eng.max_blocks == 16
    # 8 prompt + 6 generated = 14 tokens -> never more than 2 blocks gathered
    assert 0 < eng.counters["max_gather_blocks"] <= 2


def test_engine_rejects_oversized_request(qwen_reduced):
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(params, cfg, max_slots=1, block_size=8,
                                   max_seq_len=16)
    ok = eng.submit(Request(id=7, prompt=(1,) * 12, max_new_tokens=8), 0.0)
    assert not ok and 7 in eng.sched.rejected


# ------------------------------------------------------------- spec surface


def test_engine_fails_fast_on_unfreezable_spec(qwen_reduced):
    """Construction-time rejection with an error naming the registry's
    device-capable methods — no lazy import deep in the freeze path."""
    from repro.core import QuantSpec, registry

    cfg, params = qwen_reduced
    for bad in ("tv:lam=0.05",                 # lam method: no count budget
                QuantSpec("l1_ls", lam=0.01)):
        with pytest.raises(ValueError) as ei:
            ContinuousBatchingEngine(params, cfg, max_slots=1, block_size=8,
                                     max_seq_len=16, kv_quant=bad)
        msg = str(ei.value)
        for m in registry.device_methods():
            assert m in msg, (bad, msg)
    with pytest.raises(ValueError, match="registered methods"):
        ContinuousBatchingEngine(params, cfg, max_slots=1, block_size=8,
                                 max_seq_len=16, kv_quant="nosuch@16")


def test_engine_legacy_kv_args_and_tv_alias(qwen_reduced):
    """Legacy (method, kv_num_values) pairs and the old 'tv' alias resolve
    to validated specs."""
    cfg, params = qwen_reduced
    eng = ContinuousBatchingEngine(params, cfg, max_slots=1, block_size=8,
                                   max_seq_len=16, kv_quant="tv",
                                   kv_num_values=8)
    assert str(eng.kv_spec) == "tv_iter@8"
    assert eng.kv_quant == "tv_iter" and eng.kv_num_values == 8
    assert not eng.freeze_async            # tv_iter has no device backend


def test_quantized_kv_iter_l1_fista_device_path(qwen_reduced):
    """The lam-parameterised FISTA freeze path (iter_l1 spec, per-row
    lambda bisection to the 4-bit budget) serves within the documented
    tolerance and never solves pages on host. Geometry matches the serve
    verification contract (block 16, the context the tolerance is
    documented for — the l1 family runs ~1.5x the kmeans_ls deviation, so
    the harsher tiny-page unit geometry is reserved for kmeans)."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, 32).tolist() for _ in range(2)]
    gen = 8
    runs = {}
    for kvq in (None, "iter_l1@16"):
        eng = ContinuousBatchingEngine(params, cfg, max_slots=2,
                                       block_size=16, max_seq_len=64,
                                       kv_quant=kvq, record_logits=True)
        eng.generate(prompts, max_new_tokens=gen)
        runs[kvq] = eng
    fp, q = runs[None], runs["iter_l1@16"]
    assert q.freeze_async and q.kv_spec.device_capable
    assert q.counters["freeze_dispatches"] > 0
    assert q.counters["host_page_solves"] == 0
    for i in range(len(prompts)):
        d = np.abs(fp.request_logits[i] - q.request_logits[i])
        scale = np.abs(fp.request_logits[i]).max()
        assert d.max() <= 2.5, d.max()
        assert d.max() / scale <= 0.08, (d.max(), scale)
