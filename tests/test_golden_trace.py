"""Golden-trace regression: a seeded colocated AND disaggregated greedy
trace (token ids + a digest of each request's final-step logits) is pinned
in ``tests/golden_trace.json``, so a decode/cache/transfer refactor that
silently changes tokens fails THIS test loudly instead of only surfacing
under a ``launch/serve.py`` verification run.

Token ids must match exactly (greedy decode is deterministic for a fixed
seed and platform); final logits are compared against the pinned rounded
values with a small tolerance so benign numeric drift (BLAS/jax version)
is distinguishable from a real decode change — the sha256 token digest in
the fixture is the one-line fingerprint to quote in a bisect.

Regenerate (ONLY when an intentional decode-semantics change is being
made, and say so in the commit):

    PYTHONPATH=src python tests/test_golden_trace.py --regen
"""
import hashlib
import json
import os

import numpy as np
import pytest

import jax

from repro import models
from repro.configs import get_reduced_config
from repro.serving import ContinuousBatchingEngine, DisaggEngine

pytestmark = pytest.mark.serving

FIXTURE = os.path.join(os.path.dirname(__file__), "golden_trace.json")

PROMPT_SEED, PARAM_SEED = 42, 0
N_REQ, PROMPT_LEN, GEN = 3, 12, 8
GEOM = dict(block_size=8, max_seq_len=48)


def _build():
    cfg = get_reduced_config("qwen3_0_6b")
    params = models.init_params(cfg, jax.random.PRNGKey(PARAM_SEED))
    rng = np.random.default_rng(PROMPT_SEED)
    prompts = [rng.integers(0, cfg.vocab, PROMPT_LEN).tolist()
               for _ in range(N_REQ)]
    return cfg, params, prompts


def _trace(engine_kind):
    cfg, params, prompts = _build()
    if engine_kind == "colocated":
        eng = ContinuousBatchingEngine(params, cfg, max_slots=2,
                                       record_logits=True, **GEOM)
    else:
        eng = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                           migrate="fp", max_slots=2, record_logits=True,
                           **GEOM)
    out = eng.generate(prompts, max_new_tokens=GEN)
    tokens = [out[i] for i in range(N_REQ)]
    final_logits = [np.asarray(eng.request_logits[i][-1], np.float64)
                    for i in range(N_REQ)]
    digest = hashlib.sha256(
        json.dumps(tokens).encode()).hexdigest()[:16]
    return {"tokens": tokens, "token_digest": digest,
            "final_logits": [np.round(l, 4).tolist() for l in final_logits]}


def _regen():
    fix = {kind: _trace(kind) for kind in ("colocated", "disagg")}
    fix["meta"] = {"arch": "qwen3_0_6b", "reduced": True,
                   "prompt_seed": PROMPT_SEED, "param_seed": PARAM_SEED,
                   "n_req": N_REQ, "prompt_len": PROMPT_LEN, "gen": GEN,
                   **GEOM}
    with open(FIXTURE, "w") as f:
        json.dump(fix, f, indent=1, sort_keys=True)
    print(f"wrote {FIXTURE}")


@pytest.mark.parametrize("kind", ["colocated", "disagg"])
def test_golden_trace(kind):
    with open(FIXTURE) as f:
        fix = json.load(f)
    got = _trace(kind)
    want = fix[kind]
    assert got["tokens"] == want["tokens"], (
        f"{kind} greedy trace changed (pinned digest "
        f"{want['token_digest']}, got {got['token_digest']}); if this "
        f"decode-semantics change is intentional, regenerate the fixture "
        f"with tests/test_golden_trace.py --regen and say so in the commit")
    assert got["token_digest"] == want["token_digest"]
    for i in range(N_REQ):
        np.testing.assert_allclose(
            got["final_logits"][i], want["final_logits"][i], atol=5e-3,
            rtol=0, err_msg=f"{kind} request {i} final logits drifted")


def test_golden_colocated_disagg_agree():
    """The two pinned engine compositions must pin the SAME trace: fp
    migration is exact, so divergence means the handoff broke."""
    with open(FIXTURE) as f:
        fix = json.load(f)
    assert fix["colocated"]["tokens"] == fix["disagg"]["tokens"]


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
