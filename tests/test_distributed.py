"""Distribution-layer tests on 8 host devices (2 data x 4 model mesh):
real execution of sharded train/prefill/decode for a dense and a MoE arch,
sharding-rule sanity, and loss-goes-down."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest


from repro import models
from repro.configs import get_reduced_config
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.runtime.sharding import (batch_shardings, cache_shardings,
                                    param_shardings)
from repro.train.step import (cache_specs, input_specs, make_decode_step,
                              make_train_step, train_state_specs)

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(len(jax.devices()) < 8,
                       reason="needs 8 host devices"),
]

# version gate for the pinned toolchain: jax.set_mesh landed after 0.4.x;
# the sharded execution tests need it and fail with AttributeError there
needs_set_mesh = pytest.mark.xfail(
    not hasattr(jax, "set_mesh"), raises=AttributeError, strict=True,
    reason=f"jax {jax.__version__} has no jax.set_mesh (needs newer jax); "
           "pre-existing failure, version-gated on the pinned toolchain")


def _mesh():
    return make_host_mesh(2, 4)


def _small_cfg(arch):
    cfg = get_reduced_config(arch)
    # make dims divide the 4-way model axis
    return dataclasses.replace(cfg, d_model=64, n_heads=4, n_kv_heads=4,
                               head_dim=16, d_ff=128, vocab=512)


@needs_set_mesh
@pytest.mark.parametrize("arch", ["qwen3_0_6b", "granite_moe_3b_a800m"])
def test_sharded_train_step_runs_and_learns(arch):
    mesh = _mesh()
    cfg = _small_cfg(arch)
    step_fn, opt = make_train_step(cfg, mesh, lr=1e-2)
    state_shape, state_shard = train_state_specs(cfg, mesh, opt)

    with jax.set_mesh(mesh):
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        params = jax.device_put(params, state_shard["params"])
        state = {"params": params, "opt": jax.device_put(opt.init(params),
                                                         state_shard["opt"]),
                 "step": jnp.zeros((), jnp.int32)}

        B, S = 8, 32
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        pipe = SyntheticLM(cfg, B, S, seed=0)
        bshard = batch_shardings(mesh, specs)
        jit_step = jax.jit(step_fn, in_shardings=(state_shard, bshard),
                           out_shardings=(state_shard, None),
                           donate_argnums=(0,))
        losses = []
        for i in range(8):
            batch = pipe.next_batch(0, mesh, specs)  # same batch: must overfit
            state, metrics = jit_step(state, batch)
            losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses  # learning on a repeated batch


def test_param_shardings_cover_and_divide():
    mesh = _mesh()
    cfg = _small_cfg("jamba_1_5_large_398b")
    pshape = jax.eval_shape(lambda: models.init_params(cfg, jax.random.PRNGKey(0)))
    shards = param_shardings(mesh, pshape)

    def check(path, leaf, sh):
        for dim, ax in zip(leaf.shape,
                           tuple(sh.spec) + (None,) * (len(leaf.shape) - len(sh.spec))):
            if ax is not None:
                size = (np.prod([mesh.shape[a] for a in ax])
                        if isinstance(ax, tuple) else mesh.shape[ax])
                assert dim % size == 0, (path, leaf.shape, sh.spec)

    jax.tree_util.tree_map_with_path(check, pshape, shards)
    # at least half the parameter bytes are actually sharded
    tot = shard = 0
    for leaf, sh in zip(jax.tree.leaves(pshape), jax.tree.leaves(
            shards, is_leaf=lambda x: hasattr(x, "spec"))):
        tot += leaf.size
        if any(ax is not None for ax in sh.spec):
            shard += leaf.size
    assert shard > 0.5 * tot


@needs_set_mesh
@pytest.mark.parametrize("arch", ["qwen3_0_6b", "rwkv6_3b"])
def test_sharded_decode_executes(arch):
    mesh = _mesh()
    cfg = _small_cfg(arch)
    with jax.set_mesh(mesh):
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        pshard = param_shardings(
            mesh, jax.eval_shape(lambda: models.init_params(cfg, jax.random.PRNGKey(0))))
        params = jax.device_put(params, pshard)
        B, L = 4, 32
        cache = models.init_cache(cfg, B, L)
        cshape = jax.eval_shape(lambda: models.init_cache(cfg, B, L))
        cshard = cache_shardings(mesh, cfg, cshape, batch_size=B)
        cache = jax.device_put(cache, cshard)
        tokens = jnp.zeros((B, 1), jnp.int32)

        def step(p, t, c):
            logits, nc = models.decode_step(p, cfg, t, c, L - 1)
            return jnp.argmax(logits[:, -1], -1), nc

        out, new_cache = jax.jit(step, in_shardings=(pshard, None, cshard),
                                 out_shardings=(None, cshard))(params, tokens,
                                                               cache)
        assert out.shape == (B,)
        assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
