"""Observability subsystem tests: tracer schema + determinism, streaming
metrics, exporters, and trace-vs-counter reconciliation on real engine runs.

The engine tests are the observability analogue of the golden-trace
fixture: a seeded run with a ``FakeClock`` tracer must emit byte-identical
Perfetto JSON across runs (timestamps are event counts, args are
deterministic ids/byte-counts — never wall-clock), and every async
page-freeze span opened during a run must reach exactly one terminal state
(installed | dropped | rolled_back) by drain, reconciling with the
worker's freeze counters.
"""
import json

import numpy as np
import pytest

import jax

from repro import models
from repro.configs import get_reduced_config
from repro.obs import (FakeClock, MetricsExporter, NULL_TRACER, Registry,
                       Tracer, count_events, prometheus_text, select_events,
                       tracks_of)
from repro.obs.stats import LogHistogram
from repro.serving import ContinuousBatchingEngine, DisaggEngine, derive_draft
from repro.serving.metrics import MetricsCollector, percentile
from repro.serving.scheduler import make_requests

PROMPT_SEED = 42
N_REQ, PROMPT_LEN, GEN = 3, 12, 8
GEOM = dict(max_slots=2, block_size=8, max_seq_len=48)


# ===================================================================== unit


def _make_full_tracer():
    tr = Tracer(clock=FakeClock())
    with tr.span("decode/w0", "decode_step", step=1):
        pass
    t0 = tr.now()
    tr.complete("transfer", "extract", t0, bytes=1024, pages=2)
    tr.instant("router", "admit", rid=0)
    tr.counter("decode/w0", "cache", occupancy=0.5, frozen_pages=3)
    tr.async_begin("freeze/w0", "page_freeze", 7, page=7, slot=0)
    tr.async_instant("freeze/w0", "page_freeze", 7, state="dispatched")
    tr.async_end("freeze/w0", "page_freeze", 7, state="installed")
    return tr


def test_tracer_chrome_schema():
    tr = _make_full_tracer()
    d = tr.to_dict()
    json.dumps(d, allow_nan=False)          # strict JSON throughout
    assert d["displayTimeUnit"] == "ms"
    evs = d["traceEvents"]
    # one labeled lane per component: thread_name + sort metadata per track
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {"decode/w0", "transfer", "router", "freeze/w0"}
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    for e in evs:
        assert {"ph", "name", "pid"} <= set(e)
        if e["ph"] == "X":
            assert "ts" in e and "dur" in e and e["dur"] >= 0
        elif e["ph"] == "i":
            assert e["s"] == "t"
        elif e["ph"] in ("b", "n", "e"):
            # async spans need (cat, id) so Perfetto can pair them
            assert isinstance(e["id"], str) and e["cat"] == "freeze/w0"
        elif e["ph"] == "C":
            assert set(e["args"]) == {"occupancy", "frozen_pages"}
    assert count_events(tr.events, track="freeze/w0", ph="b") == 1
    assert count_events(tr.events, track="freeze/w0", ph="e") == 1
    assert count_events(tr.events, name="decode_step", ph="X") == 1
    # identical event sequences on fake clocks serialize byte-identically
    a = json.dumps(tr.to_dict(), sort_keys=True, separators=(",", ":"))
    b = json.dumps(_make_full_tracer().to_dict(), sort_keys=True,
                   separators=(",", ":"))
    assert a == b


def test_null_tracer_is_inert(tmp_path):
    assert NULL_TRACER.enabled is False
    s1 = NULL_TRACER.span("decode/w0", "x", a=1)
    s2 = NULL_TRACER.span("router", "y")
    assert s1 is s2                       # one shared span: zero allocation
    with s1:
        pass
    NULL_TRACER.complete("t", "n", NULL_TRACER.now())
    NULL_TRACER.instant("t", "n")
    NULL_TRACER.counter("t", "n", v=1)
    NULL_TRACER.async_begin("t", "n", 1)
    NULL_TRACER.async_end("t", "n", 1)
    assert NULL_TRACER.events == ()
    assert NULL_TRACER.to_dict()["traceEvents"] == []
    path = tmp_path / "never.json"
    NULL_TRACER.write(str(path))
    assert not path.exists()


def test_log_histogram_percentiles():
    h = LogHistogram()
    assert h.percentile(50) is None       # empty: None, never NaN
    vals = [i / 1000.0 for i in range(1, 101)]      # 1ms .. 100ms
    for v in vals:
        h.observe(v)
    assert h.n == 100
    assert h.vmin == vals[0] and h.vmax == vals[-1]
    assert abs(h.mean - np.mean(vals)) < 1e-12
    # interior percentiles answer within the bucket's relative error
    for p in (50, 90, 99):
        want = float(np.percentile(vals, p))
        assert abs(h.percentile(p) / want - 1) < 0.16, (p, h.percentile(p))
    # extremes clamp to the exact observed range
    assert h.percentile(0) >= h.vmin
    assert h.percentile(100) == h.vmax
    # out-of-range values land in under/overflow but keep exact min/max
    h.observe(1e-9)
    h.observe(1e9)
    assert h.underflow == 1 and h.overflow == 1
    assert h.percentile(100) == 1e9
    json.dumps(h.snapshot(), allow_nan=False)


def test_log_histogram_windowed_delta():
    h = LogHistogram()
    for _ in range(10):
        h.observe(0.01)
    prev = h.state()
    for _ in range(10):
        h.observe(1.0)
    d = h.delta(prev)
    assert d["n"] == 10
    # the window sees only the second batch
    assert abs(h.percentile(50, **d) / 1.0 - 1) < 0.16
    # the all-time view still covers both
    assert h.percentile(10) < 0.02


def test_registry_and_prometheus_text():
    reg = Registry()
    reg.counter("requests").inc(3)
    reg.gauge("occupancy").set(0.25)
    reg.gauge("occupancy").set(0.75)
    for v in (0.01, 0.02, 0.03):
        reg.histogram("ttft_s").observe(v)
    assert "requests" in reg and "missing" not in reg
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)
    txt = prometheus_text(snap)
    assert "repro_requests_total 3" in txt
    assert "repro_occupancy 0.75" in txt
    assert "repro_occupancy_mean 0.5" in txt
    assert 'repro_ttft_s{quantile="0.5"}' in txt
    assert "repro_ttft_s_count 3" in txt
    # bare scalars (MetricsCollector.snapshot running totals) render too
    assert "repro_completed 4" in prometheus_text({"completed": 4})


def test_exporter_interval_and_windows(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    reg = Registry()
    exp = MetricsExporter(path, interval_s=1.0, clock=FakeClock(tick=0.4),
                          registry=reg)
    reg.histogram("itl_s").observe(0.01)
    assert exp.maybe_emit() is not None          # first call always emits
    reg.histogram("itl_s").observe(0.02)
    assert exp.maybe_emit() is None              # 0.4s < interval
    assert exp.maybe_emit() is None              # 0.8s
    line = exp.maybe_emit()                      # 1.2s elapsed
    assert line is not None and line["seq"] == 1
    # the window covers only what landed since the previous emit
    assert line["window"]["itl_s"]["n"] == 1
    exp.close()
    rows = [json.loads(ln) for ln in open(path)]
    assert [r["seq"] for r in rows] == [0, 1, 2]
    assert rows[0]["window"]["itl_s"]["n"] == 1
    assert "window" not in rows[2]               # nothing new at close


def test_summary_zero_token_guard():
    mc = MetricsCollector()
    mc.arrival(0, 0.0, prompt_len=4)
    mc.finish(0, 1.0)                     # finished without any token
    out = mc.summary()
    assert out == {"completed": 0, "completed_zero_token": 1}
    # mixed population: the zero-token finish is excluded from latencies
    mc.arrival(1, 0.0, prompt_len=4)
    mc.prefill_start(1, 0.1)
    mc.first_token(1, 0.2)
    mc.token(1, 0.3)
    mc.finish(1, 0.3)
    out = mc.summary()
    assert out["completed"] == 1 and out["completed_zero_token"] == 1
    json.dumps(out, allow_nan=False)


def test_percentile_empty_and_strict_json():
    assert percentile([], 50) is None
    mc = MetricsCollector()
    mc.arrival(0, 0.0, prompt_len=4)
    mc.first_token(0, 0.5)
    mc.finish(0, 0.5)                     # exactly one token: no tpot
    out = mc.summary()
    assert "tpot_p50_s" not in out and "tpot_p99_s" not in out
    # the regression this guards: bench artifacts must round-trip strict
    # JSON (json.dumps used to embed NaN here and poison BENCH_*.json)
    assert json.loads(json.dumps(out, allow_nan=False))["completed"] == 1


def test_summary_key_compat_and_streaming_bounds():
    """The rebuilt collector must emit the exact legacy summary() key set
    for a fully-populated run, from O(1)-memory aggregates."""
    mc = MetricsCollector()
    for rid in range(2):
        t = rid * 0.1
        mc.arrival(rid, t, prompt_len=8)
        mc.prefill_start(rid, t + 0.05)
        mc.first_token(rid, t + 0.1)
        for j in range(1, 5):
            mc.token(rid, t + 0.1 + 0.02 * j)
        mc.finish(rid, t + 0.18)
        mc.spec_step(2, 1, rolled_back=rid == 0)
    mc.sample_cache(0.5, 1000.0, 7000.0)
    mc.sample_cache(0.25, 500.0, 3500.0)
    tr = mc.traces[0]
    assert tr.queue_wait + tr.prefill_compute == pytest.approx(tr.ttft)
    out = mc.summary()
    assert set(out) == {
        "completed", "gen_tokens", "makespan_s", "throughput_tok_s",
        "ttft_mean_s", "ttft_p50_s", "ttft_p99_s",
        "tpot_p50_s", "tpot_p99_s",
        "queue_wait_mean_s", "queue_wait_p50_s", "queue_wait_p99_s",
        "prefill_compute_mean_s", "prefill_compute_p50_s",
        "prefill_compute_p99_s",
        "itl_p50_s", "itl_p99_s", "itl_max_s",
        "spec_steps", "spec_proposed", "spec_accepted", "spec_rollbacks",
        "spec_acceptance_rate",
        "cache_occupancy_mean", "cache_occupancy_max",
        "cache_bytes_final", "cache_bytes_fp_final",
        "cache_compression_mean", "cache_compression_final",
    }
    assert out["cache_compression_final"] == pytest.approx(7.0)
    # streaming: aggregate series live in fixed-size metrics, not lists
    assert not hasattr(mc, "occupancy") and not hasattr(mc, "cache_bytes")
    nbuckets = len(mc.stats["itl_s"].counts)
    for j in range(10_000):
        mc.token(0, 1.0 + j * 0.001)
    assert len(mc.stats["itl_s"].counts) == nbuckets
    # live snapshot() view stays JSON-safe mid-run
    snap = mc.snapshot()
    json.dumps(snap, allow_nan=False)
    assert snap["spec_steps"] == 2
    prometheus_text(snap)


# ================================================================== engines


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced_config("qwen3_0_6b")
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(PROMPT_SEED)
    prompts = [rng.integers(0, cfg.vocab, PROMPT_LEN).tolist()
               for _ in range(N_REQ)]
    return cfg, params, prompts


def _trace_bytes(tracer) -> bytes:
    return json.dumps(tracer.to_dict(), sort_keys=True,
                      separators=(",", ":")).encode()


def _freeze_span_states(events):
    """(begin-count, {span id -> terminal state}) for page_freeze spans."""
    begins = select_events(events, name="page_freeze", ph="b")
    ends = select_events(events, name="page_freeze", ph="e")
    states = {}
    for e in ends:
        assert e["id"] not in states, f"span {e['id']} ended twice"
        states[e["id"]] = e["args"]["state"]
    return len(begins), states


@pytest.mark.serving
def test_colocated_trace_byte_identical(model, tmp_path):
    cfg, params, prompts = model

    def one(tag):
        tr = Tracer(clock=FakeClock())
        eng = ContinuousBatchingEngine(
            params, cfg, kv_quant="kmeans_ls@16", freeze_async=False,
            tracer=tr, **GEOM)
        s = eng.run(make_requests(prompts, GEN))
        path = tmp_path / f"{tag}.json"
        tr.write(str(path))
        return tr, s, path.read_bytes()

    tr, s, raw1 = one("a")
    _, _, raw2 = one("b")
    assert raw1 == raw2, "seeded colocated trace is not byte-deterministic"
    assert json.loads(raw1)["traceEvents"]       # and is real JSON
    # counters reconcile with the trace
    assert count_events(tr.events, name="decode_step", ph="X") \
        == s["decode_steps"]
    assert count_events(tr.events, name="flush", ph="X") \
        == s["freeze_dispatches"]
    nb, states = _freeze_span_states(tr.events)
    assert nb == len(states), "a freeze span never reached a terminal state"
    assert set(states.values()) <= {"installed", "dropped", "rolled_back"}


@pytest.mark.serving
def test_disagg_trace_byte_identical(model, tmp_path):
    cfg, params, prompts = model

    def one(tag):
        tr = Tracer(clock=FakeClock())
        # sync freezes, same as the colocated twin above: the async path's
        # install step is gated on a wall-clock is_ready() poll, so which
        # iteration installs (and hence the event order) is load-dependent
        eng = DisaggEngine(
            params, cfg, prefill_workers=1, decode_workers=1,
            migrate="frozen", kv_quant="kmeans_ls@16", freeze_async=False,
            tracer=tr, **GEOM)
        # one request: the prefill/harvest interleaving is trivially
        # serial, so even the disagg composition pins exact bytes
        eng.run(make_requests(prompts[:1], GEN))
        path = tmp_path / f"{tag}.json"
        tr.write(str(path))
        return tr, path.read_bytes()

    tr, raw1 = one("a")
    _, raw2 = one("b")
    assert raw1 == raw2, "seeded disagg trace is not byte-deterministic"
    got = set(tracks_of(tr))
    assert {"router", "prefill/w0", "decode/w0", "transfer"} <= got
    # frozen migration crosses the seam as codes+codebooks: the extract
    # span must record fewer wire bytes than the fp-equivalent rows
    ex = select_events(tr.events, name="extract", ph="X")
    assert ex and all(e["args"]["mode"] == "frozen" for e in ex)


@pytest.mark.serving
def test_freeze_spans_terminal_by_drain(model):
    """Async freezing: every page_freeze span opened anywhere in the run
    (incl. pages whose sequence finished with the solve in flight) must be
    closed terminally by drain, and installs must match the counter."""
    cfg, params, prompts = model
    tr = Tracer(clock=FakeClock())
    eng = ContinuousBatchingEngine(
        params, cfg, kv_quant="kmeans_ls@16", freeze_async=True,
        tracer=tr, **GEOM)
    s = eng.run(make_requests(prompts, GEN))
    nb, states = _freeze_span_states(tr.events)
    assert nb > 0, "run froze nothing — geometry no longer exercises freezes"
    assert nb == len(states)
    assert set(states.values()) <= {"installed", "dropped", "rolled_back"}
    assert count_events(tr.events, name="flush", ph="X") \
        == s["freeze_dispatches"]
    assert count_events(tr.events, name="install", ph="i") \
        == s["freeze_installs"]
    # dispatched markers never exceed opened spans
    assert count_events(tr.events, name="page_freeze", ph="n") <= nb


@pytest.mark.serving
def test_six_component_spec_disagg_trace(model, tmp_path):
    """The acceptance composition (disagg + speculative + frozen
    migration) emits all six component tracks and reconciles every
    speculative/freeze counter against the trace."""
    cfg, params, prompts = model
    draft = derive_draft(params, cfg)
    tr = Tracer(clock=FakeClock())
    eng = DisaggEngine(
        params, cfg, prefill_workers=1, decode_workers=1, migrate="frozen",
        kv_quant="kmeans_ls@16", speculate=2, draft=draft, tracer=tr,
        **GEOM)
    s = eng.run(make_requests(prompts, GEN))
    assert s["completed"] == N_REQ
    got = set(tracks_of(tr))
    assert {"router", "prefill/w0", "decode/w0", "freeze/w0", "spec/w0",
            "transfer"} <= got
    # speculative reconciliation: one accept instant per verified slice,
    # one rollback instant per rolled-back slice
    assert count_events(tr.events, name="accept", ph="i") == s["spec_steps"]
    assert count_events(tr.events, name="rollback", ph="i") \
        == s["spec_rollbacks"]
    assert count_events(tr.events, name="decode_step", ph="X") \
        == s["decode_steps"]
    assert count_events(tr.events, name="flush", ph="X") \
        == s["freeze_dispatches"]
    nb, states = _freeze_span_states(tr.events)
    assert nb == len(states)
    assert set(states.values()) <= {"installed", "dropped", "rolled_back"}
    path = tmp_path / "trace.json"
    tr.write(str(path))
    d = json.load(open(path))
    assert {e["args"]["name"] for e in d["traceEvents"]
            if e["ph"] == "M" and e["name"] == "thread_name"} == got


@pytest.mark.serving
def test_engine_exporter_jsonl(model, tmp_path):
    """An exporter hung off the run loop lands ≥1 strict-JSON line with
    the live totals, and roofline gauges appear in the registry."""
    cfg, params, prompts = model
    path = str(tmp_path / "m.jsonl")
    exp = MetricsExporter(path, interval_s=0.0)       # emit every step
    eng = ContinuousBatchingEngine(
        params, cfg, kv_quant="kmeans_ls@16", exporter=exp, **GEOM)
    eng.run(make_requests(prompts, GEN))
    exp.close(eng.metrics)
    rows = [json.loads(ln) for ln in open(path)]
    assert rows and rows[-1]["completed"] == N_REQ
    assert rows[-1]["gen_tokens"] == N_REQ * GEN
    # host-side modeled roofline gauges published per step
    assert "hbm_bytes_per_token" in eng.metrics.stats
    assert eng.metrics.stats.gauge("hbm_bytes_per_token").n > 0
    prometheus_text(eng.metrics.snapshot())
