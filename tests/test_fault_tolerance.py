"""Checkpointing, crash recovery, elastic resharding, straggler detection,
quantized gradient compression, and pipeline parallelism - on host devices."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest


from repro.checkpoint import ckpt
from repro.runtime.ftolerance import StragglerMonitor, Trainer
from repro.quant.gradcomp import (init_error_feedback,
                                  pod_quantized_allreduce)

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(len(jax.devices()) < 8,
                       reason="needs 8 host devices"),
]


# ------------------------------------------------------------- checkpoints

def _toy_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": {"w": jnp.ones((16, 8)), "b": jnp.zeros((8,))},
                    "count": jnp.zeros((), jnp.int32)},
            "step": jnp.zeros((), jnp.int32)}


def test_ckpt_roundtrip_atomic_keep_last(tmp_path):
    d = str(tmp_path)
    s = _toy_state()
    for step in (10, 20, 30, 40):
        ckpt.save(s, d, step, keep_last=2)
    assert ckpt.latest_step(d) == 40
    assert sorted(os.listdir(d)) == ["step_00000030", "step_00000040"]
    restored, step = ckpt.restore(_toy_state(seed=1), d)
    assert step == 40
    np.testing.assert_allclose(restored["params"]["w"], s["params"]["w"])


def test_ckpt_reshard_on_load(tmp_path):
    """Save from one sharding, restore onto a different mesh layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    d = str(tmp_path)
    s = _toy_state()
    mesh_a = make_host_mesh(2, 4)
    sh_a = {"params": {"w": NamedSharding(mesh_a, P("data", "model")),
                       "b": NamedSharding(mesh_a, P(None))},
            "opt": {"m": {"w": NamedSharding(mesh_a, P("data", "model")),
                          "b": NamedSharding(mesh_a, P(None))},
                    "count": NamedSharding(mesh_a, P())},
            "step": NamedSharding(mesh_a, P())}
    s_sharded = jax.device_put(s, sh_a)
    ckpt.save(s_sharded, d, 5)
    mesh_b = make_host_mesh(4, 2)       # elastic: different mesh shape
    sh_b = jax.tree.map(
        lambda ns: NamedSharding(mesh_b, ns.spec), sh_a,
        is_leaf=lambda x: isinstance(x, NamedSharding))
    restored, _ = ckpt.restore(_toy_state(1), d, shardings=sh_b)
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                               np.asarray(s["params"]["w"]))
    assert restored["params"]["w"].sharding.mesh.shape["data"] == 4


# ------------------------------------------------------------- trainer

def _make_trainer(tmp_path, fail_at=None, total=None):
    def init_state():
        return {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}

    @jax.jit
    def step_fn(state, batch):
        x = state["x"] + batch
        return {"x": x, "step": state["step"] + 1}, {"loss": x}

    def next_batch(step):
        return jnp.float32(step + 1)   # deterministic in step

    return Trainer(step_fn=step_fn, init_state_fn=init_state,
                   next_batch_fn=next_batch, ckpt_dir=str(tmp_path),
                   ckpt_every=5, fail_at=fail_at)


def test_trainer_crash_recovery_equivalence(tmp_path):
    """Run with injected failures == uninterrupted run (exact state)."""
    clean = _make_trainer(tmp_path / "clean").run(23)
    faulty_tr = _make_trainer(tmp_path / "faulty", fail_at={7, 12, 12, 19})
    faulty = faulty_tr.run(23)
    assert faulty_tr.restarts >= 2
    np.testing.assert_allclose(float(faulty["x"]), float(clean["x"]))
    assert int(faulty["step"]) == int(clean["step"]) == 23


def test_straggler_monitor_flags_slow_steps():
    m = StragglerMonitor(threshold=2.0, warmup=2)
    for i in range(10):
        m.record(i, 0.1)
    m.record(10, 0.5)      # 5x the EMA
    assert m.flagged and m.flagged[-1][0] == 10
    m.record(11, 0.1)      # EMA not poisoned by the outlier
    assert abs(m.ema - 0.1) < 0.02


# version gate for the pinned toolchain: explicit-sharding meshes
# (jax.sharding.AxisType + jax.set_mesh) landed after 0.4.x; the two
# mesh-scoped tests below need them and fail with AttributeError there
needs_axis_type = pytest.mark.xfail(
    not hasattr(jax.sharding, "AxisType"), raises=AttributeError, strict=True,
    reason=f"jax {jax.__version__} has no jax.sharding.AxisType (needs newer "
           "jax); pre-existing failure, version-gated on the pinned toolchain")


# ------------------------------------------------- gradient compression

@needs_axis_type
def test_quantized_allreduce_matches_exact_within_tolerance():
    """2-pod compressed all-reduce ~= exact mean; error feedback shrinks the
    bias across repeated applications."""
    mesh = jax.make_mesh((2,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g_pods = np.random.default_rng(0).normal(size=(2, 64, 32)).astype(np.float32)

    def run(gs, err):
        return pod_quantized_allreduce(gs, err)

    fn = jax.shard_map(run, mesh=mesh,
                       in_specs=({"w": jax.sharding.PartitionSpec("pod")},
                                 {"w": jax.sharding.PartitionSpec("pod")}),
                       out_specs=({"w": jax.sharding.PartitionSpec("pod")},
                                  {"w": jax.sharding.PartitionSpec("pod")}),
                       check_vma=False)
    with jax.set_mesh(mesh):
        err0 = jnp.zeros((2, 64, 32), jnp.float32)
        out, err = fn({"w": jnp.asarray(g_pods)}, {"w": err0})
    exact = g_pods.mean(0)
    got = np.asarray(out["w"])[0]    # every pod shard holds the same mean
    rel = np.abs(got - exact).max() / np.abs(exact).max()
    assert rel < 0.02, rel            # int8: ~1/127 quantization error
    assert np.abs(np.asarray(err["w"])).max() > 0   # feedback state active


# ------------------------------------------------- pipeline parallelism

@needs_axis_type
def test_gpipe_pipeline_matches_sequential():
    from repro.runtime.pipeline import pipeline_forward

    n_stages, n_micro, mb, d = 4, 8, 2, 16
    mesh = jax.make_mesh((4,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(size=(n_stages, d, d)) / np.sqrt(d),
                     jnp.float32)
    x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    with jax.set_mesh(mesh):
        out = pipeline_forward(stage_fn, ws, x, mesh=mesh, n_stages=n_stages)
    ref = x
    for i in range(n_stages):
        ref = jnp.tanh(ref @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
