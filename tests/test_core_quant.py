"""Unit + property tests for the paper's core algorithms (repro.core)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ALL_METHODS, COUNT_METHODS, LAM_METHODS, LSQProblem, cd_solve, kmeans_1d,
    kmeans_ls_quantize, make_problem, max_stable_lam2, objective,
    optimal_kmeans_1d, quantize, reconstruct, refit_support, support_of,
    tv_solve_problem, unique_with_counts,
)
from repro.core.cd import cd_solve_dense_reference
from repro.core.refit import refit_support_dense_reference
from repro.core.kmeans_ls import kmeans_ls_dense_reference


def _data(seed=0, n=400, round_to=2):
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1, n).round(round_to)


# ---------------------------------------------------------------- CD solver

@pytest.mark.parametrize("lam,lam2", [(0.01, 0.0), (0.1, 0.0), (0.05, "auto")])
def test_cd_matches_dense_reference(lam, lam2):
    """The O(m)-per-sweep CD must produce the same iterates as textbook CD."""
    vals, counts, _ = unique_with_counts(_data(1))
    prob = make_problem(vals, counts)
    l2 = 0.25 * max_stable_lam2(prob) if lam2 == "auto" else lam2
    a_fast, _ = cd_solve(prob, lam, l2, max_sweeps=30)
    a_ref, _ = cd_solve_dense_reference(prob, lam, l2, max_sweeps=30)
    np.testing.assert_allclose(np.asarray(a_fast), a_ref, atol=5e-4)


def test_cd_monotone_objective():
    vals, counts, _ = unique_with_counts(_data(2))
    prob = make_problem(vals, counts)
    prev = float("inf")
    alpha = jnp.ones((prob.m,), jnp.float32)
    from repro.core.cd import cd_sweep
    lamv = jnp.full((prob.m,), jnp.float32(0.05))
    for _ in range(10):
        alpha, _ = cd_sweep(alpha, prob, lamv, 0.0)
        f = float(objective(prob, alpha, 0.05))
        assert f <= prev + 1e-5, "CD objective must be non-increasing"
        prev = f


def test_cd_init_ones_zero_ls_loss():
    """Paper §3.2.1: alpha=1 reconstructs w_hat exactly."""
    vals, counts, _ = unique_with_counts(_data(3))
    prob = make_problem(vals, counts)
    r = np.asarray(prob.w_hat) - np.asarray(reconstruct(jnp.ones(prob.m), prob.d))
    assert np.abs(r).max() < 1e-5


def test_l1l2_sparser_at_equal_lam1():
    """Paper §3.3/fig.4: negative-l2 yields fewer distinct values at equal lam1."""
    w = _data(4)
    _, i1 = quantize(w, "l1", lam=0.05)
    _, i2 = quantize(w, "l1l2", lam=0.05)
    assert i2["n_values"] <= i1["n_values"]


def test_tv_exact_beats_or_matches_cd():
    """TV solves eq.6 (penalize_first=False) globally: objective <= CD's."""
    vals, counts, _ = unique_with_counts(_data(5))
    prob = make_problem(vals, counts)
    for lam in (0.01, 0.05, 0.2):
        a_cd, _ = cd_solve(prob, lam, penalize_first=False, max_sweeps=300, tol=1e-9)
        u_tv = tv_solve_problem(prob, lam)
        d = np.asarray(prob.d)
        a_tv = np.diff(u_tv, prepend=0.0) / np.where(d == 0, 1.0, d)
        f_cd = float(objective(prob, a_cd, lam, penalize_first=False))
        f_tv = float(objective(prob, jnp.asarray(a_tv, jnp.float32), lam,
                               penalize_first=False))
        assert f_tv <= f_cd + 1e-3
        # and they agree when CD is converged tightly (loose: f32 CD has a slow
        # tail near merge boundaries where the objective is nearly flat)
        np.testing.assert_allclose(np.asarray(reconstruct(a_cd, prob.d)), u_tv,
                                   atol=5e-2)


# ---------------------------------------------------------------- refit

def test_refit_matches_lstsq_oracle():
    vals, counts, _ = unique_with_counts(_data(6))
    for weighted in (False, True):
        prob = make_problem(vals, counts, weighted=weighted)
        alpha, _ = cd_solve(prob, 0.05)
        sup = support_of(alpha)
        w_star, _ = refit_support(prob, sup)
        w_ref = refit_support_dense_reference(prob, np.asarray(sup))
        np.testing.assert_allclose(np.asarray(w_star), w_ref, atol=1e-4)


def test_refit_reduces_loss():
    """Paper claim 2: LS refit strictly improves the raw l1 result."""
    w = _data(7)
    _, raw = quantize(w, "l1", lam=0.08)
    _, ls = quantize(w, "l1_ls", lam=0.08)
    assert ls["l2_loss"] <= raw["l2_loss"]


# ---------------------------------------------------------------- alg 3 / kmeans

def test_kmeans_ls_matches_eq20_oracle():
    vals, counts, _ = unique_with_counts(_data(8))
    prob = make_problem(vals, counts)
    w_star, _, idx, _ = kmeans_ls_quantize(prob, 7)
    w_ref = kmeans_ls_dense_reference(prob, np.asarray(idx))
    np.testing.assert_allclose(np.asarray(w_star), w_ref, atol=1e-4)


def test_kmeans_interval_invariant():
    """1-D clusters are intervals: assignment must be sorted."""
    vals, counts, _ = unique_with_counts(_data(9))
    _, idx, _, _ = kmeans_1d(jnp.asarray(vals, jnp.float32),
                             jnp.asarray(counts, jnp.float32), 10)
    assert bool(jnp.all(jnp.diff(idx) >= 0))


def test_dp_is_loss_lower_bound():
    vals, counts, _ = unique_with_counts(_data(10))
    ones = np.ones_like(counts)
    _, _, _, sse = optimal_kmeans_1d(vals, ones, 9)
    prob = make_problem(vals, counts)
    for meth in ("kmeans", "kmeans_ls", "mog", "dtc"):
        _, info = quantize(_data(10), meth, num_values=9)
        assert sse <= info["l2_loss_unique"] + 1e-6, meth


# ---------------------------------------------------------------- API invariants

@pytest.mark.parametrize("method", ALL_METHODS)
def test_api_end_to_end(method):
    w = _data(11, n=300)
    kw = dict(lam=0.05) if method in LAM_METHODS else dict(num_values=10)
    qt, info = quantize(w, method, **kw)
    dense = np.asarray(qt.to_dense())
    assert dense.shape == w.shape
    assert np.isfinite(dense).all()
    # value sharing: distinct values == codebook size
    assert len(np.unique(dense)) == info["n_values"]
    if method in COUNT_METHODS:
        assert info["n_values"] <= 10


def test_count_methods_respect_l():
    w = _data(12)
    for method in COUNT_METHODS:
        for l in (2, 5, 33):
            _, info = quantize(w, method, num_values=l)
            assert info["n_values"] <= l, (method, l)


def test_hard_sigmoid_clip():
    """Eq. 21: outputs must live in [a, b] after clipping."""
    w = np.linspace(-0.5, 1.5, 200)
    qt, _ = quantize(w, "kmeans", num_values=7, clip=(0.0, 1.0))
    d = np.asarray(qt.to_dense())
    assert d.min() >= 0.0 and d.max() <= 1.0


def test_weighted_improves_full_vector_loss():
    rng = np.random.default_rng(13)
    w = np.concatenate([np.full(900, 1.0), rng.normal(5, 1, 100)]).round(1)
    _, unw = quantize(w, "kmeans_ls", num_values=4, weighted=False)
    _, wt = quantize(w, "kmeans_ls", num_values=4, weighted=True)
    assert wt["l2_loss"] <= unw["l2_loss"] + 1e-9


# ---------------------------------------------------------------- properties

@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32), min_size=3,
                max_size=120),
       st.integers(2, 12))
def test_property_quantize_invariants(data, l):
    """For any input and target count: (1) <= l distinct values, (2) codebook
    within data range for count methods, (3) reconstruction shape preserved,
    (4) loss is zero when l >= number of unique values."""
    w = np.asarray(data, np.float32)
    qt, info = quantize(w, "kmeans_ls", num_values=l)
    assert info["n_values"] <= l
    cb = np.asarray(qt.codebook)
    assert cb.min() >= w.min() - 1e-4 and cb.max() <= w.max() + 1e-4
    if len(np.unique(w)) <= l:
        assert info["l2_loss"] < 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_property_tv_optimality_random(seed):
    """TV solution's objective never exceeds CD's on random problems."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(3, 60))
    vals = np.unique(rng.normal(0, 1, m))
    prob = make_problem(vals, np.ones_like(vals))
    lam = float(rng.uniform(0.001, 0.5))
    u_tv = tv_solve_problem(prob, lam)
    a_cd, _ = cd_solve(prob, lam, penalize_first=False, max_sweeps=500, tol=1e-10)
    d = np.asarray(prob.d)
    a_tv = np.diff(u_tv, prepend=0.0) / np.where(d == 0, 1.0, d)
    f_tv = float(objective(prob, jnp.asarray(a_tv, jnp.float32), lam, penalize_first=False))
    f_cd = float(objective(prob, a_cd, lam, penalize_first=False))
    assert f_tv <= f_cd + 1e-4 * max(1.0, abs(f_cd))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_idempotence(seed):
    """Quantizing an already-quantized vector with the same l is lossless."""
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, 200)
    qt, _ = quantize(w, "kmeans_ls", num_values=6)
    w2 = np.asarray(qt.to_dense())
    qt2, info2 = quantize(w2, "kmeans_ls", num_values=6)
    assert info2["l2_loss"] < 1e-8
