"""Per-architecture smoke tests: reduced config of the same family, one
forward (and where applicable prefill+decode consistency) on CPU."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, get_reduced_config

B, S = 2, 16


def _batch(cfg, rng):
    ks = jax.random.split(rng, 3)
    batch = {}
    if cfg.input_kind == "embeds" and cfg.family != "encdec":
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.float32)
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    elif cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                                jnp.float32)
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced_config(arch)
    rng = jax.random.PRNGKey(0)
    params = models.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    logits = models.forward(params, cfg, batch, train=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch):
    cfg = get_reduced_config(arch)
    rng = jax.random.PRNGKey(1)
    params = models.init_params(cfg, rng)
    batch = _batch(cfg, rng)

    def loss_fn(p):
        logits = models.forward(p, cfg, batch, train=True)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, batch["labels"][..., None],
                                             axis=-1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: bad grads"
    # at least 99% of parameters receive gradient signal
    total = sum(g.size for g in flat)
    nonzero = sum(int((g != 0).sum()) for g in flat)
    assert nonzero > 0.5 * total, f"{arch}: {nonzero}/{total} grads nonzero"


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "gemma2_27b", "rwkv6_3b",
                                  "jamba_1_5_large_398b",
                                  "deepseek_v2_lite_16b", "whisper_tiny"])
def test_prefill_decode_matches_forward(arch):
    """logits(full forward)[:, -1] == prefill(S-1) then one decode step."""
    cfg = get_reduced_config(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    rng = jax.random.PRNGKey(2)
    params = models.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    full = models.forward(params, cfg, batch, train=False)

    cache = models.init_cache(cfg, B, S, enc_len=S)
    if cfg.family == "encdec":
        pre_batch = {"enc_embeds": batch["enc_embeds"],
                     "tokens": batch["tokens"][:, :S - 1]}
    else:
        pre_batch = {"tokens": batch["tokens"][:, :S - 1]}
    _, cache = models.prefill(params, cfg, pre_batch, cache)
    step_logits, _ = models.decode_step(params, cfg,
                                        batch["tokens"][:, S - 1:S],
                                        cache, S - 1)
    np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-3, rtol=2e-3)
