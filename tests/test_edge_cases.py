"""Degenerate-input edge cases: constant vectors, singletons, zeros, and
already-k-valued inputs must quantize losslessly and finitely."""
import numpy as np
import pytest

from repro.core import LAM_METHODS, quantize

EDGE_VECS = [
    (np.full(50, 3.14), "constant"),
    (np.array([1.0, 2.0]), "two-values"),
    (np.array([-5.0]), "singleton"),
    (np.zeros(10), "zeros"),
]
METHODS = ["l1_ls", "kmeans_ls", "tv", "dp", "iter_l1", "l0", "kmeans"]


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("w,name", EDGE_VECS)
def test_degenerate_inputs(method, w, name):
    kw = dict(lam=0.01) if method in LAM_METHODS else dict(num_values=2)
    qt, info = quantize(w, method, **kw)
    dense = np.asarray(qt.to_dense())
    assert np.isfinite(dense).all(), (method, name)
    assert dense.shape == w.shape
    # <= 2 unique input values means the quantization must be exact
    if len(np.unique(w)) <= 2 and method not in LAM_METHODS:
        assert info["l2_loss"] < 1e-10, (method, name)
