"""HLO analyzer + roofline model tests: trip-count awareness (the reason the
analyzer exists - cost_analysis counts scan bodies once), dot FLOPs,
collective bytes, and the analytic parameter model vs real param counts."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze
from repro.analysis.roofline import Roofline, model_params_active
from repro.configs import get_reduced_config


@pytest.mark.xfail(strict=False, reason="HLO text emitted by the pinned jax/XLA lacks the scan-trip/collective markers the analyzer parses; passes on newer jax")
def test_analyzer_multiplies_scan_trip_counts():
    w = jnp.zeros((128, 128), jnp.float32)

    def f(x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    compiled = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)
                                ).compile()
    stats = analyze(compiled.as_text())
    one_iter = 2 * 128 ** 3
    assert 12 in stats["while_trips"].values()
    assert stats["flops"] >= 12 * one_iter * 0.99, stats["flops"]
    # and cost_analysis indeed under-counts (the bug we work around)
    ca = compiled.cost_analysis()
    assert ca["flops"] < 2 * one_iter


@pytest.mark.xfail(strict=False, reason="HLO text emitted by the pinned jax/XLA lacks the scan-trip/collective markers the analyzer parses; passes on newer jax")
def test_analyzer_counts_collective_bytes():
    from repro.launch.mesh import compat_mesh

    mesh = compat_mesh((8,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jnp.sum(x * x)  # reduction over sharded dim -> all-reduce

    c = jax.jit(f, in_shardings=NamedSharding(mesh, P("d")),
                out_shardings=NamedSharding(mesh, P())).lower(
        jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
    stats = analyze(c.as_text())
    assert stats["collective_bytes"] > 0


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12, hbm_bytes=819e9 / 2, collective_bytes=0,
                 model_flops_per_device=197e12 * 0.75)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert r.dominant == "compute"
    assert abs(r.roofline_fraction - 1.0) < 1e-9
    assert abs(r.useful_flops_ratio - 0.75) < 1e-9


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "granite_moe_3b_a800m",
                                  "rwkv6_3b", "jamba_1_5_large_398b"])
def test_analytic_param_count_matches_actual(arch):
    """model_params_active's total must track the real initialized count."""
    from repro import models

    cfg = get_reduced_config(arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    total, active = model_params_active(cfg)
    assert active <= total
    # analytic model skips norms/biases/small lora leaves: within 20%
    assert 0.65 * actual < total < 1.25 * actual, (total, actual)
